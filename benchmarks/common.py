"""Shared benchmark utilities."""

from __future__ import annotations

import time
from collections.abc import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            **kwargs) -> float:
    """Median wall-clock microseconds per call (after jit warmup)."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
