"""Shared benchmark utilities."""

from __future__ import annotations

import json
import platform
import time
from collections.abc import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            **kwargs) -> float:
    """Median wall-clock microseconds per call (after jit warmup)."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


_RESULTS: list[dict] = []


def emit(name: str, us: float, derived: str = "") -> None:
    """Print one CSV result line and collect it for :func:`write_json`."""
    print(f"{name},{us:.1f},{derived}", flush=True)
    _RESULTS.append({"name": name, "us": round(us, 1), "derived": derived})


def write_json(path: str) -> None:
    """Dump every emitted result (plus run metadata) as a JSON artifact —
    CI uploads this per run so regressions are diffable across commits."""
    doc = {
        "results": _RESULTS,
        "meta": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "python": platform.python_version(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        },
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {len(_RESULTS)} results to {path}", flush=True)
