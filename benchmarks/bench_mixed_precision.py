
"""Paper Table 1: training time fp32 vs mixed precision (+ speedup).

CPU container: measures the framework's mixed-precision machinery (policy
cast points, dynamic loss scaling, master weights) on a reduced ResNet;
the TPU speedup column comes from the roofline (memory term halves in bf16).
Also reports activation-byte footprints (the paper's "halves memory" claim).
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as nn
from repro.core import functions as F
from repro.distributed.train_step import init_train_state, make_train_step
from repro.models.cnn import resnet
from repro.precision.loss_scale import dynamic_scaler, static_scaler
from repro.solvers import Momentum
from benchmarks.common import emit, time_fn


def _train_step_for(type_config: str):
    ctx = nn.get_extension_context("cpu", type_config=type_config)

    def build():
        with nn.context_scope(ctx):
            nn.clear_parameters()
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.standard_normal((8, 3, 32, 32)),
                            ctx.policy.compute_dtype)
            y = jnp.asarray(rng.integers(0, 10, (8,)), jnp.int32)

            def loss_fn(params, batch):
                def fwd(img):
                    return resnet(img, "resnet18", num_classes=10, width=16)
                logits = nn.apply(fwd, params, batch["x"])
                return jnp.mean(F.softmax_cross_entropy(logits, batch["y"]))

            params = nn.init(
                lambda img: resnet(img, "resnet18", num_classes=10, width=16),
                jax.random.key(0), x)
            solver = Momentum(lr=0.05)
            scaler = dynamic_scaler() if ctx.policy.needs_loss_scaling \
                else static_scaler(1.0)
            state = init_train_state(params, solver, scaler)
            step = jax.jit(make_train_step(loss_fn, solver, scaler))
            batch = {"x": x, "y": y}

            def run(s):
                with nn.context_scope(ctx):
                    return step(s, batch)

            act_bytes = int(np.prod(x.shape)) * x.dtype.itemsize
            return run, state, act_bytes

    return build()


def main() -> None:
    results = {}
    for tc in ("float", "half", "bf16"):
        run, state, act_bytes = _train_step_for(tc)
        us = time_fn(lambda: run(state), iters=3)
        results[tc] = us
        emit(f"table1/resnet18w16_train_{tc}", us,
             f"act_bytes_per_image={act_bytes // 8}")
    emit("table1/speedup_half_vs_fp32", results["float"],
         f"x{results['float'] / results['half']:.2f}")
    emit("table1/speedup_bf16_vs_fp32", results["float"],
         f"x{results['float'] / results['bf16']:.2f}")


if __name__ == "__main__":
    main()
