"""Bench-regression gate: diff produced bench JSONs against committed
baselines and fail on meaningful regressions.

Usage (what the CI bench-smoke job runs)::

    python benchmarks/compare.py BASELINE.json NEW.json [BASELINE2 NEW2 ...]
        [--threshold 1.20]

Every ``us`` value :func:`benchmarks.common.emit` records is
lower-is-better by construction (rates are stored as ``1e6 / rate``), so
one rule covers throughputs, latencies and footprints alike: a metric
regresses when ``new.us > baseline.us * threshold``.

Only metrics whose names match the GATED patterns — decode tok/s, TTFT,
and per-device bytes — can fail the gate; everything else in the
baseline is printed for context but never fails (hit rates, preemption
counts and drain times are workload diagnostics, not regression
signals). A gated metric that *disappears* from the new results fails
too: silently dropping a measurement must not read as "no regression".

Baselines are committed as ``BENCH_*.json``, seeded by running the exact
CI command (same ``--smoke`` sizes) — see the bench-smoke job in
``.github/workflows/ci.yml``. After an intentional perf change, reseed
the affected baseline the same way and commit it with the change.
"""

import argparse
import json
import re
import sys

# the gate covers exactly the regression surface the serving tier promises:
# time-to-first-token, steady-state decode rate, memory per device,
# (PR 8) how fast a replica death turns back into flowing tokens, and
# (PR 10) KV-cache bytes per token — lower is better, so a change that
# bloats the quantized pool layout (wider scales, lost packing) fails here
GATED = (
    re.compile(r"ttft"),
    re.compile(r"decode_tok_per_s"),
    re.compile(r"bytes_per_device"),
    re.compile(r"recovery"),
    re.compile(r"kv_bytes_per_token"),
)

DEFAULT_THRESHOLD = 1.20


def load(path: str) -> dict[str, dict]:
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: r for r in data["results"]}


def is_gated(name: str) -> bool:
    return any(p.search(name) for p in GATED)


def compare_pair(base_path: str, new_path: str,
                 threshold: float) -> list[str]:
    """Print a comparison table for one baseline/new pair; return the
    list of gate failures (empty = pass)."""
    base, new = load(base_path), load(new_path)
    failures: list[str] = []
    print(f"\n{base_path} -> {new_path} (fail if gated ratio > "
          f"{threshold:.2f}x)")
    for name, b in base.items():
        gated = is_gated(name)
        n = new.get(name)
        if n is None:
            if gated:
                failures.append(f"{name}: gated metric missing from "
                                f"{new_path}")
                print(f"  FAIL {name}: missing from new results")
            else:
                print(f"  ---- {name}: missing (ungated, ignored)")
            continue
        if b["us"] <= 0:
            print(f"  ---- {name}: baseline us={b['us']} (unratioable, "
                  f"ignored)")
            continue
        ratio = n["us"] / b["us"]
        bad = gated and ratio > threshold
        tag = "FAIL" if bad else ("gate" if gated else "info")
        print(f"  {tag} {name}: {b['us']:.1f} -> {n['us']:.1f} us "
              f"({ratio:.2f}x)")
        if bad:
            failures.append(
                f"{name}: {b['us']:.1f} -> {n['us']:.1f} us "
                f"({ratio:.2f}x > {threshold:.2f}x) — {n.get('derived', '')}")
    for name in new:
        if name not in base:
            print(f"  new  {name}: {new[name]['us']:.1f} us (no baseline, "
                  f"not gated)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on >threshold regressions vs committed baselines")
    ap.add_argument("pairs", nargs="+",
                    help="alternating BASELINE.json NEW.json paths")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="max allowed new/baseline us ratio on gated "
                         "metrics (default 1.20 = 20%% regression)")
    args = ap.parse_args(argv)
    if len(args.pairs) % 2:
        ap.error("need an even number of paths (baseline/new pairs)")
    failures: list[str] = []
    for i in range(0, len(args.pairs), 2):
        failures += compare_pair(args.pairs[i], args.pairs[i + 1],
                                 args.threshold)
    if failures:
        print(f"\nbench-compare: {len(failures)} regression(s):",
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nbench-compare: all gated metrics within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
