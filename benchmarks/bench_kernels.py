
"""Kernel-layer microbenches: XLA naive vs blockwise-flash attention and the
SSD scan (CPU wall time; the TPU story is the roofline/§Perf tables)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.ssd import ref as ssd_ref
from benchmarks.common import emit, time_fn


def main() -> None:
    rng = np.random.default_rng(0)
    B, S, Hq, Hkv, D = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    naive = jax.jit(lambda q, k, v: fa_ref.mha_reference(q, k, v, causal=True))
    chunk = jax.jit(lambda q, k, v: fa_ref.mha_chunked(
        q, k, v, causal=True, block_q=256, block_k=256))
    us_n = time_fn(naive, q, k, v, iters=3)
    us_c = time_fn(chunk, q, k, v, iters=3)
    emit("kernels/attention_naive_1k", us_n)
    emit("kernels/attention_folded_blockwise_1k", us_c,
         f"x{us_n / us_c:.2f} vs naive")

    B, S, H, P, G, N = 1, 512, 8, 64, 1, 64
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, H), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, G, N)), jnp.float32)
    naive_ssd = jax.jit(lambda *a: ssd_ref.ssd_naive(*a))
    chunk_ssd = jax.jit(lambda *a: ssd_ref.ssd_chunked(*a, chunk=64))
    us_n = time_fn(naive_ssd, x, dt, A, Bm, Cm, iters=3)
    us_c = time_fn(chunk_ssd, x, dt, A, Bm, Cm, iters=3)
    emit("kernels/ssd_tokenscan_512", us_n)
    emit("kernels/ssd_chunked_512", us_c, f"x{us_n / us_c:.2f} vs token scan")


if __name__ == "__main__":
    main()
