"""Kernel-layer microbenches: XLA naive vs blockwise-flash attention, the
SSD scan, and the PR-3 paged-attention decode variants (CPU wall time; the
TPU story is the roofline/§Perf tables).

The paged section compares three lowerings of the same decode step —
dense cache, gather-then-dense paged reference, and the Pallas page-table
walk (interpret mode on CPU) — and reports each variant's compiled temp
allocation from ``memory_analysis()``. The kernel variant is *asserted*
to stay under the dense-gather temp footprint: the whole point of walking
the page table in VMEM is that the ``(B, max_blocks*block_size, Hkv, D)``
gather copy never exists.

Run with ``--json out.json`` for a machine-readable artifact (CI uploads
it per push); ``--smoke`` trims sizes/iters for the CI bench-smoke job.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import quant
from repro.kernels.flash_attention import paged_attention as pa
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.ssd import ref as ssd_ref
from repro.models import transformer as T
from benchmarks.common import emit, time_fn, write_json


def bench_attention(rng) -> None:
    B, S, Hq, Hkv, D = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    naive = jax.jit(lambda q, k, v: fa_ref.mha_reference(q, k, v, causal=True))
    chunk = jax.jit(lambda q, k, v: fa_ref.mha_chunked(
        q, k, v, causal=True, block_q=256, block_k=256))
    us_n = time_fn(naive, q, k, v, iters=3)
    us_c = time_fn(chunk, q, k, v, iters=3)
    emit("kernels/attention_naive_1k", us_n)
    emit("kernels/attention_folded_blockwise_1k", us_c,
         f"x{us_n / us_c:.2f} vs naive")


def bench_ssd(rng) -> None:
    B, S, H, P, G, N = 1, 512, 8, 64, 1, 64
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, H), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, G, N)), jnp.float32)
    naive_ssd = jax.jit(lambda *a: ssd_ref.ssd_naive(*a))
    chunk_ssd = jax.jit(lambda *a: ssd_ref.ssd_chunked(*a, chunk=64))
    us_n = time_fn(naive_ssd, x, dt, A, Bm, Cm, iters=3)
    us_c = time_fn(chunk_ssd, x, dt, A, Bm, Cm, iters=3)
    emit("kernels/ssd_tokenscan_512", us_n)
    emit("kernels/ssd_chunked_512", us_c, f"x{us_n / us_c:.2f} vs token scan")


def temp_bytes(fn, *args) -> int:
    """Compiled-HLO temp allocation (the materialized-gather detector).

    Fails loudly when the backend can't report it — a silent 0 would make
    the no-gather acceptance assert below pass vacuously."""
    ma = jax.jit(fn).lower(*args).compile().memory_analysis()
    if ma is None or not hasattr(ma, "temp_size_in_bytes"):
        raise RuntimeError(
            "memory_analysis() reports no temp_size_in_bytes on this "
            "backend — the paged-decode gather-temp bound cannot be checked")
    return int(ma.temp_size_in_bytes)


def bench_paged(rng, smoke: bool) -> None:
    """Dense decode vs gather-then-dense paged vs Pallas-interpret paged.

    Wall clocks on CPU favor the XLA variants (the interpreter emulates the
    grid + DMAs step by step); the HBM-traffic story is the temp-bytes
    column — on TPU the kernel's advantage IS that missing gather pass.
    """
    B, Hq, Hkv, D = (2, 4, 2, 32) if smoke else (4, 8, 2, 64)
    bs, MB = (8, 8) if smoke else (16, 16)
    Smax = bs * MB
    NB = B * MB + 1
    iters = 2 if smoke else 5
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((B, Smax, Hkv, D)), jnp.float32)
    vd = jnp.asarray(rng.standard_normal((B, Smax, Hkv, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((NB, bs, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((NB, bs, Hkv, D)), jnp.float32)
    pages = jnp.asarray(1 + np.arange(B * MB).reshape(B, MB), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, Smax + 1, B), jnp.int32)

    dense = jax.jit(lambda *a: fa_ref.decode_reference(*a))
    gather = jax.jit(lambda *a: fa_ref.paged_decode_reference(*a))
    pallas = jax.jit(lambda *a: pa.paged_decode(*a, interpret=True))

    gather_bytes = B * MB * bs * Hkv * D * 4       # ONE pool's dense view
    t_dense = temp_bytes(lambda *a: fa_ref.decode_reference(*a),
                         q, kd, vd, lengths)
    t_gather = temp_bytes(lambda *a: fa_ref.paged_decode_reference(*a),
                          q, kp, vp, pages, lengths)
    t_pallas = temp_bytes(lambda *a: pa.paged_decode(*a, interpret=True),
                          q, kp, vp, pages, lengths)

    us_d = time_fn(dense, q, kd, vd, lengths, iters=iters)
    us_g = time_fn(gather, q, kp, vp, pages, lengths, iters=iters)
    us_p = time_fn(pallas, q, kp, vp, pages, lengths, iters=iters)
    emit("kernels/paged_decode_dense", us_d, f"temp={t_dense}B")
    emit("kernels/paged_decode_gather_ref", us_g,
         f"temp={t_gather}B gather={gather_bytes}B")
    emit("kernels/paged_decode_pallas_interpret", us_p,
         f"temp={t_pallas}B gather={gather_bytes}B")

    # acceptance: the kernel's compiled HLO holds no dense gather temp —
    # its transient footprint must stay under a single pool's dense view
    # (the reference allocates ~2 of them, one per K/V pool)
    assert t_pallas < gather_bytes, (
        f"paged Pallas decode materializes {t_pallas}B of temps — at least "
        f"one dense {gather_bytes}B gather copy snuck back in")

    # prefill walk parity point: chunked prefill through the page table
    C = 4 if smoke else 8
    qc = jnp.asarray(rng.standard_normal((B, C, Hq, D)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, Smax - C, B), jnp.int32)
    g_pre = jax.jit(lambda *a: fa_ref.paged_prefill_reference(*a))
    p_pre = jax.jit(lambda *a: pa.paged_prefill(*a, interpret=True))
    us_gp = time_fn(g_pre, qc, kp, vp, pages, pos, iters=iters)
    us_pp = time_fn(p_pre, qc, kp, vp, pages, pos, iters=iters)
    emit("kernels/paged_prefill_gather_ref", us_gp)
    emit("kernels/paged_prefill_pallas_interpret", us_pp)


def bench_paged_quant(rng, smoke: bool) -> None:
    """Quantized (int8) pools through the same three lowerings, plus the
    byte-accounting acceptance: at head_dim 64, int8 pools + f32 scales
    must cost <= 0.55x the bf16 bytes/token, and the quantized Pallas walk
    must still never materialize the dense DEQUANTIZED gather copy (the
    failure mode that would erase the bandwidth win)."""
    B, Hq, Hkv = (2, 4, 2) if smoke else (4, 8, 2)
    D = 64                               # the 0.55x bound is a D=64 claim
    bs, MB = (8, 8) if smoke else (16, 16)
    Smax = bs * MB
    NB = B * MB + 1
    iters = 2 if smoke else 5
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((NB, bs, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((NB, bs, Hkv, D)), jnp.float32)
    kq, ks = quant.quantize(kp, jnp.int8)
    vq, vs = quant.quantize(vp, jnp.int8)
    pages = jnp.asarray(1 + np.arange(B * MB).reshape(B, MB), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, Smax + 1, B), jnp.int32)

    gather_f32 = lambda *a: fa_ref.paged_decode_reference(
        a[0], a[1], a[2], a[5], a[6], k_scale=a[3], v_scale=a[4])
    pallas_q = lambda *a: pa.paged_decode(
        a[0], a[1], a[2], a[5], a[6], k_scale=a[3], v_scale=a[4],
        interpret=True)
    args = (q, kq, vq, ks, vs, pages, lengths)

    # the dense view a dequantize-then-gather lowering would materialize:
    # ONE pool's pages widened to f32 (same bytes as the unquantized bench)
    gather_bytes = B * MB * bs * Hkv * D * 4
    t_gather = temp_bytes(gather_f32, *args)
    t_pallas = temp_bytes(pallas_q, *args)
    us_g = time_fn(jax.jit(gather_f32), *args, iters=iters)
    us_p = time_fn(jax.jit(pallas_q), *args, iters=iters)
    emit("kernels/paged_decode_quant_gather_ref", us_g,
         f"temp={t_gather}B gather={gather_bytes}B, int8 pools")
    emit("kernels/paged_decode_quant_pallas_interpret", us_p,
         f"temp={t_pallas}B gather={gather_bytes}B, int8 pools, "
         f"block dequant in VMEM")
    assert t_pallas < gather_bytes, (
        f"quantized Pallas decode materializes {t_pallas}B of temps — a "
        f"dense dequantized {gather_bytes}B gather copy snuck back in")

    # state-spec byte accounting at head_dim 64 (the ISSUE acceptance
    # number): bytes/token = pool + scale leaves over block_size tokens
    cfg = ModelConfig(name="q", family="dense", n_layers=2, d_model=256,
                      n_heads=4, n_kv_heads=Hkv, d_ff=512, vocab_size=64,
                      head_dim=D)
    spec_bytes = lambda dt: sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
        for s in T.paged_kv_cache_specs(cfg, NB, bs, dt).values())
    bpt_bf = spec_bytes(jnp.bfloat16) / (NB * bs)
    bpt_i8 = spec_bytes(jnp.int8) / (NB * bs)
    ratio = bpt_i8 / bpt_bf
    emit("kernels/paged_quant_kv_bytes_per_token", bpt_i8,
         f"{bpt_i8:.0f} B/tok int8+scales vs {bpt_bf:.0f} bf16 at D={D} "
         f"(x{ratio:.3f})")
    assert ratio <= 0.55, (
        f"int8 pools + scales cost {ratio:.3f}x bf16 bytes/token at "
        f"D={D} — exceeds the 0.55x acceptance bound")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump results as a JSON artifact")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few iters (CI bench-smoke)")
    args = ap.parse_args(argv if argv is not None else [])
    rng = np.random.default_rng(0)
    if not args.smoke:
        bench_attention(rng)
        bench_ssd(rng)
    bench_paged(rng, args.smoke)
    bench_paged_quant(rng, args.smoke)
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
