
"""Paper Tables 2-3: per-architecture training step time (model zoo).

The paper benchmarks its reference-model zoo (ResNet variants, lightweight
models); ours is the 10 assigned architectures at smoke scale — the same
framework-overhead measurement — plus loss-decrease over 20 steps standing
in for the (data-gated) validation-error column.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as nn
from repro.configs import ARCHS
from repro.distributed.train_step import init_train_state, make_train_step
from repro.models.registry import get_model
from repro.precision.loss_scale import static_scaler
from repro.solvers import Adam
from benchmarks.common import emit, time_fn


def bench_arch(arch: str) -> None:
    nn.clear_parameters()
    cfg = dataclasses.replace(ARCHS[arch].smoke(), remat="none")
    api = get_model(cfg)
    rng = np.random.default_rng(0)
    S = max(32, cfg.ssm_chunk * 2 if cfg.ssm_state else 32)
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (2, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(1, cfg.vocab_size, (2, S)),
                                   jnp.int32)}
    if cfg.mrope:
        pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None, :, None],
                              (2, S, 3))
        batch["positions"] = jnp.asarray(np.ascontiguousarray(pos))
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((2, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)

    def loss_fn(p, b):
        return nn.apply(lambda **kw: api.loss_fn(**kw), p, **b)

    fwd = {k: v for k, v in batch.items() if k != "labels"}
    params = nn.init(lambda **kw: api.forward(**kw), jax.random.key(0), **fwd)
    solver = Adam(alpha=3e-3)
    scaler = static_scaler(1.0)
    state = init_train_state(params, solver, scaler)
    step = jax.jit(make_train_step(loss_fn, solver, scaler),
                   donate_argnums=())
    us = time_fn(lambda: step(state, batch), iters=3)

    losses = []
    s = state
    for _ in range(20):
        s, m = step(s, batch)
        losses.append(float(m["loss"]))
    emit(f"table2_3/{arch}", us,
         f"loss {losses[0]:.3f}->{losses[-1]:.3f}")


def main() -> None:
    for arch in sorted(ARCHS):
        bench_arch(arch)


if __name__ == "__main__":
    main()
