
"""Paper §2.2: static vs dynamic computation-graph overhead.

Same LeNet, three execution planes: dynamic (define-by-run, op-by-op with
VJP capture), static deferred (graph built once, per-node forward), and
static compiled (whole-graph XLA program) — the paper's "static is fast"
claim, quantified.
"""

import jax
import numpy as np

import repro.core as nn
from repro.models.cnn import lenet
from benchmarks.common import emit, time_fn


def main() -> None:
    nn.clear_parameters()
    x_np = np.random.default_rng(0).standard_normal((8, 1, 28, 28)) \
        .astype(np.float32)

    # dynamic: every call rebuilds + executes op by op
    def dynamic_call():
        with nn.auto_forward():
            xv = nn.Variable(data=x_np)
            return lenet(xv).data

    us_dyn = time_fn(dynamic_call, iters=5)
    emit("graph/dynamic_op_by_op", us_dyn)

    # static deferred: graph built once, forward() re-executes nodes
    xv = nn.Variable(data=x_np)
    y = lenet(xv)

    def static_forward():
        y.forward()
        return y.data

    us_static = time_fn(static_forward, iters=5)
    emit("graph/static_per_node", us_static)

    # static compiled: one fused XLA program (first call compiles)
    cg = nn.compile_graph(y)

    def compiled_forward():
        cg.forward()
        return y.data

    us_comp = time_fn(compiled_forward, iters=5)
    emit("graph/static_compiled", us_comp,
         f"speedup_vs_dynamic x{us_dyn / us_comp:.1f}")


if __name__ == "__main__":
    main()
