
"""Serving engine throughput: continuous batching vs sequential requests."""

import jax
import jax.numpy as jnp
import time

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from benchmarks.common import emit

CFG = ModelConfig(name="t", family="dense", n_layers=4, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512,
                  head_dim=32, remat="none")


def run(max_batch: int, n_requests: int = 8, new_tokens: int = 16) -> float:
    nn.clear_parameters()
    api = get_model(CFG)
    params = nn.init(lambda t: T.forward(CFG, t), jax.random.key(0),
                     jnp.zeros((1, 8), jnp.int32))
    eng = ServingEngine(api, params, max_batch=max_batch, max_seq=64)
    for i in range(n_requests):
        eng.submit(Request(uid=i, prompt=[1 + i, 2, 3],
                           max_new_tokens=new_tokens))
    eng.step()  # warm the compiled step
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    return toks / dt


def main() -> None:
    seq = run(max_batch=1)
    cb = run(max_batch=4)
    emit("serving/sequential_tok_per_s", 1e6 / max(seq, 1e-9), f"{seq:.1f} tok/s")
    emit("serving/continuous_batch4_tok_per_s", 1e6 / max(cb, 1e-9),
         f"{cb:.1f} tok/s, x{cb / seq:.2f}")


if __name__ == "__main__":
    main()
