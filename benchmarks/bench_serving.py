
"""Serving engine throughput: continuous batching vs sequential requests,
and chunked prefill vs token-by-token prompt absorption."""

import jax
import jax.numpy as jnp
import time

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from benchmarks.common import emit

CFG = ModelConfig(name="t", family="dense", n_layers=4, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512,
                  head_dim=32, remat="none")


def make_engine(max_batch: int, max_seq: int, chunk: int) -> ServingEngine:
    nn.clear_parameters()
    api = get_model(CFG)
    params = nn.init(lambda t: T.forward(CFG, t), jax.random.key(0),
                     jnp.zeros((1, 8), jnp.int32))
    return ServingEngine(api, params, max_batch=max_batch, max_seq=max_seq,
                         chunk=chunk)


def run(max_batch: int, n_requests: int = 8, new_tokens: int = 16,
        prompt_len: int = 3, chunk: int = 16, max_seq: int = 64) -> float:
    eng = make_engine(max_batch, max_seq, chunk)
    for i in range(n_requests):
        prompt = [1 + (i + j) % (CFG.vocab_size - 1)
                  for j in range(prompt_len)]
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=new_tokens))
    eng.step()  # warm the (B, chunk) prefill shape
    eng.step()  # warm the (B, 1) decode shape
    pre = sum(len(r.generated) for r in eng.completed) \
        + sum(len(r.generated) for r in eng.active if r is not None)
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done) - pre  # steady-state only
    return toks / dt


def run_prefill(chunk: int, prompt_len: int = 64, n_requests: int = 4,
                new_tokens: int = 4) -> tuple[float, float]:
    """Returns (wall seconds to drain, mean TTFT) — prompt-dominated load."""
    eng = make_engine(4, 128, chunk)
    # max_new 2 forces one decode step after absorption, so BOTH compiled
    # step shapes (B, chunk) and (B, 1) are warm before timing
    warm = Request(uid=-1, prompt=[1] * prompt_len, max_new_tokens=2)
    eng.submit(warm)
    eng.run_until_drained()
    eng.completed.clear()
    for i in range(n_requests):
        prompt = [1 + (i + j) % (CFG.vocab_size - 1)
                  for j in range(prompt_len)]
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=new_tokens))
    t0 = time.perf_counter()
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    return dt, eng.metrics_summary().get("mean_ttft_s", 0.0)


def main() -> None:
    seq = run(max_batch=1)
    cb = run(max_batch=4)
    emit("serving/sequential_tok_per_s", 1e6 / max(seq, 1e-9), f"{seq:.1f} tok/s")
    emit("serving/continuous_batch4_tok_per_s", 1e6 / max(cb, 1e-9),
         f"{cb:.1f} tok/s, x{cb / seq:.2f}")

    # chunked prefill vs token-by-token absorption, 64-token prompts
    t_tok, ttft_tok = run_prefill(chunk=1)
    t_chk, ttft_chk = run_prefill(chunk=16)
    emit("serving/prefill_tokbytok_s", t_tok * 1e6,
         f"{t_tok:.2f}s drain, TTFT {ttft_tok * 1e3:.0f}ms")
    emit("serving/prefill_chunk16_s", t_chk * 1e6,
         f"{t_chk:.2f}s drain, TTFT {ttft_chk * 1e3:.0f}ms, "
         f"x{t_tok / max(t_chk, 1e-9):.2f} faster")


if __name__ == "__main__":
    main()
