
"""Serving engine throughput: continuous batching vs sequential requests,
chunked prefill vs token-by-token absorption, and the PR-2 paged-cache
workloads — shared-prefix TTFT (prefix cache on/off vs the PR-1 dense
baseline) and cache-memory footprint at equal capacity.

Run with ``--json out.json`` to dump the results as a machine-readable
artifact (CI uploads it per push); ``--smoke`` trims request counts for
the CI bench-smoke job.
"""

import argparse
import time

import jax
import jax.numpy as jnp

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from benchmarks.common import emit, write_json

CFG = ModelConfig(name="t", family="dense", n_layers=4, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512,
                  head_dim=32, remat="none")

_PARAMS = None


def get_params():
    global _PARAMS
    if _PARAMS is None:
        nn.clear_parameters()
        _PARAMS = nn.init(lambda t: T.forward(CFG, t), jax.random.key(0),
                          jnp.zeros((1, 8), jnp.int32))
    return _PARAMS


def make_engine(max_batch: int, max_seq: int, chunk: int,
                **kw) -> ServingEngine:
    return ServingEngine(get_model(CFG), get_params(), max_batch=max_batch,
                         max_seq=max_seq, chunk=chunk, **kw)


def state_mbytes(state) -> float:
    return sum(a.size * a.dtype.itemsize
               for a in jax.tree.leaves(state)) / 2**20


def run(max_batch: int, n_requests: int = 8, new_tokens: int = 16,
        prompt_len: int = 3, chunk: int = 16, max_seq: int = 64) -> float:
    eng = make_engine(max_batch, max_seq, chunk)
    for i in range(n_requests):
        prompt = [1 + (i + j) % (CFG.vocab_size - 1)
                  for j in range(prompt_len)]
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=new_tokens))
    eng.step()  # warm the (B, chunk) prefill shape
    eng.step()  # warm the (B, 1) decode shape
    pre = sum(len(r.generated) for r in eng.completed) \
        + sum(len(r.generated) for r in eng.active if r is not None)
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done) - pre  # steady-state only
    return toks / dt


def run_prefill(chunk: int, prompt_len: int = 64, n_requests: int = 4,
                new_tokens: int = 4) -> tuple[float, float]:
    """Returns (wall seconds to drain, mean TTFT) — prompt-dominated load."""
    eng = make_engine(4, 128, chunk, prefix_cache=False)
    # max_new 2 forces one decode step after absorption, so BOTH compiled
    # step shapes (B, chunk) and (B, 1) are warm before timing
    warm = Request(uid=-1, prompt=[1] * prompt_len, max_new_tokens=2)
    eng.submit(warm)
    eng.run_until_drained()
    eng.completed.clear()
    for i in range(n_requests):
        prompt = [1 + (i + j) % (CFG.vocab_size - 1)
                  for j in range(prompt_len)]
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=new_tokens))
    t0 = time.perf_counter()
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    return dt, eng.metrics_summary().get("mean_ttft_s", 0.0)


def shared_prefix_prompts(n_requests: int, prefix_len: int = 64,
                          tail_len: int = 8) -> list[list[int]]:
    """The ISSUE workload: n requests sharing a ``prefix_len``-token system
    prompt, each with a short unique tail."""
    prefix = [1 + j % (CFG.vocab_size - 1) for j in range(prefix_len)]
    return [prefix + [11 + (13 * i + j) % 97 for j in range(tail_len)]
            for i in range(n_requests)]


def run_shared_prefix(n_requests: int = 8, prefix_len: int = 64,
                      new_tokens: int = 8, *, paged: bool,
                      prefix_cache: bool) -> tuple[float, float]:
    """Returns (mean TTFT over the workload, mean prefix-hit tokens)."""
    eng = make_engine(4, 128, 16, paged=paged, prefix_cache=prefix_cache)
    prompts = shared_prefix_prompts(n_requests, prefix_len)
    # warm both compiled shapes AND (when enabled) the prefix map, exactly
    # as a serving system would carry a hot system-prompt cache
    eng.submit(Request(uid=-1, prompt=prompts[0], max_new_tokens=2))
    eng.run_until_drained()
    eng.completed.clear()
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=new_tokens))
    eng.run_until_drained()
    m = eng.metrics_summary()
    return m["mean_ttft_s"], m.get("mean_prefix_hit_tokens", 0.0)


# ---------------------------------------------------------------------- #
# scheduler: priority classes + preemption vs FIFO on an overcommitted pool
# ---------------------------------------------------------------------- #

def run_priority_mix(policy: str, n_bulk: int = 6, n_hi: int = 2,
                     bulk_new: int = 16, hi_new: int = 8):
    """The ISSUE-5 workload: a backlog of bulk (priority 0) requests
    overcommits a small block pool, then interactive (priority 2)
    requests arrive mid-flight. Under ``policy="fifo"`` they wait out the
    whole backlog; under ``policy="priority"`` they jump the queue and
    preempt bulk actives when the pool is short. Returns (mean TTFT of
    the interactive requests, mean TTFT of bulk, engine)."""
    # pool fits ~2 bulk requests: (24 + 16 tokens) / 4-token blocks = 10
    # blocks each, 21 usable — both slots full leaves ~1 block free
    eng = make_engine(2, 64, 8, block_size=4, num_blocks=22,
                      prefix_cache=False, scheduler=policy)
    # warm both compiled shapes so TTFT measures scheduling, not tracing
    eng.submit(Request(uid=-1, prompt=[1] * 24, max_new_tokens=2))
    eng.run_until_drained()
    eng.completed.clear()
    for i in range(n_bulk):
        prompt = [1 + (i + j) % (CFG.vocab_size - 1) for j in range(24)]
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=bulk_new,
                           priority=0))
    for _ in range(3):   # bulk occupies both slots and most of the pool
        eng.step()
    for i in range(n_hi):
        prompt = [7 + (3 * i + j) % 89 for j in range(8)]
        eng.submit(Request(uid=100 + i, prompt=prompt,
                           max_new_tokens=hi_new, priority=2))
    eng.run_until_drained()
    done = eng.completed
    hi = [r for r in done if r.uid >= 100]
    bulk = [r for r in done if 0 <= r.uid < 100]
    assert len(hi) == n_hi and len(bulk) == n_bulk, "requests lost"
    hi_ttft = sum(r.metrics.ttft for r in hi) / len(hi)
    bulk_ttft = sum(r.metrics.ttft for r in bulk) / len(bulk)
    return hi_ttft, bulk_ttft, eng


def main_sched(args) -> None:
    """--sched suite: priority-mix TTFT under an overcommitted pool.
    Asserts the acceptance criteria: high-priority TTFT strictly beats
    FIFO, and the pool drains with zero leaked blocks."""
    n_bulk = 4 if args.smoke else 6
    fifo_hi, fifo_bulk, fifo_eng = run_priority_mix("fifo", n_bulk=n_bulk)
    pri_hi, pri_bulk, pri_eng = run_priority_mix("priority", n_bulk=n_bulk)
    m = pri_eng.metrics_summary()
    assert m["preemptions"] > 0, \
        "overcommitted priority mix must exercise preemption"
    assert pri_hi < fifo_hi, (
        f"high-priority TTFT {pri_hi * 1e3:.1f}ms must strictly beat FIFO "
        f"{fifo_hi * 1e3:.1f}ms under an overcommitted pool")
    for eng in (fifo_eng, pri_eng):
        assert eng.alloc.free_blocks == eng.num_blocks - 1, \
            "blocks leaked after drain"
        assert eng.alloc.check_conservation()
    emit("serving_sched/fifo_hi_ttft_s", fifo_hi * 1e6,
         f"interactive TTFT {fifo_hi * 1e3:.1f}ms behind a FIFO backlog")
    emit("serving_sched/priority_hi_ttft_s", pri_hi * 1e6,
         f"interactive TTFT {pri_hi * 1e3:.1f}ms with priority+preemption, "
         f"x{fifo_hi / max(pri_hi, 1e-9):.1f} vs FIFO")
    emit("serving_sched/priority_bulk_ttft_s", pri_bulk * 1e6,
         f"bulk TTFT {pri_bulk * 1e3:.1f}ms (FIFO {fifo_bulk * 1e3:.1f}ms) "
         f"— the cost of yielding")
    emit("serving_sched/preemptions", m["preemptions"],
         f"{m['preemptions']:.0f} preemptions, {m['requeues']:.0f} "
         f"requeues, 0 leaked blocks")


# ---------------------------------------------------------------------- #
# speculative decoding: n-gram drafts vs token-at-a-time on a repetitive
# decode-dominated workload
# ---------------------------------------------------------------------- #

# decode-step-bound geometry: a (1 + k)-wide verify step should cost about
# what a 1-wide decode step costs (as it does at serving scale, where step
# launch and weight streaming dominate); CFG's larger per-token compute on
# a CPU host would instead price the verify step ~1.5x the decode step and
# measure the host, not the mechanism
SPEC_CFG = ModelConfig(name="spec", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                       head_dim=16, remat="none")

_SPEC_PARAMS = None


def get_spec_params():
    global _SPEC_PARAMS
    if _SPEC_PARAMS is None:
        nn.clear_parameters()
        _SPEC_PARAMS = nn.init(lambda t: T.forward(SPEC_CFG, t),
                               jax.random.key(0),
                               jnp.zeros((1, 8), jnp.int32))
    return _SPEC_PARAMS


def spec_prompt(i: int, prompt_len: int) -> list[int]:
    """A short phrase repeated — the n-gram proposer's best case (and the
    regime greedy tiny-model decode locks into constant runs anyway)."""
    phrase = [3 + i, 5, 7, 11 + i]
    return [phrase[j % len(phrase)] for j in range(prompt_len)]


def run_spec(spec_k: int, n_requests: int = 4, new_tokens: int = 64,
             prompt_len: int = 32):
    """Returns (mean decode tok/s, acceptance rate, token streams, engine)
    for one drain of the repetitive workload at the given draft width
    (0 = the token-at-a-time baseline)."""
    eng = ServingEngine(get_model(SPEC_CFG), get_spec_params(), max_batch=4,
                        max_seq=160, chunk=16, prefix_cache=False,
                        spec_k=spec_k)
    # warm every compiled shape this engine will use: (B, chunk) prefill
    # plus (B, 1) decode or (B, 1 + k) verify
    eng.submit(Request(uid=-1, prompt=spec_prompt(9, prompt_len),
                       max_new_tokens=4))
    eng.run_until_drained()
    eng.completed.clear()
    for i in range(n_requests):
        eng.submit(Request(uid=i, prompt=spec_prompt(i, prompt_len),
                           max_new_tokens=new_tokens))
    eng.run_until_drained()
    m = eng.metrics_summary()
    streams = {r.uid: list(r.generated) for r in eng.completed}
    return (m["mean_decode_tok_per_s"], m.get("spec_accept_rate", 0.0),
            streams, eng)


def main_spec(args) -> None:
    """--spec suite: decode throughput with n-gram speculative decoding vs
    the token-at-a-time baseline. Asserts the acceptance criteria: the
    spec streams are bitwise the baseline streams, and decode tok/s at
    least doubles on the repetitive workload (median of 3 drains each, so
    one noisy CI timeslice can't decide the comparison). Both modes fill
    the batch — idle rows would dilute the decode-rate signal — and smoke
    only trims the generation length."""
    n_req = 4
    new_tok = 48 if args.smoke else 64
    base_runs = [run_spec(0, n_requests=n_req, new_tokens=new_tok)
                 for _ in range(3)]
    spec_runs = [run_spec(4, n_requests=n_req, new_tokens=new_tok)
                 for _ in range(3)]
    for _, rate, streams, eng in base_runs + spec_runs:
        assert streams == base_runs[0][2], \
            "token streams must not depend on spec_k or on the drain"
        assert eng.alloc.free_blocks == eng.num_blocks - 1, \
            "blocks leaked after drain"
        assert eng.alloc.check_conservation()
    base_dec = sorted(r[0] for r in base_runs)[1]
    spec_dec = sorted(r[0] for r in spec_runs)[1]
    rate = spec_runs[0][1]
    spec_eng = spec_runs[0][3]
    speedup = spec_dec / max(base_dec, 1e-9)
    assert speedup >= 2.0, (
        f"speculative decode {spec_dec:.1f} tok/s is only x{speedup:.2f} "
        f"the baseline {base_dec:.1f} tok/s (acceptance {rate:.2f}) — "
        f"repetitive workload should at least double decode throughput")
    emit("serving_spec/baseline_decode_tok_per_s",
         1e6 / max(base_dec, 1e-9), f"{base_dec:.1f} tok/s token-at-a-time")
    emit("serving_spec/spec_decode_tok_per_s", 1e6 / max(spec_dec, 1e-9),
         f"{spec_dec:.1f} tok/s with k=4 n-gram drafts, x{speedup:.2f}")
    emit("serving_spec/accept_rate", rate * 1e6,
         f"{rate * 100:.0f}% of draft tokens accepted "
         f"({spec_eng.scheduler.spec_accepted}/"
         f"{spec_eng.scheduler.spec_proposed}), streams bitwise equal")


# ---------------------------------------------------------------------- #
# multi-replica router: prefix-affinity vs random placement on a
# shared-prefix workload (per-replica caches make placement = hit rate)
# ---------------------------------------------------------------------- #

def router_families(n_families: int, prefix_len: int = 64):
    """Prefix families: each family shares a ``prefix_len``-token leading
    block run; members differ only in a short unique tail."""
    fams = []
    for f in range(n_families):
        prefix = [1 + (7 * f + j) % (CFG.vocab_size - 1)
                  for j in range(prefix_len)]
        fams.append(prefix)
    return fams


def run_router(policy: str, n_families: int = 4, waves: int = 3,
               prefix_len: int = 64, new_tokens: int = 8):
    """Drive ``waves`` arrival waves (one request per family per wave,
    drained between waves — a steady shared-prefix stream) through 2
    replicas under the given routing policy. Returns (router, engines,
    streams {uid: tokens}, summary dict)."""
    from repro.serving.router import Router, make_replica_engines
    engines = make_replica_engines(
        get_model(CFG), get_params(), replicas=2, use_meshes=False,
        max_batch=2, max_seq=128, chunk=16)
    # warm both replicas' compiled shapes with a throwaway family, then
    # flush its prefix entries so measurement starts with cold caches
    fams = router_families(n_families, prefix_len)
    warm = [1 + (7 * n_families + j) % (CFG.vocab_size - 1)
            for j in range(prefix_len)]
    for r, eng in enumerate(engines):
        eng.submit(Request(uid=-1 - r, prompt=warm, max_new_tokens=2))
        eng.run_until_drained()
        eng.completed.clear()
        eng.prefix.evict(eng.num_blocks)
    router = Router(engines, policy=policy, seed=7)
    uid = 0
    for w in range(waves):
        for f, prefix in enumerate(fams):
            tail = [11 + (13 * f + 5 * w + j) % 97 for j in range(4)]
            router.submit(Request(uid=uid, prompt=prefix + tail,
                                  max_new_tokens=new_tokens))
            uid += 1
        router.run_until_drained()
    streams = {r.uid: list(r.generated) for r in router.completed}
    return router, engines, streams, router.metrics_summary()


def main_router(args) -> None:
    """--router suite: prefix-affinity routing vs random placement over 2
    replicas. Asserts the acceptance criteria: affinity's replica
    prefix-hit tokens strictly beat random routing, token streams are
    bitwise identical to a single-replica run, and every replica drains
    with zero leaked blocks (all live blocks map-pinned, pool fully free
    after a full prefix flush)."""
    n_fam = 3 if args.smoke else 4
    waves = 3 if args.smoke else 4
    # median of 3 drains for the gated timings (routing/streams/hit stats
    # are deterministic across drains; only wall-clock is noisy)
    aff_runs = [run_router("affinity", n_families=n_fam, waves=waves)
                for _ in range(3)]
    aff_router, aff_eng, aff_streams, aff = aff_runs[0]
    aff = dict(aff)
    for key in ("mean_ttft_s", "mean_decode_tok_per_s"):
        aff[key] = sorted(r[3][key] for r in aff_runs)[1]
    rnd_router, rnd_eng, rnd_streams, rnd = run_router(
        "random", n_families=n_fam, waves=waves)

    # single-replica reference: same requests through one engine
    ref_eng = make_engine(2, 128, 16)
    uid = 0
    for w in range(waves):
        for f, prefix in enumerate(router_families(n_fam)):
            tail = [11 + (13 * f + 5 * w + j) % 97 for j in range(4)]
            ref_eng.submit(Request(uid=uid, prompt=prefix + tail,
                                   max_new_tokens=8))
            uid += 1
        ref_eng.run_until_drained()
    ref_streams = {r.uid: list(r.generated) for r in ref_eng.completed}

    assert aff_streams == ref_streams, \
        "affinity routing changed a token stream vs single-replica"
    assert all(r[2] == aff_streams for r in aff_runs), \
        "token streams must not depend on the drain"
    assert rnd_streams == ref_streams, \
        "random routing changed a token stream vs single-replica"
    aff_hit = aff.get("mean_prefix_hit_tokens", 0.0)
    rnd_hit = rnd.get("mean_prefix_hit_tokens", 0.0)
    assert aff_hit > rnd_hit, (
        f"prefix-affinity routing must strictly beat random placement on "
        f"shared-prefix traffic: {aff_hit:.1f} vs {rnd_hit:.1f} hit "
        f"tokens/request")
    assert aff.get("affinity_hit_rate", 0.0) > 0.0, \
        "no request was routed onto a live cached prefix"
    for eng in (*(e for r in aff_runs for e in r[1]), *rnd_eng):
        assert eng.alloc.check_conservation()
        live = {b for b in range(1, eng.num_blocks)
                if eng.alloc.refcount(b) > 0}
        pinned = eng.prefix.registered_blocks()
        assert live <= pinned, f"leaked blocks: {sorted(live - pinned)}"
        eng.prefix.evict(eng.num_blocks)   # full flush -> all blocks free
        assert eng.alloc.free_blocks == eng.num_blocks - 1, \
            "blocks leaked after drain + prefix flush"

    emit("serving_router/affinity_ttft_s", aff["mean_ttft_s"] * 1e6,
         f"TTFT {aff['mean_ttft_s'] * 1e3:.1f}ms, 2 replicas, "
         f"prefix-affinity routing")
    emit("serving_router/affinity_decode_tok_per_s",
         1e6 / max(aff["mean_decode_tok_per_s"], 1e-9),
         f"{aff['mean_decode_tok_per_s']:.1f} tok/s decode")
    emit("serving_router/affinity_hit_tokens_per_req",
         1e6 / max(aff_hit, 1e-9),
         f"{aff_hit:.1f} prefix-hit tok/req vs {rnd_hit:.1f} random "
         f"(x{aff_hit / max(rnd_hit, 1e-9):.2f})")
    keyed = (aff_router.affinity_hits + aff_router.cold_affinity
             + aff_router.load_fallbacks)
    emit("serving_router/affinity_hit_rate",
         aff["affinity_hit_rate"] * 1e6,
         f"{aff['affinity_hit_rate'] * 100:.0f}% of keyed requests "
         f"routed onto a live cached prefix "
         f"({aff_router.affinity_hits}/{keyed}); random baseline spreads "
         f"{max(rnd_router.routed)}/{min(rnd_router.routed)}")


# ---------------------------------------------------------------------- #
# fault tolerance: kill a replica mid-drain, assert bitwise recovery and
# measure recovery latency + surviving-replica decode throughput
# ---------------------------------------------------------------------- #

def faults_workload(n_requests: int = 6, prompt_len: int = 32,
                    new_tokens: int = 24) -> list[dict]:
    """Mixed greedy/sampled request kwargs. Sampled requests carry
    explicit seeds: the per-``(seed, len(generated))`` decode PRNG makes
    their streams a pure function of the request, so a migrated
    continuation on another replica draws the same tokens."""
    out = []
    for i in range(n_requests):
        prompt = [1 + (5 * i + j) % (CFG.vocab_size - 1)
                  for j in range(prompt_len)]
        kw = dict(uid=i, prompt=prompt, max_new_tokens=new_tokens)
        if i % 2:
            kw.update(temperature=0.8, top_k=40, seed=1000 + i)
        out.append(kw)
    return out


def run_faults_reference(kw_list: list[dict]) -> dict[int, list[int]]:
    """Fault-free reference streams: the same requests through one
    engine (placement never changes tokens — the router suite proves
    that — so one replica is the canonical fault-free run)."""
    eng = make_engine(2, 128, 16)
    eng.submit(Request(uid=-1, prompt=[1] * 32, max_new_tokens=2))
    eng.run_until_drained()
    eng.completed.clear()
    for kw in kw_list:
        eng.submit(Request(**kw))
    done = eng.run_until_drained()
    return {r.uid: list(r.generated) for r in done}


def make_faults_replicas():
    """Two warmed replicas shared by all drills. The first drill's
    migrations still compile the resume-prompt prefill widths on the
    survivor (a resume prompt = original + generated tokens ends on
    chunk widths the plain workload never hits); reusing the engines
    means drills 2+ measure recovery mechanics, not jit compiles, and
    the median discards the cold drill."""
    from repro.serving.router import make_replica_engines
    engines = make_replica_engines(
        get_model(CFG), get_params(), replicas=2, use_meshes=False,
        max_batch=2, max_seq=128, chunk=16)
    for r, eng in enumerate(engines):   # warm both compiled shapes
        eng.submit(Request(uid=-1 - r, prompt=[1] * 32, max_new_tokens=2))
        eng.run_until_drained()
        eng.completed.clear()
        eng.prefix.evict(eng.num_blocks)
    return engines


def run_faults_chaos(kw_list: list[dict], engines, kill_step: int = 5):
    """Submit the workload to 2 replicas, then kill replica 0 at its
    ``kill_step``-th post-warmup step attempt (permanently — probes keep
    failing). Returns (router, streams, migrated uids, per-uid emission
    times)."""
    from repro.serving.faults import Fault, FaultInjector
    from repro.serving.router import Router
    router = Router(engines, seed=7)
    emit_t: dict[int, list[float]] = {}

    def on_tokens(r, toks, done):
        if toks:
            emit_t.setdefault(r.uid, []).append(time.monotonic())

    # everything submitted before the kill: the victim holds both active
    # slots AND queued requests, so migration covers in-flight + queued
    for kw in kw_list:
        req = Request(**kw)
        req.on_tokens = on_tokens
        router.submit(req)
    inj = FaultInjector(engines[0],
                        [Fault(step=kill_step, kind="die", steps=0)])
    inj.install()
    try:
        router.run_until_drained()
    finally:
        inj.uninstall()                 # next drill gets a live replica 0
    streams = {r.uid: list(r.generated) for r in router.completed}
    migrated = {r.uid for r in router.completed if r.migrated}
    return router, streams, migrated, emit_t


def main_faults(args) -> None:
    """--faults suite: the PR-8 chaos drill. Kills 1 of 2 replicas
    mid-drain via the deterministic injector and asserts the acceptance
    criteria: every request completes with streams bitwise equal to the
    fault-free run (greedy and sampled), zero leaked blocks on the
    survivor, and recovery latency (death -> first migrated-token
    emission) is reported and gated."""
    n_req = 4 if args.smoke else 6
    new_tok = 16 if args.smoke else 24
    kill_step = 4 if args.smoke else 5
    kw_list = faults_workload(n_req, new_tokens=new_tok)
    ref = run_faults_reference(kw_list)
    # median of 3 drills over SHARED engines for the gated wall-clock
    # metrics: drill 1 pays the resume-shape compiles, the median keeps
    # the warm drills (the structural assertions must hold on every one)
    engines = make_faults_replicas()
    recoveries, decs, n_migrated = [], [], 0
    for _ in range(3):
        router, streams, migrated, emit_t = run_faults_chaos(
            kw_list, engines, kill_step=kill_step)
        assert router.replica_deaths == 1, "the scripted kill must fire"
        assert router.migration_failures == 0, \
            "no request may fail to move"
        assert migrated, "the kill must catch requests on the victim"
        assert len(streams) == len(ref), (
            f"lost requests: {sorted(set(ref) - set(streams))}")
        for uid, toks in sorted(ref.items()):
            assert streams[uid] == toks, (
                f"request {uid}{' (migrated)' if uid in migrated else ''} "
                f"diverged from the fault-free stream")
        decs.append(engines[1].metrics_summary()["mean_decode_tok_per_s"])
        # zero leaked blocks: the victim's actives were freed by harvest,
        # the survivor drained normally; after a full prefix flush every
        # non-garbage block must be free on both
        for eng in engines:
            assert eng.alloc.check_conservation()
            live = {b for b in range(1, eng.num_blocks)
                    if eng.alloc.refcount(b) > 0}
            pinned = eng.prefix.registered_blocks()
            assert live <= pinned, \
                f"leaked blocks: {sorted(live - pinned)}"
            eng.prefix.evict(eng.num_blocks)
            assert eng.alloc.free_blocks == eng.num_blocks - 1, \
                "blocks leaked after drain + prefix flush"
            eng.completed.clear()       # drills reuse uids
        death = router.last_death_t
        post = [t for uid in migrated for t in emit_t.get(uid, [])
                if t >= death]
        assert post, "migrated requests must emit tokens after the death"
        recoveries.append(min(post) - death)
        n_migrated = len(migrated)
    recovery = sorted(recoveries)[1]
    dec = sorted(decs)[1]
    emit("serving_faults/recovery_latency_s", recovery * 1e6,
         f"{recovery * 1e3:.1f}ms from replica death to the first "
         f"migrated-token emission ({n_migrated} requests moved)")
    emit("serving_faults/migrated_requests", float(n_migrated),
         f"{n_migrated}/{n_req} requests migrated off the victim, "
         f"0 failures, streams bitwise equal")
    emit("serving_faults/post_fault_decode_tok_per_s",
         1e6 / max(dec, 1e-9),
         f"{dec:.1f} tok/s decode on the survivor "
         f"(absorbed the migrated backlog)")


# ---------------------------------------------------------------------- #
# tensor-parallel serving: TTFT / decode rate / per-device cache bytes
# ---------------------------------------------------------------------- #

# wider head geometry than CFG so tp=4 still splits the kv-head axis
TP_CFG = ModelConfig(name="tp", family="dense", n_layers=4, d_model=128,
                     n_heads=8, n_kv_heads=4, d_ff=256, vocab_size=512,
                     head_dim=16, remat="none")

_TP_PARAMS = None


def get_tp_params():
    global _TP_PARAMS
    if _TP_PARAMS is None:
        nn.clear_parameters()
        _TP_PARAMS = nn.init(lambda t: T.forward(TP_CFG, t),
                             jax.random.key(0), jnp.zeros((1, 8), jnp.int32))
    return _TP_PARAMS


def run_tp(tp: int, n_requests: int = 8, prompt_len: int = 48,
           new_tokens: int = 16) -> tuple[float, float, int]:
    """Returns (mean TTFT s, mean decode tok/s, cache bytes on device 0)
    for one engine spanning ``tp`` host devices."""
    from repro.launch.serve_shardings import per_device_state_bytes
    eng = ServingEngine(get_model(TP_CFG), get_tp_params(), max_batch=4,
                        max_seq=128, chunk=16, tp=tp)
    # warm both compiled shapes before timing
    eng.submit(Request(uid=-1, prompt=[1] * prompt_len, max_new_tokens=2))
    eng.run_until_drained()
    eng.completed.clear()
    for i in range(n_requests):
        prompt = [1 + (i + j) % (TP_CFG.vocab_size - 1)
                  for j in range(prompt_len)]
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=new_tokens))
    eng.run_until_drained()
    m = eng.metrics_summary()
    return (m["mean_ttft_s"], m["mean_decode_tok_per_s"],
            per_device_state_bytes(eng.state))


def main_tp(args) -> None:
    """--tp suite: one engine at tp=1/2/4 on forced host devices. Run with
    XLA_FLAGS=--xla_force_host_platform_device_count=8 (the bench-smoke CI
    job does); widths beyond the device count are skipped with a note."""
    n_req = 4 if args.smoke else 8
    new_tok = 8 if args.smoke else 16
    n_dev = jax.device_count()
    for tp in (1, 2, 4):
        if tp > n_dev:
            print(f"serving_tp/tp{tp}: skipped ({n_dev} devices)",
                  flush=True)
            continue
        # median of 3 drains per width: single-shot TTFT on forced host
        # devices is noisy enough to trip the CI bench-compare gate
        runs = [run_tp(tp, n_requests=n_req, new_tokens=new_tok)
                for _ in range(3)]
        ttft = sorted(r[0] for r in runs)[1]
        dec = sorted(r[1] for r in runs)[1]
        dev_bytes = runs[0][2]
        emit(f"serving_tp/tp{tp}_ttft_s", ttft * 1e6,
             f"TTFT {ttft * 1e3:.1f}ms at tp={tp}")
        emit(f"serving_tp/tp{tp}_decode_tok_per_s", 1e6 / max(dec, 1e-9),
             f"{dec:.1f} tok/s decode at tp={tp}")
        emit(f"serving_tp/tp{tp}_cache_bytes_per_device", float(dev_bytes),
             f"{dev_bytes / 2**20:.2f} MiB KV on device 0 "
             f"(1/{tp} of the pool)")


# ---------------------------------------------------------------------- #
# tiered KV cache: host-RAM spill tier vs drop-and-reprefill on an
# undersized HBM pool, plus persistent-prefix warm restart
# ---------------------------------------------------------------------- #

def run_tiered(host_blocks: int, kv_store: str | None = None,
               n_families: int = 4, waves: int = 2, prefix_len: int = 112,
               new_tokens: int = 8):
    """One engine on a deliberately undersized HBM pool (17 usable blocks
    vs 28 registered prefix blocks of steady demand), driven one request
    at a time so registration pressure evicts older families between
    arrivals. Wave 0 is cold; wave 1+ revisits prefixes the pressure has
    pushed out of HBM — with a host tier (``host_blocks > 0``) they fetch
    back (~1 remaining prefill chunk), without one they drop and
    re-prefill all 15 chunks from scratch.

    Returns (mean revisit-wave TTFT, streams {uid: tokens}, engine)."""
    eng = make_engine(2, 128, 8, block_size=16, num_blocks=18,
                      host_cache_blocks=host_blocks or None,
                      kv_store=kv_store)
    fams = router_families(n_families, prefix_len)
    # warm every compiled shape with a throwaway family: prefill + decode,
    # and (tiered only) the spill-extract and fetch-insert device ops
    warm = [1 + (7 * n_families + j) % (CFG.vocab_size - 1)
            for j in range(prefix_len)] + [11, 12, 13, 14]
    eng.submit(Request(uid=-1, prompt=warm, max_new_tokens=2))
    eng.run_until_drained()
    if host_blocks:
        eng.prefix.evict(eng.num_blocks)           # spill the warm chain
        eng.submit(Request(uid=-2, prompt=warm, max_new_tokens=2))
        eng.run_until_drained()                    # fetch it back (insert)
    eng.prefix.evict(eng.num_blocks)
    if host_blocks and not kv_store:
        eng.prefix.host.flush()                    # measurement starts cold
    eng.completed.clear()

    for w in range(waves):
        for f, prefix in enumerate(fams):
            tail = [11 + (13 * f + 5 * w + j) % 97 for j in range(4)]
            eng.submit(Request(uid=100 * w + f, prompt=prefix + tail,
                               max_new_tokens=new_tokens))
            eng.run_until_drained()
    streams = {r.uid: list(r.generated) for r in eng.completed}
    # revisit-wave TTFT; with waves=1 (warm-restart probe) the first
    # wave IS the measurement
    revisit = [r.metrics.ttft for r in eng.completed if r.uid >= 100] \
        or [r.metrics.ttft for r in eng.completed]
    return sum(revisit) / len(revisit), streams, eng


def main_tiered(args) -> None:
    """--tiered suite: host-RAM spill tier vs drop-and-reprefill on an
    undersized HBM pool. Asserts the acceptance criteria: revisit-wave
    TTFT with the host tier is >= 2x better than dropping, token streams
    are bitwise identical to the untiered path, both tiers drain to zero
    leaked blocks, and a warm-restarted engine gets prefix hits on its
    first wave from the persisted store."""
    import os
    import tempfile

    # median of 3 full runs for the gated timings: the first run pays
    # one-off XLA compiles for the extract/insert index shapes that the
    # warm-up family doesn't cover (streams/hit stats are deterministic)
    tiered_runs = [run_tiered(host_blocks=64) for _ in range(3)]
    drop_runs = [run_tiered(host_blocks=0) for _ in range(3)]
    ttft_host = sorted(r[0] for r in tiered_runs)[1]
    ttft_drop = sorted(r[0] for r in drop_runs)[1]
    streams, eng = tiered_runs[0][1], tiered_runs[0][2]

    assert streams == drop_runs[0][1], \
        "host tier changed a token stream vs the untiered path"
    assert all(r[1] == streams for r in tiered_runs), \
        "token streams must not depend on the drain"
    m = eng.metrics_summary()
    host_tok = m.get("mean_host_hit_tokens", 0.0)
    assert host_tok > 0, "no revisit was served from the host tier"
    drop_m = drop_runs[0][2].metrics_summary()
    assert drop_m.get("mean_prefix_hit_tokens", 0.0) == 0.0, \
        "baseline kept HBM hits — pool not undersized, bench is vacuous"
    assert ttft_drop >= 2.0 * ttft_host, (
        f"host-tier revisits must be >= 2x better than drop-and-reprefill:"
        f" {ttft_host * 1e3:.1f}ms vs {ttft_drop * 1e3:.1f}ms")

    # zero leaks in BOTH tiers: drain the map and flush the host pool
    for _, _, e in (*tiered_runs, *drop_runs):
        assert e.alloc.check_conservation()
        e.prefix.evict(e.num_blocks)
        if hasattr(e.prefix, "host"):
            e.prefix.host.flush()
            assert len(e.prefix.host) == 0
        assert e.alloc.free_blocks == e.num_blocks - 1, \
            "blocks leaked after drain + prefix flush"

    # warm restart: persist the prefix store, then a fresh engine on the
    # same store must land prefix hits on its very first wave. Median of
    # 3 save/restart cycles: the first restart pays the store-load and
    # snapshot-extract compile blips
    restart_ttfts = []
    with tempfile.TemporaryDirectory() as tmp:
        store = os.path.join(tmp, "kv")
        _, warm_streams, warm_eng = run_tiered(host_blocks=64,
                                               kv_store=store)
        n_saved = warm_eng.save_kv_store()
        assert n_saved > 0, "nothing persisted to the kv store"
        for _ in range(3):
            t, restart_streams, restart_eng = run_tiered(
                host_blocks=64, kv_store=store, waves=1)
            restart_ttfts.append(t)
        first = {u: t for u, t in restart_streams.items() if u < 100}
        assert first == {u: t for u, t in warm_streams.items() if u < 100}, \
            "warm restart changed a first-wave token stream"
        rm = restart_eng.metrics_summary()
        warm_tok = rm.get("mean_prefix_hit_tokens", 0.0)
        assert warm_tok > 0, \
            "warm-restarted engine got no prefix hits on its first wave"
    ttft_warm = sorted(restart_ttfts)[1]

    spilled = eng.scheduler.stats().get("tier_spilled_blocks", 0)
    fetched = eng.scheduler.stats().get("tier_fetched_blocks", 0)
    emit("serving_tiered/revisit_ttft_host_tier_s", ttft_host * 1e6,
         f"TTFT {ttft_host * 1e3:.1f}ms revisiting spilled prefixes "
         f"({spilled} blk spilled, {fetched} fetched back)")
    emit("serving_tiered/revisit_ttft_drop_reprefill_s", ttft_drop * 1e6,
         f"TTFT {ttft_drop * 1e3:.1f}ms drop-and-reprefill baseline, "
         f"host tier x{ttft_drop / max(ttft_host, 1e-9):.2f} better")
    emit("serving_tiered/host_hit_tokens_per_req", 1e6 / max(host_tok, 1e-9),
         f"{host_tok:.1f} tok/req served from the host tier")
    # ungated (no "ttft" in the name): at ~15ms absolute the first-wave
    # latency is drain-overhead noise; the functional guarantee (hits > 0,
    # bitwise streams) is asserted above and fails the job directly
    emit("serving_tiered/warm_restart_first_wave_s", ttft_warm * 1e6,
         f"{ttft_warm * 1e3:.1f}ms to first token after restart, "
         f"{warm_tok:.0f} tok/req from the persisted store "
         f"({n_saved} prefix blocks on disk)")


# ---------------------------------------------------------------------- #
# quantized KV pools: bytes/token, equal-byte cache capacity, decode-rate
# parity vs bf16 pools
# ---------------------------------------------------------------------- #

def run_decode_rate(n_requests: int, new_tokens: int, **kw):
    """Decode-dominated drain (short prompts, long generations): returns
    (mean decode tok/s, streams {uid: tokens}, engine)."""
    eng = make_engine(4, 128, 16, prefix_cache=False, **kw)
    eng.submit(Request(uid=-1, prompt=[1] * 16, max_new_tokens=2))
    eng.run_until_drained()
    eng.completed.clear()
    for i in range(n_requests):
        prompt = [1 + (5 * i + j) % (CFG.vocab_size - 1) for j in range(16)]
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=new_tokens))
    eng.run_until_drained()
    m = eng.metrics_summary()
    return (m["mean_decode_tok_per_s"],
            {r.uid: list(r.generated) for r in eng.completed}, eng)


def prefix_tokens_before_first_eviction(num_blocks: int, prompt_len: int = 48,
                                        **kw) -> tuple[int, ServingEngine]:
    """Feed unique-prefix requests one at a time until registration
    pressure first evicts a cached prefix block, and return the prefix
    tokens the pool held at that moment. Each prompt is unique from its
    first token, so every request pins ``prompt_len // block_size`` fresh
    blocks — the map grows by exactly that until the pool is full and the
    scheduler starts evicting to admit."""
    eng = make_engine(2, 128, 16, num_blocks=num_blocks, **kw)
    per_req = prompt_len // eng.block_size
    for i in range(4 * num_blocks):
        before = len(eng.prefix)
        prompt = [1 + (17 * i + j) % (CFG.vocab_size - 1)
                  for j in range(prompt_len)]
        prompt[0] = 1 + i % (CFG.vocab_size - 1)   # unique chain from tok 0
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=4))
        eng.run_until_drained()
        if len(eng.prefix) - before < per_req:     # an eviction happened
            return before * eng.block_size, eng
    raise AssertionError(
        f"pool of {num_blocks} blocks never hit eviction pressure — "
        f"the undersized-pool bench is vacuous")


def main_quant(args) -> None:
    """--quant suite: int8 KV pools vs the bf16 baseline. Asserts the
    acceptance criteria: kv_bytes_per_token at int8 (pool + scales) is
    <= 0.62x bf16 at this head_dim-32 geometry (the 0.55x bound is the
    head_dim-64 statement — bench_kernels asserts that one), an
    equal-byte pool caches >= 1.5x more prefix tokens before its first
    eviction, decode tok/s stays within 10% of bf16, and the greedy
    divergence rate is bounded and reported."""
    n_req = 4
    new_tok = 32 if args.smoke else 48

    # bytes/token, first-class from the engine's own spec accounting
    probe_bf = make_engine(2, 64, 16, cache_dtype=jnp.bfloat16)
    probe_i8 = make_engine(2, 64, 16, cache_dtype=jnp.bfloat16,
                           kv_dtype="int8")
    bpt_bf = probe_bf.kv_bytes_per_token()
    bpt_i8 = probe_i8.kv_bytes_per_token()
    ratio = bpt_i8 / bpt_bf
    # CFG has head_dim 32: int8 + f32 scales = (D + 4) / (2D) = 0.5625
    assert ratio <= 0.62, (
        f"int8 pools cost {ratio:.3f}x the bf16 bytes/token — scales "
        f"outgrew the payload savings")

    # equal pool BYTES: the int8 engine gets the block count the same
    # byte budget buys, then must cache >= 1.5x the prefix tokens before
    # eviction pressure first drops a block
    n_bf = 18
    n_i8 = int(n_bf * bpt_bf / bpt_i8)
    assert n_i8 * bpt_i8 <= n_bf * bpt_bf + 1e-6
    tok_bf, e_bf = prefix_tokens_before_first_eviction(
        n_bf, cache_dtype=jnp.bfloat16)
    tok_i8, e_i8 = prefix_tokens_before_first_eviction(
        n_i8, cache_dtype=jnp.bfloat16, kv_dtype="int8")
    cap_x = tok_i8 / max(tok_bf, 1)
    assert cap_x >= 1.5, (
        f"equal-byte int8 pool cached only x{cap_x:.2f} the prefix tokens "
        f"before first eviction ({tok_i8} vs {tok_bf}) — expected >= 1.5x")
    for e in (e_bf, e_i8):
        assert e.alloc.check_conservation()

    # decode-rate parity + greedy stability, median of 3 drains each
    bf_runs = [run_decode_rate(n_req, new_tok, cache_dtype=jnp.bfloat16)
               for _ in range(3)]
    i8_runs = [run_decode_rate(n_req, new_tok, cache_dtype=jnp.bfloat16,
                               kv_dtype="int8") for _ in range(3)]
    assert all(r[1] == i8_runs[0][1] for r in i8_runs), \
        "int8 streams must not depend on the drain"
    dec_bf = sorted(r[0] for r in bf_runs)[1]
    dec_i8 = sorted(r[0] for r in i8_runs)[1]
    speed_x = dec_i8 / max(dec_bf, 1e-9)
    assert speed_x >= 0.90, (
        f"int8 decode {dec_i8:.1f} tok/s is only x{speed_x:.2f} the bf16 "
        f"{dec_bf:.1f} tok/s — dequant overhead exceeds the 10% budget")
    streams_bf, streams_i8 = bf_runs[0][1], i8_runs[0][1]
    div = sum(streams_bf[u] != streams_i8[u]
              for u in streams_bf) / len(streams_bf)
    # greedy stability on a random-weight micro-model: logits are nearly
    # flat, so one early argmax flip cascades and whole-stream equality
    # is a coin toss. The stable, meaningful statistic is how FAR streams
    # agree before first divergence (matched-prefix fraction) — assert a
    # floor on that and report the raw divergence rate alongside
    matched = total = 0
    for u in streams_bf:
        a, b = streams_bf[u], streams_i8[u]
        matched += next((i for i, (x, y) in enumerate(zip(a, b))
                         if x != y), len(a))
        total += len(a)
    stable = matched / max(total, 1)
    assert stable >= 0.25, (
        f"int8 streams match bf16 for only {stable:.0%} of greedy tokens "
        f"before first divergence — quantization noise dominates")

    emit("serving_quant/kv_bytes_per_token_bf16", bpt_bf,
         f"{bpt_bf:.0f} B/tok, bf16 pools")
    emit("serving_quant/kv_bytes_per_token_int8", bpt_i8,
         f"{bpt_i8:.0f} B/tok incl. f32 scales, x{ratio:.3f} of bf16")
    emit("serving_quant/bf16_decode_tok_per_s", 1e6 / max(dec_bf, 1e-9),
         f"{dec_bf:.1f} tok/s decode, bf16 pools")
    emit("serving_quant/int8_decode_tok_per_s", 1e6 / max(dec_i8, 1e-9),
         f"{dec_i8:.1f} tok/s decode, int8 pools, x{speed_x:.2f} vs bf16")
    emit("serving_quant/equal_bytes_prefix_tokens_int8",
         1e6 / max(tok_i8, 1),
         f"{tok_i8} prefix tok cached before first eviction vs {tok_bf} "
         f"bf16 at equal pool bytes (x{cap_x:.2f}, "
         f"{n_i8 - 1} vs {n_bf - 1} usable blocks)")
    emit("serving_quant/greedy_divergence_rate", div * 1e6,
         f"{div:.0%} of greedy streams diverge from bf16 pools "
         f"({sum(streams_bf[u] != streams_i8[u] for u in streams_bf)}"
         f"/{len(streams_bf)}); streams agree for {stable:.0%} of "
         f"tokens before first divergence")


def main(argv=()) -> None:
    # default () so run.py's programmatic call ignores ITS own sys.argv
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", help="write results JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: fewer requests, same code paths")
    ap.add_argument("--tp", action="store_true",
                    help="run the tensor-parallel suite instead (needs "
                         "forced host devices; see main_tp docstring)")
    ap.add_argument("--sched", action="store_true",
                    help="run the scheduler priority/preemption suite "
                         "instead (asserts priority TTFT beats FIFO)")
    ap.add_argument("--spec", action="store_true",
                    help="run the speculative-decoding suite instead "
                         "(asserts bitwise-equal streams and >= 2x decode "
                         "tok/s on a repetitive workload)")
    ap.add_argument("--router", action="store_true",
                    help="run the multi-replica router suite instead "
                         "(asserts prefix-affinity beats random placement "
                         "and streams match a single-replica run)")
    ap.add_argument("--faults", action="store_true",
                    help="run the fault-tolerance chaos drill instead "
                         "(kills 1 of 2 replicas mid-drain; asserts "
                         "bitwise recovery and zero leaked blocks)")
    ap.add_argument("--tiered", action="store_true",
                    help="run the tiered KV cache suite instead (asserts "
                         "host-tier revisits beat drop-and-reprefill >= "
                         "2x on TTFT, bitwise streams, zero leaks in "
                         "both tiers, warm-restart first-wave hits)")
    ap.add_argument("--quant", action="store_true",
                    help="run the quantized KV pool suite instead "
                         "(asserts int8 bytes/token vs bf16, >= 1.5x "
                         "prefix tokens cached at equal pool bytes, "
                         "decode tok/s within 10% of bf16, bounded "
                         "greedy divergence)")
    args = ap.parse_args(list(argv))
    if args.quant:
        main_quant(args)
        if args.json:
            write_json(args.json)
        return
    if args.tiered:
        main_tiered(args)
        if args.json:
            write_json(args.json)
        return
    if args.faults:
        main_faults(args)
        if args.json:
            write_json(args.json)
        return
    if args.router:
        main_router(args)
        if args.json:
            write_json(args.json)
        return
    if args.tp:
        main_tp(args)
        if args.json:
            write_json(args.json)
        return
    if args.sched:
        main_sched(args)
        if args.json:
            write_json(args.json)
        return
    if args.spec:
        main_spec(args)
        if args.json:
            write_json(args.json)
        return
    n_req = 4 if args.smoke else 8
    new_tok = 8 if args.smoke else 16

    seq = run(max_batch=1, n_requests=n_req, new_tokens=new_tok)
    cb = run(max_batch=4, n_requests=n_req, new_tokens=new_tok)
    emit("serving/sequential_tok_per_s", 1e6 / max(seq, 1e-9), f"{seq:.1f} tok/s")
    emit("serving/continuous_batch4_tok_per_s", 1e6 / max(cb, 1e-9),
         f"{cb:.1f} tok/s, x{cb / seq:.2f}")

    # chunked prefill vs token-by-token absorption, 64-token prompts
    t_tok, ttft_tok = run_prefill(chunk=1, n_requests=n_req // 2)
    t_chk, ttft_chk = run_prefill(chunk=16, n_requests=n_req // 2)
    emit("serving/prefill_tokbytok_s", t_tok * 1e6,
         f"{t_tok:.2f}s drain, TTFT {ttft_tok * 1e3:.0f}ms")
    emit("serving/prefill_chunk16_s", t_chk * 1e6,
         f"{t_chk:.2f}s drain, TTFT {ttft_chk * 1e3:.0f}ms, "
         f"x{t_tok / max(t_chk, 1e-9):.2f} faster")

    # shared-prefix workload: n requests, 64-token common prefix.
    # dense = the PR-1 baseline layout; paged+prefix skips the prefix
    ttft_dense, _ = run_shared_prefix(n_req, paged=False, prefix_cache=False)
    ttft_paged, _ = run_shared_prefix(n_req, paged=True, prefix_cache=False)
    ttft_hit, hit_tok = run_shared_prefix(n_req, paged=True,
                                          prefix_cache=True)
    emit("serving/shared_prefix_ttft_dense_s", ttft_dense * 1e6,
         f"TTFT {ttft_dense * 1e3:.0f}ms (PR-1 dense baseline)")
    emit("serving/shared_prefix_ttft_paged_s", ttft_paged * 1e6,
         f"TTFT {ttft_paged * 1e3:.0f}ms (paged, no prefix cache)")
    emit("serving/shared_prefix_ttft_prefix_hit_s", ttft_hit * 1e6,
         f"TTFT {ttft_hit * 1e3:.0f}ms, {hit_tok:.0f} tok/req reused, "
         f"x{ttft_dense / max(ttft_hit, 1e-9):.2f} vs dense")

    # capacity: cache bytes needed to hold max_batch in-flight requests of
    # ~24 live tokens each — dense pays max_seq per slot, paged pays blocks
    api = get_model(CFG)
    dense_mb = state_mbytes(api.decode_state_init(4, 128 + 16, jnp.float32))
    blocks = 4 * 2 + 1  # 4 slots x ceil(24/16) blocks + garbage block
    paged_mb = state_mbytes(api.paged_state_init(4, blocks, 16, jnp.float32))
    emit("serving/cache_mem_dense_mb", dense_mb * 1e6, f"{dense_mb:.2f} MiB")
    emit("serving/cache_mem_paged_mb", paged_mb * 1e6,
         f"{paged_mb:.2f} MiB for the same live tokens, "
         f"x{dense_mb / max(paged_mb, 1e-9):.1f} smaller")

    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
