# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness — paper-table parity:

  table1       mixed-precision training time + speedup (paper Table 1, §3.3)
  table2_3     model-zoo training step times (paper Tables 2-3 adapted to the
               10 assigned architectures)
  graph        static vs dynamic computation graphs (paper §2.2, Figure 1)
  collectives  distributed all-reduce (+compressed) scaling (paper §2.3)
  nnp          serialization round-trip (paper §3)
  kernels      attention / SSD kernel-layer microbenches
  serving      continuous-batching throughput

The TPU-scale performance story (roofline terms per arch x shape x mesh) is
produced by ``repro.launch.dryrun`` + ``repro.launch.report`` and recorded in
EXPERIMENTS.md; this harness measures the *framework* on the host, as the
paper's own tables measure wall-clock behaviour of the implementation.
"""

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (bench_collectives, bench_fileformat,
                            bench_graph_modes, bench_kernels,
                            bench_mixed_precision, bench_model_zoo,
                            bench_serving)
    suites = [
        ("table1", bench_mixed_precision.main),
        ("table2_3", bench_model_zoo.main),
        ("graph", bench_graph_modes.main),
        ("collectives", bench_collectives.main),
        ("nnp", bench_fileformat.main),
        ("kernels", bench_kernels.main),
        ("serving", bench_serving.main),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites:
        if only and name != only:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # keep the harness running
            failed += 1
            print(f"{name}/SUITE_FAILED,0,{type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
