
"""Paper §3: NNP serialization round-trip cost (trace/save/load/execute)."""

import os
import tempfile
import time

import numpy as np

import repro.core as nn
from repro.fileformat import NnpExecutor, export_model, load_nnp
from repro.models.cnn import lenet
from benchmarks.common import emit, time_fn


def main() -> None:
    nn.clear_parameters()
    x = np.random.default_rng(0).standard_normal((4, 1, 28, 28)) \
        .astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m.nnp")
        t0 = time.perf_counter()
        export_model("lenet", lambda x: lenet(x), {"x": x}, path)
        emit("nnp/trace_and_save", (time.perf_counter() - t0) * 1e6,
             f"{os.path.getsize(path) // 1024}KiB")
        t0 = time.perf_counter()
        mf, params = load_nnp(path)
        ex = NnpExecutor(mf.network("lenet"), params)
        out = ex(x=x)
        emit("nnp/load_and_first_exec", (time.perf_counter() - t0) * 1e6)
        us = time_fn(lambda: ex(x=x), iters=5)
        emit("nnp/exec_steady_state", us)


if __name__ == "__main__":
    main()
