
"""Paper §2.3 / Listing 3: distributed all-reduce scaling (8 host devices).

Measures the communicator's grad all-reduce (plain / bf16 / int8-compressed)
in a subprocess with 8 forced host devices — the benchmarked analogue of the
paper's multi-GPU data-parallel setup.
"""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")

CODE = """
import time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.comm import Communicator, compressed_all_reduce

mesh = jax.make_mesh((8,), ("data",))
comm = Communicator(mesh, axis="data")

for size_mb in (1, 16):
    n = size_mb * 2**20 // 4
    x = jnp.ones((8, n), jnp.float32)
    for method in (None, "bf16", "int8"):
        if method is None:
            body = lambda v: comm.all_reduce(v, mean=True)
        else:
            body = lambda v, m=method: compressed_all_reduce(v, "data",
                                                             method=m)
        f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                              out_specs=P("data"), check_rep=False))
        out = f(x); jax.block_until_ready(out)
        ts = []
        for _ in range(5):
            t0 = time.perf_counter(); jax.block_until_ready(f(x))
            ts.append(time.perf_counter() - t0)
        us = sorted(ts)[2] * 1e6
        name = method or "fp32"
        print(f"collectives/allreduce_{size_mb}MB_{name},{us:.1f},"
              f"{size_mb / (us / 1e6) / 1024:.2f}GBps", flush=True)
"""


def main() -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    proc = subprocess.run([sys.executable, "-c", CODE], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode:
        print(f"collectives/FAILED,0,{proc.stderr[-200:]}", flush=True)
    else:
        print(proc.stdout, end="", flush=True)


if __name__ == "__main__":
    main()
