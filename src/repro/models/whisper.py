"""Whisper-medium backbone (arXiv:2212.04356): encoder-decoder transformer.

Per the assignment the conv/mel frontend is a STUB — ``input_specs()``
provides precomputed frame embeddings (B, n_frames, d_model). The backbone is
faithful: sinusoidal positions + non-causal self-attention encoder; decoder
with causal self-attention, cross-attention against the encoder output,
learned positions, LayerNorm + GELU.

Serving: cross-attention K/V are computed once per request
(:func:`init_decode_state`) and reused every decode step.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

import repro.core as nn
from repro.core import functions as F
from repro.core import initializer as I
from repro.core import parametric as PF
from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import transformer as T


def _sinusoid(S: int, d: int) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def encode(cfg: ModelConfig, frames):
    """frames: (B, F, d_model) stub embeddings -> (B, F, d_model)."""
    B, S, d = frames.shape
    x = frames + _sinusoid(S, d).astype(frames.dtype)[None]
    x = constrain(x, "batch", "frames", "embed")
    dummy = jnp.zeros((B, S), jnp.int32)
    cos, sin = T.rope_tables(cfg, dummy)  # unused (use_rope=False) but shaped

    def block(h, idx):
        a, _ = T.attention(cfg, T.norm(cfg, h, "ln_attn"), cos, sin,
                           causal=False, use_rope=False)
        h = h + a
        return h + T.mlp(cfg, T.norm(cfg, h, "ln_mlp"))

    x = nn.layer_stack("enc_layers", cfg.n_encoder_layers, block, x,
                       remat=cfg.remat, unroll=cfg.scan_unroll)
    return T.norm(cfg, x, "ln_enc_final")


def _decoder_positions_embed(cfg: ModelConfig, S: int, offset=0):
    table = nn.get_parameter_or_create(
        "dec_pos/W", (cfg.max_position, cfg.d_model), I.normal(0.01))
    idx = jnp.arange(S, dtype=jnp.int32) + offset
    return jnp.take(table, idx, axis=0)


def _decoder_block(cfg: ModelConfig, x, enc_out, cos, sin, *,
                   self_cache=None, cache_pos=None, cross_kv=None):
    h = T.norm(cfg, x, "ln_self")
    a, new_self = T.attention(cfg, h, cos, sin, name="self",
                              cache=self_cache, cache_pos=cache_pos,
                              use_rope=False)
    x = x + a
    h = T.norm(cfg, x, "ln_cross")
    if cross_kv is None:
        Kh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        Bsz, Fl, _ = enc_out.shape
        k = PF.dense(enc_out, Kh * hd, name="cross_k").reshape(Bsz, Fl, Kh, hd)
        v = PF.dense(enc_out, Kh * hd, name="cross_v").reshape(Bsz, Fl, Kh, hd)
        cross_kv = (k, v)
    c, _ = T.attention(cfg, h, cos, sin, name="cross", cross_kv=cross_kv,
                       causal=False, use_rope=False)
    x = x + c
    h = T.norm(cfg, x, "ln_mlp")
    return x + T.mlp(cfg, h), new_self, cross_kv


def forward(cfg: ModelConfig, tokens, frames=None, positions=None,
            last_only: bool = False):
    """Training/prefill: tokens (B, S) decoder inputs, frames stub."""
    B, S = tokens.shape
    if frames is None:
        frames = jnp.zeros((B, cfg.n_audio_frames, cfg.d_model),
                           T.embed_tokens(cfg, tokens).dtype)
    enc_out = encode(cfg, frames)

    x = T.embed_tokens(cfg, tokens)
    x = x + _decoder_positions_embed(cfg, S).astype(x.dtype)[None]
    dummy = jnp.zeros((B, S), jnp.int32)
    cos, sin = T.rope_tables(cfg, dummy)

    def block(h, idx):
        h, _, _ = _decoder_block(cfg, h, enc_out, cos, sin)
        return h

    x = nn.layer_stack("dec_layers", cfg.n_layers, block, x, remat=cfg.remat,
                       unroll=cfg.scan_unroll)
    if last_only:
        x = x[:, -1:]
    x = T.norm(cfg, x, "ln_final")
    return T.lm_head(cfg, x), jnp.zeros((), jnp.float32)


def forward_hidden(cfg: ModelConfig, tokens, frames=None):
    B, S = tokens.shape
    if frames is None:
        frames = jnp.zeros((B, cfg.n_audio_frames, cfg.d_model),
                           T.embed_tokens(cfg, tokens).dtype)
    enc_out = encode(cfg, frames)
    x = T.embed_tokens(cfg, tokens)
    x = x + _decoder_positions_embed(cfg, S).astype(x.dtype)[None]
    dummy = jnp.zeros((B, S), jnp.int32)
    cos, sin = T.rope_tables(cfg, dummy)

    def block(h, idx):
        h, _, _ = _decoder_block(cfg, h, enc_out, cos, sin)
        return h

    x = nn.layer_stack("dec_layers", cfg.n_layers, block, x, remat=cfg.remat,
                       unroll=cfg.scan_unroll)
    return T.norm(cfg, x, "ln_final")


def loss_fn(cfg: ModelConfig, tokens, labels, frames=None, positions=None):
    if cfg.loss_chunk:
        x = forward_hidden(cfg, tokens, frames)
        return T.ce_from_hidden_chunked(cfg, x, labels, cfg.loss_chunk)
    logits, _ = forward(cfg, tokens, frames)
    return jnp.mean(F.softmax_cross_entropy(logits, labels))


# --------------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------------- #

def init_decode_state(cfg: ModelConfig, frames, max_seq: int,
                      dtype=jnp.bfloat16) -> dict[str, Any]:
    """Run the encoder + per-layer cross-K/V projections once."""
    B = frames.shape[0]
    enc_out = encode(cfg, frames)
    Kh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    Fl = enc_out.shape[1]

    def block(carry, idx):
        k = PF.dense(enc_out, Kh * hd, name="cross_k").reshape(B, Fl, Kh, hd)
        v = PF.dense(enc_out, Kh * hd, name="cross_v").reshape(B, Fl, Kh, hd)
        return carry, {"k": k.astype(dtype), "v": v.astype(dtype)}

    # Reuse the dec_layers stacked params (read mode slices the whole layer
    # dict; the body only touches the cross_k/cross_v entries). Must run
    # against params initialized via forward().
    _, cross = nn.layer_stack_with_output(
        "dec_layers", cfg.n_layers, block, jnp.zeros(()))
    kv_shape = (cfg.n_layers, B, max_seq, Kh, hd)
    return {"cross": cross,
            "self_kv": {"k": jnp.zeros(kv_shape, dtype),
                        "v": jnp.zeros(kv_shape, dtype)}}


def state_specs(cfg: ModelConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16):
    Kh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    L, Fl = cfg.n_layers, cfg.n_audio_frames
    return {"cross": {"k": jax.ShapeDtypeStruct((L, batch, Fl, Kh, hd), dtype),
                      "v": jax.ShapeDtypeStruct((L, batch, Fl, Kh, hd), dtype)},
            "self_kv": {"k": jax.ShapeDtypeStruct((L, batch, max_seq, Kh, hd),
                                                  dtype),
                        "v": jax.ShapeDtypeStruct((L, batch, max_seq, Kh, hd),
                                                  dtype)}}


def decode_step(cfg: ModelConfig, tokens, state: dict[str, Any],
                pos: jax.Array, positions=None):
    """tokens (B, 1); state from init_decode_state/state_specs."""
    B, S = tokens.shape
    x = T.embed_tokens(cfg, tokens)
    pe = jnp.take(nn.get_parameter_or_create(
        "dec_pos/W", (cfg.max_position, cfg.d_model), I.normal(0.01)),
        jnp.arange(S, dtype=jnp.int32) + pos, axis=0)
    x = x + pe.astype(x.dtype)[None]
    dummy = jnp.zeros((B, S), jnp.int32)
    cos, sin = T.rope_tables(cfg, dummy)

    def block(h, idx, layer_state):
        self_kv, cross = layer_state
        h, new_self, _ = _decoder_block(
            cfg, h, None, cos, sin,
            self_cache=(self_kv["k"], self_kv["v"]), cache_pos=pos,
            cross_kv=(cross["k"], cross["v"]))
        return h, {"k": new_self[0], "v": new_self[1]}

    x, new_self = nn.layer_stack_with_output(
        "dec_layers", cfg.n_layers, block, x,
        xs=(state["self_kv"], state["cross"]), unroll=cfg.scan_unroll)
    x = T.norm(cfg, x, "ln_final")
    return T.lm_head(cfg, x), {"cross": state["cross"], "self_kv": new_self}
