"""Decoder-only LM transformer family: dense GQA, MoE, M-RoPE variants.

Covers phi3.5-moe, granite-moe, deepseek-coder, llama3.2, mistral-nemo,
granite-34b, qwen2-vl (backbone; patch embeddings stubbed upstream).

Written against the functional core (``PF``/``F`` on plain arrays inside
``nn.init``/``nn.apply``) so one definition serves the eager plane, the smoke
tests and the pjit distributed runtime. Activations carry logical-axis
annotations (:mod:`repro.distributed.sharding`) — the launcher's rule table
decides the physical layout.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

import repro.core as nn
from repro.core import context as _ctx
from repro.core import functions as F
from repro.core import initializer as I
from repro.core import parametric as PF
from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain, named_zeros
from repro.kernels import ops as K
from repro.kernels import quant

MOE_AUX_COEF = 0.01


# --------------------------------------------------------------------------- #
# positions / rotary
# --------------------------------------------------------------------------- #

def default_positions(cfg: ModelConfig, B: int, S: int,
                      offset: jax.Array | int = 0) -> jax.Array:
    base = jnp.arange(S, dtype=jnp.int32)[None, :]
    off = jnp.asarray(offset, jnp.int32)
    if off.ndim == 1:  # per-row positions (continuous batching)
        pos = base + off[:, None]
    else:
        pos = base + off
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.mrope:  # text-only stream: t == h == w
        pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
    return pos


def _mrope_sections(half: int) -> tuple[int, int, int]:
    """Qwen2-VL splits the rotary half-dim into (t, h, w) sections 1:1.5:1.5
    (e.g. 16/24/24 for head_dim 128)."""
    t = half // 4
    h = (half - t) // 2
    return t, h, half - t - h


def rope_tables(cfg: ModelConfig, positions: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """cos/sin of shape (B, S, head_dim//2), fp32."""
    hd = cfg.resolved_head_dim
    half = hd // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, jnp.float32) / hd))
    if cfg.mrope:
        assert positions.ndim == 3 and positions.shape[-1] == 3
        t, h, w = _mrope_sections(half)
        sec = jnp.concatenate([jnp.zeros(t, jnp.int32),
                               jnp.ones(h, jnp.int32),
                               jnp.full((w,), 2, jnp.int32)])
        pos = positions[..., sec]            # (B, S, half): component per freq
        freqs = pos.astype(jnp.float32) * inv[None, None, :]
    else:
        assert positions.ndim == 2
        freqs = positions.astype(jnp.float32)[..., None] * inv[None, None, :]
    return jnp.cos(freqs), jnp.sin(freqs)


# --------------------------------------------------------------------------- #
# building blocks
# --------------------------------------------------------------------------- #

def norm(cfg: ModelConfig, x, name: str):
    if cfg.norm == "layernorm":
        return PF.layer_normalization(x, name=name)
    return PF.rms_norm(x, name=name)


def _activate(cfg: ModelConfig, x):
    return F.gelu(x) if cfg.act == "gelu" else F.silu(x)


def attention(cfg: ModelConfig, x, cos, sin, *, name: str = "attn",
              causal: bool = True, window: int | None = None,
              cache: tuple[jax.Array, jax.Array] | None = None,
              cache_pos: jax.Array | None = None,
              pages: jax.Array | None = None,
              cross_kv: tuple[jax.Array, jax.Array] | None = None,
              use_rope: bool = True):
    """GQA attention. Returns (out, new_cache | None).

    ``cache``: (k, v) of shape (B, Smax, Hkv, hd) — decode path writes the new
    K/V at ``cache_pos`` and attends against the whole cache.
    ``pages``: (B, max_blocks) int32 page tables switching ``cache`` to the
    block-paged layout — (k, v) become (num_blocks, block_size, Hkv, hd)
    pools shared by all rows; writes scatter through the page table and
    reads gather through it (``K.attention_*_paged``).
    ``cross_kv``: precomputed encoder K/V (whisper cross-attention).
    """
    B, S, d = x.shape
    H, Kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

    q = PF.dense(x, H * hd, name=f"{name}_q", use_bias=cfg.qkv_bias)
    q = q.reshape(B, S, H, hd)
    if cross_kv is None:
        k = PF.dense(x, Kh * hd, name=f"{name}_k", use_bias=cfg.qkv_bias)
        v = PF.dense(x, Kh * hd, name=f"{name}_v", use_bias=cfg.qkv_bias)
        k = k.reshape(B, S, Kh, hd)
        v = v.reshape(B, S, Kh, hd)
        if use_rope:
            q = F.apply_rope(q, cos, sin)
            k = F.apply_rope(k, cos, sin)
    else:
        k, v = cross_kv

    q = constrain(q, "batch", "seq", "heads", "head_dim")
    if cross_kv is None:
        k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
        v = constrain(v, "batch", "seq", "kv_heads", "head_dim")

    # Merged batch×kv-head sharding: when the head count doesn't divide the
    # model axis (deepseek: 56 H / 8 KV on a 16-wide axis), flatten
    # (batch, kv_head) into one dim that DOES divide the whole mesh — fully
    # local attention, zero attention collectives. (GQA groups stay intact:
    # each merged row is one kv head with its `rep` query heads.)
    # Long sequences only: at short (train) seqs the attention region is
    # cheap to replicate, while the merged layout's boundary resharding
    # lowers to XLA's replicate-then-partition path (see EXPERIMENTS §Perf).
    if cache is None and cross_kv is None and S >= 8192:
        from repro.distributed.sharding import get_env
        env = get_env()
        mesh = env.mesh
        if (mesh is not None and not mesh.empty and "model" in mesh.shape
                and H % mesh.shape["model"] != 0
                and (B * Kh) % (mesh.shape["model"]
                                * mesh.shape.get("data", 1)) == 0):
            rep = H // Kh
            qm = q.reshape(B, S, Kh, rep, hd).transpose(0, 2, 1, 3, 4) \
                .reshape(B * Kh, S, rep, hd)
            km = k.transpose(0, 2, 1, 3).reshape(B * Kh, S, 1, hd)
            vm = v.transpose(0, 2, 1, 3).reshape(B * Kh, S, 1, hd)
            qm = constrain(qm, "batch_kv", "seq", None, None)
            km = constrain(km, "batch_kv", "seq", None, None)
            vm = constrain(vm, "batch_kv", "seq", None, None)
            ym = K.attention(qm, km, vm, causal=causal, window=window,
                             unroll=cfg.scan_unroll is True)
            ym = constrain(ym, "batch_kv", "seq", None, None)
            y = ym.reshape(B, Kh, S, rep, hd).transpose(0, 2, 1, 3, 4) \
                .reshape(B, S, H * hd)
            out = PF.dense(y, d, name=f"{name}_o",
                           w_init=I.scaled_normal(1.0, H * hd))
            return constrain(out, "batch", "seq", "embed"), None

    if cache is None and cross_kv is None:
        # Degraded-heads short-seq case (e.g. deepseek 56H on model=16 at
        # train): shard the QUERY sequence over the model axis instead —
        # attention compute partitions 16x, softmax stays chip-local over
        # the full KV (k/v all-gathered once per layer, a few hundred MB).
        from repro.distributed.sharding import get_env
        env = get_env()
        mesh = env.mesh
        if (mesh is not None and not mesh.empty and "model" in mesh.shape
                and H % mesh.shape["model"] != 0
                and S % mesh.shape["model"] == 0):
            from repro.kernels.flash_attention import ref as _fa_ref
            q = constrain(q, "batch", "attn_seq", None, None)
            k = constrain(k, "batch", None, None, None)
            v = constrain(v, "batch", None, None, None)
            y = _fa_ref.mha_reference(q, k, v, causal=causal, window=window)
            y = constrain(y, "batch", "attn_seq", None, None)
            y = y.reshape(B, S, H * hd)
            out = PF.dense(y, d, name=f"{name}_o",
                           w_init=I.scaled_normal(1.0, H * hd))
            return constrain(out, "batch", "seq", "embed"), None

    new_cache = None
    if cache is not None and pages is not None:
        # block-paged cache: scatter the chunk's K/V through the page table,
        # then attend through the gathered per-row view. ``cache_pos`` must
        # be per-row (B,) — the paged engine always schedules per-row.
        # A 4-tuple cache carries a quantized pool's (NB, bs, Hkv) scale
        # arrays; the quant/dequant fuses into the write/read kernels.
        quantized = len(cache) == 4
        if quantized:
            k_pool, v_pool, k_scale, v_scale = cache
        else:
            k_pool, v_pool = cache
            k_scale = v_scale = None
        pos_arr = jnp.asarray(cache_pos, jnp.int32)
        assert pos_arr.ndim == 1, "paged attention needs per-row positions"
        if quantized:
            k_pool, k_scale = K.paged_cache_write(k_pool, k, pages, pos_arr,
                                                  pool_scale=k_scale)
            v_pool, v_scale = K.paged_cache_write(v_pool, v, pages, pos_arr,
                                                  pool_scale=v_scale)
            k_scale = constrain(k_scale, None, None, "kv_heads")
            v_scale = constrain(v_scale, None, None, "kv_heads")
        else:
            k_pool = K.paged_cache_write(k_pool, k, pages, pos_arr)
            v_pool = K.paged_cache_write(v_pool, v, pages, pos_arr)
        # pin the pool's kv-head sharding through the scatter so GSPMD
        # carries it across layers (tp serving; no-op without a mesh)
        k_pool = constrain(k_pool, None, None, "kv_heads", "head_dim")
        v_pool = constrain(v_pool, None, None, "kv_heads", "head_dim")
        if S > 1:
            y = K.attention_prefill_paged(q, k_pool, v_pool, pages, pos_arr,
                                          k_scale=k_scale, v_scale=v_scale)
        else:
            y = K.attention_decode_paged(q, k_pool, v_pool, pages,
                                         pos_arr + 1,
                                         k_scale=k_scale, v_scale=v_scale)
        new_cache = (k_pool, v_pool, k_scale, v_scale) if quantized \
            else (k_pool, v_pool)
    elif cache is not None:
        k_cache, v_cache = cache
        assert cache_pos is not None
        pos_arr = jnp.asarray(cache_pos, jnp.int32)
        if pos_arr.ndim == 1:  # per-row positions (continuous batching)
            upd = jax.vmap(
                lambda c, n, p: lax.dynamic_update_slice(c, n, (p, 0, 0)))
            k_cache = upd(k_cache, k.astype(k_cache.dtype), pos_arr)
            v_cache = upd(v_cache, v.astype(v_cache.dtype), pos_arr)
            row_pos = pos_arr
        else:
            k_cache = lax.dynamic_update_slice(
                k_cache, k.astype(k_cache.dtype), (0, cache_pos, 0, 0))
            v_cache = lax.dynamic_update_slice(
                v_cache, v.astype(v_cache.dtype), (0, cache_pos, 0, 0))
            row_pos = jnp.full((B,), pos_arr, jnp.int32)
        k_cache = constrain(k_cache, "batch", "kv_seq", "kv_heads", "head_dim")
        v_cache = constrain(v_cache, "batch", "kv_seq", "kv_heads", "head_dim")
        if S > 1:
            # chunked prefill: queries must stay causal *within* the chunk
            # (query i sees cache[: pos + i + 1]), not all see pos + S.
            y = K.attention_prefill(q, k_cache, v_cache, row_pos)
        else:
            y = K.attention_decode(q, k_cache, v_cache, row_pos + 1)
        new_cache = (k_cache, v_cache)
    else:
        y = K.attention(q, k, v, causal=causal and cross_kv is None,
                        window=window, unroll=cfg.scan_unroll is True)

    y = constrain(y, "batch", "seq", "heads", "head_dim")
    y = y.reshape(B, S, H * hd)
    out = PF.dense(y, d, name=f"{name}_o",
                   w_init=I.scaled_normal(1.0, H * hd))
    return constrain(out, "batch", "seq", "embed"), new_cache


def mlp(cfg: ModelConfig, x, *, name: str = "mlp", d_ff: int | None = None):
    d = x.shape[-1]
    dff = d_ff or cfg.d_ff
    if cfg.act == "silu":  # gated (llama-style)
        g = PF.dense(x, dff, name=f"{name}_gate")
        u = PF.dense(x, dff, name=f"{name}_up")
        h = F.silu(g) * u
    else:
        h = _activate(cfg, PF.dense(x, dff, name=f"{name}_up", use_bias=True))
    h = constrain(h, "batch", "seq", "mlp")
    out = PF.dense(h, d, name=f"{name}_down", w_init=I.scaled_normal(1.0, dff))
    return constrain(out, "batch", "seq", "embed")


# --------------------------------------------------------------------------- #
# Mixture of Experts (GShard/Switch-style capacity dispatch)
# --------------------------------------------------------------------------- #

def moe_capacity(cfg: ModelConfig, group_size: int) -> int:
    c = int(math.ceil(group_size * cfg.top_k * cfg.capacity_factor
                      / cfg.n_experts))
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_block(cfg: ModelConfig, x, *, name: str = "moe", token_mask=None):
    """Top-k token-choice MoE with fixed expert capacity (token dropping).

    Dispatch/combine are one-hot einsums — fixed shapes, TPU-friendly; the
    experts dim is sharded over 'model' (expert parallelism) so the dispatched
    activations move through an all-to-all.
    ``token_mask`` (B, S) bool: False tokens (chunked-prefill pads) are
    dropped from routing entirely so they cannot consume expert capacity.
    Returns (y, aux_loss).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    Gs = min(cfg.moe_group_size, T)
    if T % Gs and token_mask is not None:
        # ragged token count: one dispatch group. Serving-only (chunks are
        # small); training keeps the loud assert below — silently setting
        # Gs = T there would scale capacity with T and blow up the
        # dispatch tensors instead of flagging a bad config.
        Gs = T
    nG = T // Gs
    assert nG * Gs == T, (T, Gs)
    C = moe_capacity(cfg, Gs)

    xg = x.reshape(nG, Gs, d)
    xg = constrain(xg, "expert_group", None, "embed")

    # router in fp32 (numerics: paper's "BN in fp32" rule applies to routing)
    router_w = nn.get_parameter_or_create(
        f"{name}_router/kernel", (d, E), I.normal(0.02 / math.sqrt(d)),
        dtype=jnp.float32)
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)                    # (nG,Gs,E)
    gate_vals, expert_idx = lax.top_k(probs, k)                # (nG,Gs,k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # position of each (token, choice) in its expert's queue
    oh_flat = jax.nn.one_hot(expert_idx.reshape(nG, Gs * k), E,
                             dtype=jnp.int32)                  # (nG,Gs*k,E)
    if token_mask is not None:
        # zeroed one-hots make pads rank -1 in every queue -> never kept
        tm = jnp.repeat(token_mask.reshape(nG, Gs), k, axis=1)
        oh_flat = oh_flat * tm[..., None].astype(jnp.int32)
    pos_flat = jnp.cumsum(oh_flat, axis=1) * oh_flat - 1
    pos_tok = pos_flat.max(-1).reshape(nG, Gs, k)              # (nG,Gs,k)
    keep = (pos_tok >= 0) & (pos_tok < C)

    cdt = _ctx.get_default_context().policy.compute_dtype
    dispatch = jnp.zeros((nG, Gs, E, C), cdt)
    combine = jnp.zeros((nG, Gs, E, C), cdt)
    for i in range(k):
        ohe = jax.nn.one_hot(expert_idx[..., i], E, dtype=cdt)
        ohc = jax.nn.one_hot(pos_tok[..., i], C, dtype=cdt)
        sel = (ohe[..., None] * ohc[..., None, :]) \
            * keep[..., i, None, None].astype(cdt)
        dispatch = dispatch + sel
        combine = combine + sel * gate_vals[..., i, None, None].astype(cdt)
    dispatch = constrain(dispatch, "expert_group", None, "expert", None)
    combine = constrain(combine, "expert_group", None, "expert", None)

    expert_in = jnp.einsum("gsd,gsec->gecd", xg.astype(cdt), dispatch)
    expert_in = constrain(expert_in, "expert_group", "expert", None, "embed")

    wg = nn.get_parameter_or_create(f"{name}_wi_gate", (E, d, cfg.d_ff),
                                    I.lecun_normal())
    wu = nn.get_parameter_or_create(f"{name}_wi_up", (E, d, cfg.d_ff),
                                    I.lecun_normal())
    wo = nn.get_parameter_or_create(f"{name}_wo", (E, cfg.d_ff, d),
                                    I.scaled_normal(1.0, cfg.d_ff))
    h = jnp.einsum("gecd,edf->gecf", expert_in, wg.astype(cdt))
    u = jnp.einsum("gecd,edf->gecf", expert_in, wu.astype(cdt))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(cdt) * u
    h = constrain(h, "expert_group", "expert", None, "mlp")
    expert_out = jnp.einsum("gecf,efd->gecd", h, wo.astype(cdt))
    expert_out = constrain(expert_out, "expert_group", "expert", None, "embed")

    y = jnp.einsum("gecd,gsec->gsd", expert_out, combine)
    y = y.reshape(B, S, d)

    # Switch load-balance auxiliary loss
    me = jnp.mean(probs, axis=(0, 1))                           # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return constrain(y, "batch", "seq", "embed"), aux


# --------------------------------------------------------------------------- #
# decoder blocks / full model
# --------------------------------------------------------------------------- #

def decoder_block(cfg: ModelConfig, x, cos, sin, *, cache=None,
                  cache_pos=None, pages=None, use_rope: bool = True,
                  token_mask=None):
    """Pre-norm block. Returns (x, aux, new_cache)."""
    h = norm(cfg, x, "ln_attn")
    a, new_cache = attention(cfg, h, cos, sin, cache=cache,
                             cache_pos=cache_pos, pages=pages,
                             use_rope=use_rope)
    x = x + a
    h = norm(cfg, x, "ln_mlp")
    if cfg.family == "moe":
        m, aux = moe_block(cfg, h, token_mask=token_mask)
    else:
        m, aux = mlp(cfg, h), jnp.zeros((), jnp.float32)
    return x + m, aux, new_cache


def embed_tokens(cfg: ModelConfig, tokens):
    x = PF.embed(tokens, cfg.vocab_size, cfg.d_model, name="tok_emb")
    return constrain(x, "batch", "seq", "embed")


def lm_head(cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        table = nn.get_parameter_or_create(
            "tok_emb/W", (cfg.vocab_size, cfg.d_model), I.normal(0.02))
        cdt = _ctx.get_default_context().policy.compute_dtype
        logits = jnp.einsum("bsd,vd->bsv", x, table.astype(cdt))
    else:
        logits = PF.dense(x, cfg.vocab_size, name="lm_head")
    return constrain(logits, "batch", "seq", "vocab")


def forward(cfg: ModelConfig, tokens, positions=None, last_only: bool = False):
    """Full-sequence forward (train / prefill). Returns (logits, aux).

    ``last_only``: only produce logits for the final position (prefill serving
    — skips the (B, S, V) logits buffer and its vocab matmul).
    """
    B, S = tokens.shape[:2]
    if positions is None:
        positions = default_positions(cfg, B, S)
    x = embed_tokens(cfg, tokens)
    cos, sin = rope_tables(cfg, positions)

    def block(carry, idx):
        h, aux = carry
        h, aux_i, _ = decoder_block(cfg, h, cos, sin)
        return h, aux + aux_i

    x, aux = nn.layer_stack("layers", cfg.n_layers, block,
                            (x, jnp.zeros((), jnp.float32)),
                            remat=cfg.remat, unroll=cfg.scan_unroll)
    if last_only:
        x = x[:, -1:]
    x = norm(cfg, x, "ln_final")
    return lm_head(cfg, x), aux


def forward_hidden(cfg: ModelConfig, tokens, positions=None):
    """Backbone forward stopping before the LM head: (hidden, aux)."""
    B, S = tokens.shape[:2]
    if positions is None:
        positions = default_positions(cfg, B, S)
    x = embed_tokens(cfg, tokens)
    cos, sin = rope_tables(cfg, positions)

    def block(carry, idx):
        h, aux = carry
        h, aux_i, _ = decoder_block(cfg, h, cos, sin)
        return h, aux + aux_i

    x, aux = nn.layer_stack("layers", cfg.n_layers, block,
                            (x, jnp.zeros((), jnp.float32)),
                            remat=cfg.remat, unroll=cfg.scan_unroll)
    return norm(cfg, x, "ln_final"), aux


def ce_from_hidden_chunked(cfg: ModelConfig, x, labels, chunk: int):
    """Cross-entropy over sequence chunks: the (B, S, V) logits tensor never
    materializes — peak is one (B, chunk, V) block, rematerialized in the
    backward pass (jax.checkpoint per chunk)."""
    B, S, d = x.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # ragged: fall back to one block
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, d).swapaxes(0, 1)      # (nc, B, c, d)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    xc = constrain(xc, None, "batch", None, "embed")
    lc = constrain(lc, None, "batch", None)

    @jax.checkpoint
    def one(xi, li):
        xi = constrain(xi, "batch", None, "embed")
        logits = lm_head(cfg, xi)
        ce = F.softmax_cross_entropy(logits, li)
        return jnp.sum(constrain(ce, "batch", None))

    def step(acc, xs):
        xi, li = xs
        return acc + one(xi, li), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (B * S)


def loss_fn(cfg: ModelConfig, tokens, labels, positions=None):
    """Mean next-token cross-entropy (+ MoE aux). Scalar fp32."""
    if cfg.loss_chunk:
        x, aux = forward_hidden(cfg, tokens, positions)
        loss = ce_from_hidden_chunked(cfg, x, labels, cfg.loss_chunk)
        return loss + MOE_AUX_COEF * aux / max(1, cfg.n_layers)
    logits, aux = forward(cfg, tokens, positions)
    ce = F.softmax_cross_entropy(logits, labels)
    loss = jnp.mean(ce) + MOE_AUX_COEF * aux / max(1, cfg.n_layers)
    return loss


# --------------------------------------------------------------------------- #
# decode (serving) path
# --------------------------------------------------------------------------- #

def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  dtype=jnp.bfloat16) -> dict[str, Any]:
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd)
    names = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": named_zeros(names, shape, dtype),
            "v": named_zeros(names, shape, dtype)}


def kv_cache_specs(cfg: ModelConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16) -> dict[str, jax.ShapeDtypeStruct]:
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def init_paged_kv_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                        dtype=jnp.bfloat16) -> dict[str, Any]:
    """Block-paged KV pool: no batch axis — rows address blocks through
    per-slot page tables, so memory scales with allocated blocks, not
    ``batch * max_seq``. Block 0 is the engine's garbage block.

    A quantized ``dtype`` (int8/fp8, :mod:`repro.kernels.quant`) adds
    per-(slot, head) f32 scale leaves ``k_scale``/``v_scale`` shaped
    (n_layers, num_blocks, block_size, Hkv) next to the pools — the block
    axis stays axis 1 on every leaf, so the engine's block extraction,
    tier spill/fetch and store fingerprint treat them like pool leaves.

    Under an active serving env (tensor-parallel engine) the pools come
    out sharded on the kv-head axis — each device is born holding
    ``1/tp`` of every block (scales shard the same head axis) — degrading
    to replicated for GQA geometries where ``Hkv`` doesn't divide the
    model axis."""
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads, hd)
    names = ("layers", None, None, "kv_heads", "head_dim")
    out = {"k": named_zeros(names, shape, dtype),
           "v": named_zeros(names, shape, dtype)}
    if quant.is_quantized(dtype):
        s_names = ("layers", None, None, "kv_heads")
        out["k_scale"] = named_zeros(s_names, shape[:-1], quant.SCALE_DTYPE)
        out["v_scale"] = named_zeros(s_names, shape[:-1], quant.SCALE_DTYPE)
    return out


def paged_kv_cache_specs(cfg: ModelConfig, num_blocks: int, block_size: int,
                         dtype=jnp.bfloat16) -> dict[str, jax.ShapeDtypeStruct]:
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads, hd)
    out = {"k": jax.ShapeDtypeStruct(shape, dtype),
           "v": jax.ShapeDtypeStruct(shape, dtype)}
    if quant.is_quantized(dtype):
        out["k_scale"] = jax.ShapeDtypeStruct(shape[:-1], quant.SCALE_DTYPE)
        out["v_scale"] = jax.ShapeDtypeStruct(shape[:-1], quant.SCALE_DTYPE)
    return out


def decode_step(cfg: ModelConfig, tokens, cache: dict[str, Any],
                pos: jax.Array, positions=None):
    """One decode step. tokens (B, 1); cache as from init_kv_cache;
    ``pos`` scalar int32 (synchronized batch decode). Returns (logits, cache).
    """
    B, S = tokens.shape
    if positions is None:
        positions = default_positions(cfg, B, S, offset=pos)
    x = embed_tokens(cfg, tokens)
    cos, sin = rope_tables(cfg, positions)

    def block(h, idx, layer_cache):
        h, _, new_cache = decoder_block(cfg, h, cos, sin,
                                        cache=(layer_cache["k"],
                                               layer_cache["v"]),
                                        cache_pos=pos)
        return h, {"k": new_cache[0], "v": new_cache[1]}

    x, new_cache = nn.layer_stack_with_output(
        "layers", cfg.n_layers, block, x,
        xs={"k": cache["k"], "v": cache["v"]}, unroll=cfg.scan_unroll)
    x = norm(cfg, x, "ln_final")
    return lm_head(cfg, x), new_cache


def gather_last_valid(x: jax.Array, length: jax.Array) -> jax.Array:
    """(B, C, d) -> (B, 1, d), picking position length[b]-1 per row."""
    idx = jnp.maximum(jnp.asarray(length, jnp.int32) - 1, 0)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)


def prefill(cfg: ModelConfig, tokens, cache: dict[str, Any],
            pos: jax.Array, length: jax.Array, positions=None):
    """Chunked prefill: absorb a (B, C) prompt chunk into the KV cache.

    ``pos`` (B,) is each row's cache write offset; ``length`` (B,) the number
    of valid tokens in the chunk (rows are right-padded to C). One fused call
    writes K/V for the whole chunk and returns logits at each row's last
    valid position, shape (B, 1, V), plus the updated cache — replacing C
    teacher-forced decode steps. Pad positions produce garbage logits that
    the gather skips, their cache entries are overwritten by the next chunk
    before any query can attend to them, and they are masked out of MoE
    routing so they cannot steal expert capacity from valid tokens.
    """
    B, C = tokens.shape
    pos = jnp.asarray(pos, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    if positions is None:
        positions = default_positions(cfg, B, C, offset=pos)
    x = embed_tokens(cfg, tokens)
    cos, sin = rope_tables(cfg, positions)
    valid = jnp.arange(C)[None, :] < length[:, None]

    def block(h, idx, layer_cache):
        h, _, new_cache = decoder_block(cfg, h, cos, sin,
                                        cache=(layer_cache["k"],
                                               layer_cache["v"]),
                                        cache_pos=pos, token_mask=valid)
        return h, {"k": new_cache[0], "v": new_cache[1]}

    x, new_cache = nn.layer_stack_with_output(
        "layers", cfg.n_layers, block, x,
        xs={"k": cache["k"], "v": cache["v"]}, unroll=cfg.scan_unroll)
    x = gather_last_valid(x, length)
    x = norm(cfg, x, "ln_final")
    return lm_head(cfg, x), new_cache


def prefill_paged(cfg: ModelConfig, tokens, cache: dict[str, Any],
                  pages: jax.Array, pos: jax.Array, length: jax.Array,
                  positions=None, last_only: bool = True):
    """Chunked prefill against the block-paged cache (see :func:`prefill`
    for chunk semantics). ``cache`` from :func:`init_paged_kv_cache`;
    ``pages`` (B, max_blocks) int32 per-row page tables. A C = 1 call is a
    paged decode step — the engine uses this one entry for both shapes.

    ``last_only=False`` returns logits at *every* chunk position,
    (B, C, V) instead of the gathered (B, 1, V): the chunk-causal mask
    means position ``i``'s logits condition on exactly ``tokens[:, :i+1]``
    plus the cache, which is what speculative verification needs — one
    ``(B, 1 + k)`` decode-prefill call scores all ``k`` draft tokens for
    free. The extra cost is skipping the gather (the ``C`` lm_head columns
    were computed either way).
    """
    B, C = tokens.shape
    pos = jnp.asarray(pos, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    if positions is None:
        positions = default_positions(cfg, B, C, offset=pos)
    x = embed_tokens(cfg, tokens)
    cos, sin = rope_tables(cfg, positions)
    valid = jnp.arange(C)[None, :] < length[:, None]

    quantized = "k_scale" in cache

    def block(h, idx, layer_cache):
        c = (layer_cache["k"], layer_cache["v"])
        if quantized:
            c += (layer_cache["k_scale"], layer_cache["v_scale"])
        h, _, new_cache = decoder_block(cfg, h, cos, sin, cache=c,
                                        cache_pos=pos, pages=pages,
                                        token_mask=valid)
        out = {"k": new_cache[0], "v": new_cache[1]}
        if quantized:
            out["k_scale"], out["v_scale"] = new_cache[2], new_cache[3]
        return h, out

    x, new_cache = nn.layer_stack_with_output(
        "layers", cfg.n_layers, block, x,
        xs=dict(cache), unroll=cfg.scan_unroll)
    if last_only:
        x = gather_last_valid(x, length)
    x = norm(cfg, x, "ln_final")
    return lm_head(cfg, x), new_cache
