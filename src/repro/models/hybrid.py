"""Zamba2-style hybrid: Mamba-2 backbone + a *shared* attention block
(arXiv:2411.15242) applied every ``attn_every`` layers.

The shared block's parameters are created once (``nn.capture``) and closed
over inside the layer scan — one physical copy, applied at several depths,
exactly the Zamba2 parameter-sharing trick. (We simplify away Zamba2's
per-invocation LoRA deltas; noted in DESIGN.md.)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

import repro.core as nn
from repro.core import functions as F
from repro.configs.base import ModelConfig
from repro.distributed.sharding import named_zeros
from repro.kernels import quant
from repro.models import mamba as M
from repro.models import transformer as T


def _shared_block(cfg: ModelConfig, x, cos, sin, *, cache=None,
                  cache_pos=None, pages=None):
    """Pre-norm attention + MLP with the cfg's attention geometry."""
    h = T.norm(cfg, x, "ln_attn")
    a, new_cache = T.attention(cfg, h, cos, sin, cache=cache,
                               cache_pos=cache_pos, pages=pages)
    x = x + a
    h = T.norm(cfg, x, "ln_mlp")
    x = x + T.mlp(cfg, h)
    return x, new_cache


def forward(cfg: ModelConfig, tokens, positions=None, last_only: bool = False):
    B, S = tokens.shape[:2]
    if positions is None:
        positions = T.default_positions(cfg, B, S)
    x = T.embed_tokens(cfg, tokens)
    cos, sin = T.rope_tables(cfg, positions)

    shared = nn.capture(
        "shared_attn", lambda: _shared_block(cfg, x, cos, sin))

    every = max(1, cfg.attn_every)

    def block(h, idx):
        h = h + M.mamba2_block(cfg, T.norm(cfg, h, "ln"))
        is_attn = (idx % every) == (every - 1)

        def with_attn(v):
            out, _ = nn.apply_shared(shared, _shared_block, cfg, v, cos, sin)
            return out

        return lax.cond(is_attn, with_attn, lambda v: v, h)

    x = nn.layer_stack("layers", cfg.n_layers, block, x, remat=cfg.remat,
                       unroll=cfg.scan_unroll)
    if last_only:
        x = x[:, -1:]
    x = T.norm(cfg, x, "ln_final")
    return T.lm_head(cfg, x), jnp.zeros((), jnp.float32)


def forward_hidden(cfg: ModelConfig, tokens, positions=None):
    B, S = tokens.shape[:2]
    if positions is None:
        positions = T.default_positions(cfg, B, S)
    x = T.embed_tokens(cfg, tokens)
    cos, sin = T.rope_tables(cfg, positions)
    shared = nn.capture(
        "shared_attn", lambda: _shared_block(cfg, x, cos, sin))
    every = max(1, cfg.attn_every)

    def block(h, idx):
        h = h + M.mamba2_block(cfg, T.norm(cfg, h, "ln"))
        is_attn = (idx % every) == (every - 1)

        def with_attn(v):
            out, _ = nn.apply_shared(shared, _shared_block, cfg, v, cos, sin)
            return out

        return lax.cond(is_attn, with_attn, lambda v: v, h)

    x = nn.layer_stack("layers", cfg.n_layers, block, x, remat=cfg.remat,
                       unroll=cfg.scan_unroll)
    return T.norm(cfg, x, "ln_final")


def loss_fn(cfg: ModelConfig, tokens, labels, positions=None):
    if cfg.loss_chunk:
        x = forward_hidden(cfg, tokens, positions)
        return T.ce_from_hidden_chunked(cfg, x, labels, cfg.loss_chunk)
    logits, _ = forward(cfg, tokens, positions)
    return jnp.mean(F.softmax_cross_entropy(logits, labels))


# --------------------------------------------------------------------------- #
# decode: SSM state per layer + KV cache per *attention site*
# --------------------------------------------------------------------------- #

def n_attn_sites(cfg: ModelConfig) -> int:
    every = max(1, cfg.attn_every)
    return sum(1 for i in range(cfg.n_layers) if (i % every) == (every - 1))


def init_state(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict[str, Any]:
    hd = cfg.resolved_head_dim
    sites = n_attn_sites(cfg)
    kv_shape = (sites, batch, max_seq, cfg.n_kv_heads, hd)
    kv_names = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"ssm": M.init_state(cfg, batch, dtype),
            "kv": {"k": named_zeros(kv_names, kv_shape, dtype),
                   "v": named_zeros(kv_names, kv_shape, dtype)}}


def state_specs(cfg: ModelConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    sites = n_attn_sites(cfg)
    kv_shape = (sites, batch, max_seq, cfg.n_kv_heads, hd)
    return {"ssm": M.state_specs(cfg, batch, dtype),
            "kv": {"k": jax.ShapeDtypeStruct(kv_shape, dtype),
                   "v": jax.ShapeDtypeStruct(kv_shape, dtype)}}


def init_paged_state(cfg: ModelConfig, batch: int, num_blocks: int,
                     block_size: int, dtype=jnp.bfloat16) -> dict[str, Any]:
    """Hybrid paged state: the per-site KV caches become block pools
    addressed through per-slot page tables (no batch axis), while the
    recurrent mamba state — SSD ``h`` and the conv ring window — stays a
    dense per-slot layout (it is O(1) in sequence, there is nothing to
    page; it rides alongside the paged KV in the same state dict).

    Under a tensor-parallel serving env the per-site pools shard on the
    kv-head axis and the dense SSM state shards on its SSD-head / conv
    channel dims (:func:`repro.models.mamba.init_state`); indivisible dims
    replicate — see ``CacheSpec.tp_note`` for the recorded rationale."""
    hd = cfg.resolved_head_dim
    sites = n_attn_sites(cfg)
    kv_shape = (sites, num_blocks, block_size, cfg.n_kv_heads, hd)
    kv_names = ("layers", None, None, "kv_heads", "head_dim")
    kv = {"k": named_zeros(kv_names, kv_shape, dtype),
          "v": named_zeros(kv_names, kv_shape, dtype)}
    if quant.is_quantized(dtype):
        # quantized pools carry per-(slot, head) scale leaves; the SSM
        # state stays in the compute dtype (it is O(1) per slot — nothing
        # to page, nothing worth quantizing)
        s_names = ("layers", None, None, "kv_heads")
        kv["k_scale"] = named_zeros(s_names, kv_shape[:-1], quant.SCALE_DTYPE)
        kv["v_scale"] = named_zeros(s_names, kv_shape[:-1], quant.SCALE_DTYPE)
    return {"ssm": M.init_state(cfg, batch,
                                dtype if not quant.is_quantized(dtype)
                                else jnp.bfloat16),
            "kv": kv}


def paged_state_specs(cfg: ModelConfig, batch: int, num_blocks: int,
                      block_size: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    sites = n_attn_sites(cfg)
    kv_shape = (sites, num_blocks, block_size, cfg.n_kv_heads, hd)
    kv = {"k": jax.ShapeDtypeStruct(kv_shape, dtype),
          "v": jax.ShapeDtypeStruct(kv_shape, dtype)}
    if quant.is_quantized(dtype):
        kv["k_scale"] = jax.ShapeDtypeStruct(kv_shape[:-1], quant.SCALE_DTYPE)
        kv["v_scale"] = jax.ShapeDtypeStruct(kv_shape[:-1], quant.SCALE_DTYPE)
    return {"ssm": M.state_specs(cfg, batch,
                                 dtype if not quant.is_quantized(dtype)
                                 else jnp.bfloat16),
            "kv": kv}


def _site_map(cfg: ModelConfig) -> jax.Array:
    """Layer idx -> attention-site index (or -1 for mamba-only layers)."""
    every = max(1, cfg.attn_every)
    site_of_layer = []
    s = 0
    for i in range(cfg.n_layers):
        if (i % every) == (every - 1):
            site_of_layer.append(s)
            s += 1
        else:
            site_of_layer.append(-1)
    return jnp.asarray(site_of_layer, jnp.int32)


def _scan_decode_layers(cfg: ModelConfig, x, state: dict[str, Any],
                        cos, sin, pos, ssm_block, pages=None):
    """Shared decode/prefill layer scan: per layer a mamba update via
    ``ssm_block(h_normed, layer_state) -> (out, new_state)`` plus the
    shared attention block (against its per-site KV cache — dense, or
    block-paged when ``pages`` is given) at attention sites. Returns
    (hidden, new_state_dict)."""
    shared = nn.capture(
        "shared_attn", lambda: _shared_block(cfg, x, cos, sin))
    site_map = _site_map(cfg)

    def block(carry, idx, ssm_layer_state):
        h, kv = carry
        out, new_ssm = ssm_block(T.norm(cfg, h, "ln"), ssm_layer_state)
        h = h + out
        site = site_map[idx]

        def with_attn(args):
            h_, kv_ = args
            quantized = "k_scale" in kv_
            names = ("k", "v") + (("k_scale", "v_scale") if quantized else ())
            cache = tuple(
                lax.dynamic_index_in_dim(kv_[n], site, 0, keepdims=False)
                for n in names)
            h2, new_cache = nn.apply_shared(
                shared, _shared_block, cfg, h_, cos, sin,
                cache=cache, cache_pos=pos, pages=pages)
            return h2, {n: lax.dynamic_update_index_in_dim(kv_[n], c, site, 0)
                        for n, c in zip(names, new_cache)}

        if n_attn_sites(cfg) > 0:  # static: probe configs may have none
            h, kv = lax.cond(site >= 0, with_attn, lambda a: a, (h, kv))
        return (h, kv), new_ssm

    (x, kv), new_ssm = nn.layer_stack_with_output(
        "layers", cfg.n_layers, block, (x, state["kv"]), xs=state["ssm"],
        unroll=cfg.scan_unroll)
    return x, {"ssm": new_ssm, "kv": kv}


def decode_step(cfg: ModelConfig, tokens, state: dict[str, Any],
                pos: jax.Array, positions=None):
    B, S = tokens.shape
    if positions is None:
        positions = T.default_positions(cfg, B, S, offset=pos)
    x = T.embed_tokens(cfg, tokens)
    cos, sin = T.rope_tables(cfg, positions)
    x, new_state = _scan_decode_layers(
        cfg, x, state, cos, sin, pos,
        lambda h, s: M.mamba2_block_step(cfg, h, s))
    x = T.norm(cfg, x, "ln_final")
    return T.lm_head(cfg, x), new_state


def prefill(cfg: ModelConfig, tokens, state: dict[str, Any],
            pos: jax.Array, length: jax.Array, positions=None):
    """Chunked prefill: absorb a (B, C) prompt chunk into the SSM state and
    the per-site KV caches in one fused call. ``pos`` (B,) is each row's KV
    write offset; ``length`` (B,) counts valid tokens per right-padded row.
    Returns logits (B, 1, V) at each row's last valid position + new state."""
    B, C = tokens.shape
    pos = jnp.asarray(pos, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    if positions is None:
        positions = T.default_positions(cfg, B, C, offset=pos)
    x = T.embed_tokens(cfg, tokens)
    cos, sin = T.rope_tables(cfg, positions)
    x, new_state = _scan_decode_layers(
        cfg, x, state, cos, sin, pos,
        lambda h, s: M.mamba2_block_prefill(cfg, h, s, length))
    x = T.gather_last_valid(x, length)
    x = T.norm(cfg, x, "ln_final")
    return T.lm_head(cfg, x), new_state


def prefill_paged(cfg: ModelConfig, tokens, state: dict[str, Any],
                  pages: jax.Array, pos: jax.Array, length: jax.Array,
                  positions=None):
    """Chunked prefill with block-paged per-site KV caches (see
    :func:`prefill`). The SSM state continues densely per slot — only the
    attention sites read/write through ``pages`` (B, max_blocks). A C = 1
    call is a paged decode step; prefix reuse is NOT sound for this family
    (skipping tokens would skip their SSM state updates), which the
    registry's cache spec records."""
    B, C = tokens.shape
    pos = jnp.asarray(pos, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    if positions is None:
        positions = T.default_positions(cfg, B, C, offset=pos)
    x = T.embed_tokens(cfg, tokens)
    cos, sin = T.rope_tables(cfg, positions)
    x, new_state = _scan_decode_layers(
        cfg, x, state, cos, sin, pos,
        lambda h, s: M.mamba2_block_prefill(cfg, h, s, length), pages=pages)
    x = T.gather_last_valid(x, length)
    x = T.norm(cfg, x, "ln_final")
    return T.lm_head(cfg, x), new_state
