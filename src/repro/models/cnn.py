"""CNNs for paper-parity benchmarks: LeNet (paper Listing 4) and ResNets
(paper §4 Tables 1–2).

These run on the *eager Variable plane* as well as the functional one — the
LeNet below is a line-for-line port of the paper's Listing 4.
"""

from __future__ import annotations

import jax.numpy as jnp

import repro.core as nn
from repro.core import functions as F
from repro.core import parametric as PF


def lenet(x):
    """Paper Listing 4, verbatim structure."""
    h = PF.convolution(x, 16, (5, 5), name="conv1")
    h = F.max_pooling(h, kernel=(2, 2))
    h = F.relu(h, inplace=False)
    h = PF.convolution(h, 16, (5, 5), name="conv2")
    h = F.max_pooling(h, kernel=(2, 2))
    h = F.relu(h, inplace=False)
    h = PF.affine(h, 50, name="affine3")
    h = F.relu(h, inplace=False)
    h = PF.affine(h, 10, name="affine4")
    return h


def _bn_act(x, name, batch_stat=True):
    h = PF.batch_normalization(x, name=name, batch_stat=batch_stat)
    return F.relu(h)


def basic_block(x, planes, stride, name, batch_stat=True):
    with nn.parameter_scope(name):
        h = PF.convolution(x, planes, (3, 3), pad=(1, 1),
                           stride=(stride, stride), name="conv1",
                           with_bias=False)
        h = _bn_act(h, "bn1", batch_stat)
        h = PF.convolution(h, planes, (3, 3), pad=(1, 1), name="conv2",
                           with_bias=False)
        h = PF.batch_normalization(h, name="bn2", batch_stat=batch_stat)
        if stride != 1 or x.shape[1] != planes:
            x = PF.convolution(x, planes, (1, 1), stride=(stride, stride),
                               name="down", with_bias=False)
            x = PF.batch_normalization(x, name="bn_down",
                                       batch_stat=batch_stat)
        return F.relu(h + x)


def bottleneck_block(x, planes, stride, name, batch_stat=True):
    with nn.parameter_scope(name):
        h = PF.convolution(x, planes, (1, 1), name="conv1", with_bias=False)
        h = _bn_act(h, "bn1", batch_stat)
        h = PF.convolution(h, planes, (3, 3), pad=(1, 1),
                           stride=(stride, stride), name="conv2",
                           with_bias=False)
        h = _bn_act(h, "bn2", batch_stat)
        h = PF.convolution(h, planes * 4, (1, 1), name="conv3",
                           with_bias=False)
        h = PF.batch_normalization(h, name="bn3", batch_stat=batch_stat)
        if stride != 1 or x.shape[1] != planes * 4:
            x = PF.convolution(x, planes * 4, (1, 1),
                               stride=(stride, stride), name="down",
                               with_bias=False)
            x = PF.batch_normalization(x, name="bn_down",
                                       batch_stat=batch_stat)
        return F.relu(h + x)


_RESNET_SPECS = {
    "resnet18": (basic_block, (2, 2, 2, 2)),
    "resnet50": (bottleneck_block, (3, 4, 6, 3)),
}


def resnet(x, arch: str = "resnet18", num_classes: int = 1000,
           batch_stat: bool = True, width: int = 64):
    """NCHW input. ``width=16`` gives the reduced benchmark variant."""
    block, reps = _RESNET_SPECS[arch]
    h = PF.convolution(x, width, (7, 7), pad=(3, 3), stride=(2, 2),
                       name="conv1", with_bias=False)
    h = _bn_act(h, "bn1", batch_stat)
    h = F.max_pooling(h, kernel=(3, 3), stride=(2, 2), pad=(1, 1))
    planes = width
    for stage, n in enumerate(reps):
        for i in range(n):
            stride = 2 if (stage > 0 and i == 0) else 1
            h = block(h, planes, stride, f"stage{stage}_block{i}",
                      batch_stat)
        planes *= 2
    h = F.global_average_pooling(h)
    return PF.affine(h, num_classes, name="fc")
