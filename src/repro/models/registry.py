"""Model API registry: one uniform surface per architecture family.

``ModelApi`` is what the launcher, dry-run, serving and tests program
against: ``loss_fn(tokens, labels, **extras)``, ``forward``, ``decode_step``,
``prefill`` (chunked prompt absorption for serving), plus shape-struct
providers for inputs and decode state.

Decode-state convention: every state leaf carries the layer (or attention
site) axis first and the batch axis second — the serving engine relies on
axis 1 being batch when it zeroes a slot's recurrent state on reuse. KV
cache leaves must be keyed ``"k"``/``"v"`` — plus ``"k_scale"``/
``"v_scale"`` for quantized pools (int8/fp8 ``kv_dtype``): the engine
skips all four when resetting (they are positionally overwritten and
length-masked; zeroing a scale leaf would corrupt live blocks, since
scale leaves have the *block* axis at position 1, not batch), so any
other key is treated as recurrent state and zeroed.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import hybrid, mamba, transformer, whisper


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """What a family's decode state is made of, and what the serving engine
    may therefore do with it.

    ``kind``: "kv" (pure attention cache), "recurrent" (O(1) SSM state),
    "hybrid" (recurrent state + per-site KV), "cross" (encoder cross-KV).
    ``paged``: the KV portion can live in a block pool addressed through
    per-slot page tables (``prefill_paged`` / ``paged_state_init`` set).
    ``prefix_reuse``: skipping prefill over a cache-hit prefix is *sound* —
    true only when the cache captures the full effect of the skipped tokens
    (pure KV). Recurrent/hybrid families must re-run every prompt token
    through the SSM even when their KV blocks could be shared.
    ``spec_decode``: speculative multi-token decoding is *sound* — the
    verify step writes K/V for draft tokens that may be rejected, and
    rollback is pure position arithmetic only when state is positional
    (pure KV, entries overwritten in place). Recurrent/hybrid state is an
    accumulated recurrence: absorbing a rejected draft poisons ``h`` with
    no way to rewind, so those families must decode one token at a time.
    ``tp_note``: how the family's state lays out on a tensor-parallel
    serving mesh, including the recorded reason whenever a leaf replicates
    instead of sharding (``repro.launch.serve_shardings`` applies the
    policy; the engine's ``tp_layout()`` reports the realized placement).
    ``kv_dtype``: the family's *default* paged-pool storage dtype name
    ("native" = the engine's compute dtype; "int8"/"fp8" = quantized
    pools with per-(slot, head) scale leaves, see
    :mod:`repro.kernels.quant`). The engine's ``kv_dtype`` knob /
    ``--kv-dtype`` / ``$REPRO_KV_DTYPE`` override it per deployment.
    """
    kind: str
    paged: bool = False
    prefix_reuse: bool = False
    spec_decode: bool = False
    tp_note: str = ""
    kv_dtype: str = "native"


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    forward: Callable            # (tokens, **extras) -> (logits, aux)
    loss_fn: Callable            # (tokens, labels, **extras) -> scalar
    decode_step: Callable | None # (tokens, state, pos, **extras) -> (logits, state)
    decode_state_specs: Callable | None  # (batch, max_seq) -> pytree of SDS
    decode_state_init: Callable | None
    # (tokens (B,C), state, pos (B,), length (B,)) -> (logits (B,1,V), state)
    prefill: Callable | None = None
    cache_spec: CacheSpec = CacheSpec(kind="kv")
    # (tokens (B,C), state, pages (B,MB), pos (B,), length (B,))
    #   -> (logits (B,1,V), state); C=1 doubles as the paged decode step.
    # kw last_only=False (spec_decode families) returns (B,C,V) chunk
    # logits so one call verifies a whole speculative draft window
    prefill_paged: Callable | None = None
    # (batch, num_blocks, block_size, dtype) -> paged state pytree
    paged_state_init: Callable | None = None
    paged_state_specs: Callable | None = None

    def input_specs(self, shape: ShapeConfig,
                    cache_dtype=jnp.bfloat16) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        cfg = self.cfg
        B = shape.global_batch
        if shape.kind in ("train", "prefill"):
            S = shape.seq_len
            specs: dict[str, Any] = {
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
            if cfg.mrope:
                specs["positions"] = jax.ShapeDtypeStruct((B, S, 3), jnp.int32)
            if cfg.family == "audio":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
            return specs
        # decode: one new token against a seq_len-deep cache/state
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "state": self.decode_state_specs(B, shape.seq_len, cache_dtype),
        }
        if cfg.mrope:
            specs["positions"] = jax.ShapeDtypeStruct((B, 1, 3), jnp.int32)
        return specs


def _lm_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        forward=lambda tokens, **kw: transformer.forward(cfg, tokens, **kw),
        loss_fn=lambda tokens, labels, **kw: transformer.loss_fn(
            cfg, tokens, labels, **kw),
        decode_step=lambda tokens, state, pos, **kw: transformer.decode_step(
            cfg, tokens, state, pos, **kw),
        decode_state_specs=lambda b, s, dt=jnp.bfloat16:
            transformer.kv_cache_specs(cfg, b, s, dt),
        decode_state_init=lambda b, s, dt=jnp.bfloat16:
            transformer.init_kv_cache(cfg, b, s, dt),
        prefill=lambda tokens, state, pos, length, **kw:
            transformer.prefill(cfg, tokens, state, pos, length, **kw),
        cache_spec=CacheSpec(
            kind="kv", paged=True, prefix_reuse=True, spec_decode=True,
            tp_note="KV pools shard on the kv-head axis; GQA with "
                    "Hkv % tp != 0 replicates the pools (head slices "
                    "can't split evenly) while query heads stay sharded"),
        prefill_paged=lambda tokens, state, pages, pos, length, **kw:
            transformer.prefill_paged(cfg, tokens, state, pages, pos,
                                      length, **kw),
        paged_state_init=lambda b, nb, bs, dt=jnp.bfloat16:
            transformer.init_paged_kv_cache(cfg, nb, bs, dt),
        paged_state_specs=lambda b, nb, bs, dt=jnp.bfloat16:
            transformer.paged_kv_cache_specs(cfg, nb, bs, dt),
    )


def _ssm_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        forward=lambda tokens, **kw: mamba.forward(cfg, tokens, **kw),
        loss_fn=lambda tokens, labels, **kw: mamba.loss_fn(
            cfg, tokens, labels, **kw),
        decode_step=lambda tokens, state, pos, **kw: mamba.decode_step(
            cfg, tokens, state, pos, **kw),
        # SSM state is O(1) in seq; max_seq arg ignored
        decode_state_specs=lambda b, s, dt=jnp.bfloat16:
            mamba.state_specs(cfg, b, dt),
        decode_state_init=lambda b, s, dt=jnp.bfloat16:
            mamba.init_state(cfg, b, dt),
        prefill=lambda tokens, state, pos, length, **kw:
            mamba.prefill(cfg, tokens, state, pos, length, **kw),
        # O(1) recurrent state: nothing to page, nothing to prefix-share
        cache_spec=CacheSpec(
            kind="recurrent",
            tp_note="h shards on SSD heads, conv on channels when "
                    "divisible; else replicates (O(1) per slot)"),
    )


def _hybrid_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        forward=lambda tokens, **kw: hybrid.forward(cfg, tokens, **kw),
        loss_fn=lambda tokens, labels, **kw: hybrid.loss_fn(
            cfg, tokens, labels, **kw),
        decode_step=lambda tokens, state, pos, **kw: hybrid.decode_step(
            cfg, tokens, state, pos, **kw),
        decode_state_specs=lambda b, s, dt=jnp.bfloat16:
            hybrid.state_specs(cfg, b, s, dt),
        decode_state_init=lambda b, s, dt=jnp.bfloat16:
            hybrid.init_state(cfg, b, s, dt),
        prefill=lambda tokens, state, pos, length, **kw:
            hybrid.prefill(cfg, tokens, state, pos, length, **kw),
        # paged KV at attention sites; prefix reuse is unsound (the SSM
        # state must still absorb every prompt token)
        cache_spec=CacheSpec(
            kind="hybrid", paged=True, prefix_reuse=False,
            tp_note="per-site KV pools shard on kv heads; dense SSM h "
                    "shards on SSD heads and conv windows on channels; "
                    "any indivisible dim replicates — recurrent state is "
                    "O(1) per slot, so replication costs bytes, not "
                    "per-token bandwidth"),
        prefill_paged=lambda tokens, state, pages, pos, length, **kw:
            hybrid.prefill_paged(cfg, tokens, state, pages, pos, length,
                                 **kw),
        paged_state_init=lambda b, nb, bs, dt=jnp.bfloat16:
            hybrid.init_paged_state(cfg, b, nb, bs, dt),
        paged_state_specs=lambda b, nb, bs, dt=jnp.bfloat16:
            hybrid.paged_state_specs(cfg, b, nb, bs, dt),
    )


def _audio_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        forward=lambda tokens, frames=None, **kw: whisper.forward(
            cfg, tokens, frames, **kw),
        loss_fn=lambda tokens, labels, frames=None, **kw: whisper.loss_fn(
            cfg, tokens, labels, frames, **kw),
        decode_step=lambda tokens, state, pos, **kw: whisper.decode_step(
            cfg, tokens, state, pos, **kw),
        decode_state_specs=lambda b, s, dt=jnp.bfloat16:
            whisper.state_specs(cfg, b, s, dt),
        decode_state_init=None,  # requires frames; use whisper.init_decode_state
        cache_spec=CacheSpec(kind="cross"),
    )


_FAMILY_API = {
    "dense": _lm_api,
    "vlm": _lm_api,
    "moe": _lm_api,
    "ssm": _ssm_api,
    "hybrid": _hybrid_api,
    "audio": _audio_api,
}


def get_model(cfg: ModelConfig) -> ModelApi:
    try:
        return _FAMILY_API[cfg.family](cfg)
    except KeyError as e:
        raise ValueError(f"no model family {cfg.family!r}") from e
