"""Mamba-2 (SSD / state-space duality, arXiv:2405.21060) block and LM.

The block follows the Mamba-2 reference: fused in-projection to
(z, xBC, dt), short causal depthwise conv on (x, B, C), SSD scan with scalar
per-head decay, gated RMSNorm, out-projection. The SSD itself dispatches
through :mod:`repro.kernels.ops` (Pallas kernel on TPU, chunked-jnp on XLA).

Decode carries O(1) state per layer: the SSD state (B, H, P, N) fp32 and the
conv ring buffer (B, conv-1, conv_ch) — no KV cache, which is why this family
runs the long_500k cell.

Paged serving (PR 2): this family deliberately has NO paged variant — both
state leaves are O(1) in sequence, so there is nothing to page, and prompt-
prefix reuse is unsound (skipped tokens would skip their state updates; the
cache does not capture them the way a KV cache does). The registry records
this as ``CacheSpec(kind="recurrent")`` and the engine keeps the dense
per-slot layout. In the hybrid family the same recurrent leaves ride dense
alongside the paged per-site KV pools (see ``hybrid.init_paged_state``).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

import repro.core as nn
from repro.core import context as _ctx
from repro.core import functions as F
from repro.core import initializer as I
from repro.core import parametric as PF
from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain, named_zeros
from repro.kernels import ops as K
from repro.models import transformer as T


def _dims(cfg: ModelConfig, d: int) -> tuple[int, int, int, int, int, int]:
    d_inner = cfg.ssm_expand * d
    H = d_inner // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    conv_ch = d_inner + 2 * G * N
    return d_inner, H, P, G, N, conv_ch


def _block_params(cfg: ModelConfig, d: int, name: str):
    d_inner, H, P, G, N, conv_ch = _dims(cfg, d)
    cdt = _ctx.get_default_context().policy.compute_dtype
    if cfg.ssm_split_proj:
        # TP-clean layout: separate projections so the model-axis shards
        # never straddle the z/x/B/C split points (kills the per-layer
        # resharding all-gathers of the fused kernel).
        w_z = nn.get_parameter_or_create(
            f"{name}_z/kernel", (d, d_inner), I.lecun_normal())
        w_x = nn.get_parameter_or_create(
            f"{name}_x/kernel", (d, d_inner), I.lecun_normal())
        w_bc = nn.get_parameter_or_create(
            f"{name}_bc/kernel", (d, 2 * G * N), I.lecun_normal())
        w_dt = nn.get_parameter_or_create(
            f"{name}_dtp/kernel", (d, H), I.lecun_normal())
        conv_x = nn.get_parameter_or_create(
            f"{name}_convx/W", (d_inner, 1, cfg.ssm_conv), I.uniform_fanin())
        conv_bc = nn.get_parameter_or_create(
            f"{name}_convbc/W", (2 * G * N, 1, cfg.ssm_conv),
            I.uniform_fanin())
        conv_b = nn.get_parameter_or_create(
            f"{name}_conv/b", (conv_ch,), I.zeros())
        A_log = nn.get_parameter_or_create(
            f"{name}_A_log", (H,), I.uniform(1.0), dtype=jnp.float32)
        Dskip = nn.get_parameter_or_create(
            f"{name}_D", (H,), I.ones(), dtype=jnp.float32)
        dt_bias = nn.get_parameter_or_create(
            f"{name}_dt_bias", (H,), I.zeros(), dtype=jnp.float32)
        gamma = nn.get_parameter_or_create(
            f"{name}_norm/gamma", (d_inner,), I.ones(), dtype=jnp.float32)
        w_out = nn.get_parameter_or_create(
            f"{name}_out/kernel", (d_inner, d), I.scaled_normal(1.0, d_inner))
        return dict(split=True, w_z=w_z.astype(cdt), w_x=w_x.astype(cdt),
                    w_bc=w_bc.astype(cdt), w_dt=w_dt.astype(cdt),
                    conv_x=conv_x.astype(cdt), conv_bc=conv_bc.astype(cdt),
                    conv_b=conv_b.astype(cdt), A_log=A_log, D=Dskip,
                    dt_bias=dt_bias, gamma=gamma, w_out=w_out.astype(cdt))
    w_in = nn.get_parameter_or_create(
        f"{name}_in/kernel", (d, 2 * d_inner + 2 * G * N + H),
        I.lecun_normal())
    conv_w = nn.get_parameter_or_create(
        f"{name}_conv/W", (conv_ch, 1, cfg.ssm_conv), I.uniform_fanin())
    conv_b = nn.get_parameter_or_create(
        f"{name}_conv/b", (conv_ch,), I.zeros())
    A_log = nn.get_parameter_or_create(
        f"{name}_A_log", (H,), I.uniform(1.0), dtype=jnp.float32)
    Dskip = nn.get_parameter_or_create(
        f"{name}_D", (H,), I.ones(), dtype=jnp.float32)
    dt_bias = nn.get_parameter_or_create(
        f"{name}_dt_bias", (H,), I.zeros(), dtype=jnp.float32)
    gamma = nn.get_parameter_or_create(
        f"{name}_norm/gamma", (d_inner,), I.ones(), dtype=jnp.float32)
    w_out = nn.get_parameter_or_create(
        f"{name}_out/kernel", (d_inner, d), I.scaled_normal(1.0, d_inner))
    return dict(split=False, w_in=w_in.astype(cdt), conv_w=conv_w.astype(cdt),
                conv_b=conv_b.astype(cdt), A_log=A_log, D=Dskip,
                dt_bias=dt_bias, gamma=gamma, w_out=w_out.astype(cdt))


def _split_proj(cfg, d, zxbcdt):
    d_inner, H, P, G, N, conv_ch = _dims(cfg, d)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_ch]
    dt = zxbcdt[..., d_inner + conv_ch:]
    return z, xBC, dt


def _in_proj_step(cfg: ModelConfig, p: dict[str, Any], x):
    """Decode/prefill in-projection: x (B, S, d) -> (z, xBC, dt, conv_w),
    unifying the split and fused parameter layouts."""
    if p["split"]:
        z = jnp.einsum("bsd,dk->bsk", x, p["w_z"])
        xBC = jnp.concatenate(
            [jnp.einsum("bsd,dk->bsk", x, p["w_x"]),
             jnp.einsum("bsd,dk->bsk", x, p["w_bc"])], axis=-1)
        dt = jnp.einsum("bsd,dk->bsk", x, p["w_dt"])
        conv_w = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=0)
    else:
        zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["w_in"])
        z, xBC, dt = _split_proj(cfg, x.shape[-1], zxbcdt)
        conv_w = p["conv_w"]
    return z, xBC, dt, conv_w


def _gated_norm(y, z, gamma, eps=1e-6):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * lax.rsqrt(ms + eps) * gamma).astype(y.dtype)


def _causal_dwconv(x_t, w, conv_k):
    """x_t (B, ch, S) fp32; w (ch, 1, k) -> (B, ch, S) fp32.

    Written as k shifted multiply-adds instead of lax.conv: identical math
    (k is 4), but elementwise ops partition transparently under SPMD — the
    conv op was getting replicated across the mesh (the 30 GiB temp spike).
    """
    S = x_t.shape[-1]
    xp = jnp.pad(x_t, ((0, 0), (0, 0), (conv_k - 1, 0)))
    out = jnp.zeros_like(x_t)
    for j in range(conv_k):
        out = out + xp[:, :, j:j + S] * w[:, 0, j][None, :, None]
    return out


def mamba2_block(cfg: ModelConfig, x, *, name: str = "mamba"):
    """Full-sequence SSD block. x (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    d_inner, H, P, G, N, conv_ch = _dims(cfg, d)
    p = _block_params(cfg, d, name)

    if p["split"]:
        z = jnp.einsum("bsd,dk->bsk", x, p["w_z"])
        xs = jnp.einsum("bsd,dk->bsk", x, p["w_x"])
        bc = jnp.einsum("bsd,dk->bsk", x, p["w_bc"])
        dt = jnp.einsum("bsd,dk->bsk", x, p["w_dt"])
        z = constrain(z, "batch", "seq", "ssm_inner")
        xs = constrain(xs, "batch", "seq", "ssm_inner")
        cx = _causal_dwconv(jnp.swapaxes(xs, 1, 2).astype(jnp.float32),
                            p["conv_x"].astype(jnp.float32), cfg.ssm_conv)
        cbc = _causal_dwconv(jnp.swapaxes(bc, 1, 2).astype(jnp.float32),
                             p["conv_bc"].astype(jnp.float32), cfg.ssm_conv)
        cb = p["conv_b"].astype(jnp.float32)
        cx = cx + cb[:d_inner][None, :, None]
        cbc = cbc + cb[d_inner:][None, :, None]
        x_ssm = jnp.swapaxes(jax.nn.silu(cx).astype(x.dtype), 1, 2) \
            .reshape(B, S, H, P)
        bc_o = jnp.swapaxes(jax.nn.silu(cbc).astype(x.dtype), 1, 2)
        Bm = bc_o[..., :G * N].reshape(B, S, G, N)
        Cm = bc_o[..., G * N:].reshape(B, S, G, N)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    else:
        zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["w_in"])
        z, xBC, dt = _split_proj(cfg, d, zxbcdt)
        z = constrain(z, "batch", "seq", "ssm_inner")
        xBC = constrain(xBC, "batch", "seq", None)

        xBC_t = jnp.swapaxes(xBC, 1, 2).astype(jnp.float32)   # (B, ch, S)
        conv = _causal_dwconv(xBC_t, p["conv_w"].astype(jnp.float32),
                              cfg.ssm_conv)
        conv = conv + p["conv_b"].astype(jnp.float32)[None, :, None]
        xBC = jax.nn.silu(conv).astype(x.dtype)
        xBC = jnp.swapaxes(xBC, 1, 2)                         # (B, S, ch)

        x_ssm = xBC[..., :d_inner].reshape(B, S, H, P)
        Bm = xBC[..., d_inner:d_inner + G * N].reshape(B, S, G, N)
        Cm = xBC[..., d_inner + G * N:].reshape(B, S, G, N)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    x_ssm = constrain(x_ssm, "batch", "seq", "heads", None)
    y = K.ssd(x_ssm, dt, A, Bm, Cm, p["D"], chunk=cfg.ssm_chunk,
              unroll=cfg.scan_unroll is True)
    y = constrain(y, "batch", "seq", "heads", None)
    y = y.reshape(B, S, d_inner)

    y = _gated_norm(y, z, p["gamma"])
    y = constrain(y, "batch", "seq", "ssm_inner")
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    return constrain(out, "batch", "seq", "embed")


def mamba2_block_step(cfg: ModelConfig, x, state: dict[str, Any],
                      *, name: str = "mamba"):
    """Single-token step. x (B, 1, d); state {"h": (B,H,P,N) f32,
    "conv": (B, conv-1, conv_ch)}. Returns (out, new_state)."""
    B, S, d = x.shape
    assert S == 1
    d_inner, H, P, G, N, conv_ch = _dims(cfg, d)
    p = _block_params(cfg, d, name)
    z, xBC, dt, conv_w = _in_proj_step(cfg, p, x)

    window = jnp.concatenate([state["conv"], xBC.astype(state["conv"].dtype)],
                             axis=1)                      # (B, conv, ch)
    w = jnp.swapaxes(conv_w[:, 0, :], 0, 1)               # (kernel, ch)
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      w.astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xBC_o = jax.nn.silu(conv)[:, None, :].astype(x.dtype)  # (B,1,ch)
    new_conv = window[:, 1:]

    x_t = xBC_o[:, 0, :d_inner].reshape(B, H, P)
    B_t = xBC_o[:, 0, d_inner:d_inner + G * N].reshape(B, G, N)
    C_t = xBC_o[:, 0, d_inner + G * N:].reshape(B, G, N)
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y_t, h_new = K.ssd_decode_step(state["h"], x_t, dt_t, A, B_t, C_t, p["D"])
    y = y_t.reshape(B, 1, d_inner)
    y = _gated_norm(y, z, p["gamma"])
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    return out, {"h": h_new, "conv": new_conv}


def mamba2_block_prefill(cfg: ModelConfig, x, state: dict[str, Any],
                         length: jax.Array, *, name: str = "mamba"):
    """Chunked-prefill step: absorb a (B, C, d) chunk carrying SSM state.

    ``state`` as in :func:`mamba2_block_step`; ``length`` (B,) counts the
    valid tokens per row (rows right-padded to C). The conv runs over the
    carried ring buffer concatenated with the chunk, and the SSD continues
    from ``state["h"]``. Pads are neutralized by forcing dt -> 0 (decay 1,
    zero input: an identity state transition) and the new conv window is
    sliced per row to end at the last valid token.

    Returns (out (B, C, d) — pad positions garbage — and the new state).
    """
    B, C, d = x.shape
    d_inner, H, P, G, N, conv_ch = _dims(cfg, d)
    p = _block_params(cfg, d, name)
    z, xBC, dt, conv_w = _in_proj_step(cfg, p, x)

    # causal conv over [carried window | chunk] — same math as the decode
    # step's per-token window, C tokens at a time
    window = jnp.concatenate(
        [state["conv"], xBC.astype(state["conv"].dtype)], axis=1)
    wt = jnp.swapaxes(window, 1, 2).astype(jnp.float32)   # (B, ch, k-1+C)
    w = conv_w[:, 0, :].astype(jnp.float32)               # (ch, k)
    conv = jnp.zeros((B, conv_ch, C), jnp.float32)
    for j in range(cfg.ssm_conv):
        conv = conv + wt[:, :, j:j + C] * w[:, j][None, :, None]
    conv = conv + p["conv_b"].astype(jnp.float32)[None, :, None]
    xBC_o = jnp.swapaxes(jax.nn.silu(conv), 1, 2).astype(x.dtype)  # (B,C,ch)

    # next chunk's window: the k-1 entries ending at each row's last valid
    # token (pads live past index length + k - 2, so they never enter)
    new_conv = jax.vmap(
        lambda row, l: lax.dynamic_slice(
            row, (l, 0), (cfg.ssm_conv - 1, conv_ch)))(
        window, jnp.asarray(length, jnp.int32))

    x_ssm = xBC_o[..., :d_inner].reshape(B, C, H, P)
    Bm = xBC_o[..., d_inner:d_inner + G * N].reshape(B, C, G, N)
    Cm = xBC_o[..., d_inner + G * N:].reshape(B, C, G, N)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    valid = jnp.arange(C)[None, :] < jnp.asarray(length, jnp.int32)[:, None]
    dtf = dtf * valid[..., None].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])

    ck = cfg.ssm_chunk if C % cfg.ssm_chunk == 0 else C
    y, h_new = K.ssd(x_ssm, dtf, A, Bm, Cm, p["D"], chunk=min(ck, C),
                     h0=state["h"], return_state=True,
                     unroll=cfg.scan_unroll is True)
    y = y.reshape(B, C, d_inner)
    y = _gated_norm(y, z, p["gamma"])
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    return out, {"h": h_new, "conv": new_conv}


# --------------------------------------------------------------------------- #
# pure-SSM LM (mamba2-370m)
# --------------------------------------------------------------------------- #

def forward(cfg: ModelConfig, tokens, positions=None, last_only: bool = False):
    del positions
    x = T.embed_tokens(cfg, tokens)

    def block(h, idx):
        return h + mamba2_block(cfg, T.norm(cfg, h, "ln"))

    x = nn.layer_stack("layers", cfg.n_layers, block, x, remat=cfg.remat,
                       unroll=cfg.scan_unroll)
    if last_only:
        x = x[:, -1:]
    x = T.norm(cfg, x, "ln_final")
    return T.lm_head(cfg, x), jnp.zeros((), jnp.float32)


def forward_hidden(cfg: ModelConfig, tokens):
    x = T.embed_tokens(cfg, tokens)

    def block(h, idx):
        return h + mamba2_block(cfg, T.norm(cfg, h, "ln"))

    x = nn.layer_stack("layers", cfg.n_layers, block, x, remat=cfg.remat,
                       unroll=cfg.scan_unroll)
    return T.norm(cfg, x, "ln_final")


def loss_fn(cfg: ModelConfig, tokens, labels, positions=None):
    if cfg.loss_chunk:
        x = forward_hidden(cfg, tokens)
        return T.ce_from_hidden_chunked(cfg, x, labels, cfg.loss_chunk)
    logits, _ = forward(cfg, tokens)
    return jnp.mean(F.softmax_cross_entropy(logits, labels))


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16
               ) -> dict[str, Any]:
    d_inner, H, P, G, N, conv_ch = _dims(cfg, cfg.d_model)
    L = cfg.n_layers
    # under a tensor-parallel serving env: h shards on its SSD-head dim and
    # the conv window on channels when divisible, else replicates (the
    # state is O(1) per slot — replication costs bytes, not bandwidth)
    return {"h": named_zeros(("layers", "batch", "heads", None, "state"),
                             (L, batch, H, P, N), jnp.float32),
            "conv": named_zeros(("layers", "batch", None, "conv_ch"),
                                (L, batch, cfg.ssm_conv - 1, conv_ch),
                                dtype)}


def state_specs(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    d_inner, H, P, G, N, conv_ch = _dims(cfg, cfg.d_model)
    L = cfg.n_layers
    return {"h": jax.ShapeDtypeStruct((L, batch, H, P, N), jnp.float32),
            "conv": jax.ShapeDtypeStruct((L, batch, cfg.ssm_conv - 1, conv_ch),
                                         dtype)}


def decode_step(cfg: ModelConfig, tokens, state: dict[str, Any],
                pos: jax.Array, positions=None):
    """tokens (B, 1); state from :func:`init_state`. Returns (logits, state)."""
    del pos, positions  # SSM state is position-free
    x = T.embed_tokens(cfg, tokens)

    def block(h, idx, layer_state):
        out, new_state = mamba2_block_step(cfg, T.norm(cfg, h, "ln"),
                                           layer_state)
        return h + out, new_state

    x, new_state = nn.layer_stack_with_output(
        "layers", cfg.n_layers, block, x, xs=state, unroll=cfg.scan_unroll)
    x = T.norm(cfg, x, "ln_final")
    return T.lm_head(cfg, x), new_state


def prefill(cfg: ModelConfig, tokens, state: dict[str, Any],
            pos: jax.Array, length: jax.Array, positions=None):
    """Chunked prefill: absorb a (B, C) prompt chunk into the SSM state in
    one fused call. ``pos`` is unused (the state is position-free); ``length``
    (B,) counts valid tokens per right-padded row. Returns logits (B, 1, V)
    at each row's last valid position plus the updated state."""
    del pos, positions
    length = jnp.asarray(length, jnp.int32)
    x = T.embed_tokens(cfg, tokens)

    def block(h, idx, layer_state):
        out, new_state = mamba2_block_prefill(
            cfg, T.norm(cfg, h, "ln"), layer_state, length)
        return h + out, new_state

    x, new_state = nn.layer_stack_with_output(
        "layers", cfg.n_layers, block, x, xs=state, unroll=cfg.scan_unroll)
    x = T.gather_last_valid(x, length)
    x = T.norm(cfg, x, "ln_final")
    return T.lm_head(cfg, x), new_state
