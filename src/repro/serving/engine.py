"""Serving runtime: continuous batching over a block-paged KV cache with
prefix reuse, chunked prefill and sampling.

A fixed-slot batch (compiled once per step shape); requests stream in and
out of slots without recompilation. Since PR 2 the KV cache is **paged**:

* instead of one dense ``(max_seq,)`` K/V region per slot, every attention
  layer/site owns a global pool of ``num_blocks`` fixed-size blocks
  (``block_size`` tokens, default 16) shared by all slots. Each slot holds
  a ``(max_blocks,)`` page table of block ids; the jitted step scatters new
  K/V through the table (:func:`repro.kernels.ops.paged_cache_write`) and
  attends through it (``attention_prefill_paged`` / ``attention_decode_paged``).
  Block 0 is a garbage block absorbing pad-column and idle-row writes, so
  the scatter needs no masking and nothing ever reads it.
* a slot therefore consumes blocks proportional to its request's **actual**
  length (prompt + max_new), not ``max_seq`` — and admission is gated on
  free blocks in the pool, not on worst-case slot capacity. Blocks return
  to the free list the moment a request completes.
* a **prefix cache** (vLLM-style, :mod:`repro.serving.paged`) keys each
  full prompt block by a chained 128-bit prefix digest; admission reuses
  cache-hit leading blocks by refcount (shared blocks are read-only —
  writes always start at or past the first private block, so copy-on-write
  degenerates to recomputing the partial tail block) and skips prefill
  over the hit tokens. Per-request skip counts land in ``metrics.prefix_hit_tokens``.
  Reuse is enabled only when the family's :class:`~repro.models.registry.
  CacheSpec` marks it sound (pure-KV families; recurrent/hybrid state must
  absorb every prompt token, so mamba/zamba run paged-KV without skipping).
* recurrent state (SSM ``h``, conv windows) stays dense per slot — it is
  O(1) in sequence — and is zeroed on slot reuse as before; families with
  no paged support at all (pure SSM, audio) fall back to the dense layout.
* since PR 3 the attention hot path is kernel-mode selectable
  (``kernels="pallas"`` on real TPUs walks the page table in VMEM with
  double-buffered block DMAs instead of the gather-then-dense XLA
  reference; ``"pallas_interpret"`` validates the same kernels on CPU).
  The override scopes only the engine's jitted step, not the process.
* since PR 4 one engine can span a **(data, model) mesh**: ``tp=N`` (or an
  explicit ``mesh=``) shards the params Megatron-style and the paged K/V
  pools on the kv-head axis (:mod:`repro.launch.serve_shardings` owns the
  policy), so every device holds ``1/tp`` of the KV bytes and the jitted
  step runs GSPMD-partitioned with explicit in/out shardings. All host-side
  machinery — allocator, page tables, prefix cache, scheduling — is
  layout-blind: block ids mean the same thing on every shard, page tables
  and positions replicate. Pallas kernel modes wrap the per-shard kernels
  in ``shard_map`` at the dispatch layer (each shard walks only its local
  pool slice, fused-scatter pool donation included); the default ``tp=1``
  builds no mesh at all and stays bitwise-identical to the single-device
  engine.

Scheduling policy lives in :mod:`repro.serving.scheduler` since PR 5: the
engine owns only the device-facing machinery (the jitted step, the
sharding env, metrics aggregation) and drives a host-side
:class:`~repro.serving.scheduler.Scheduler` that owns the queue, the
block allocator / prefix-cache handles and all per-slot bookkeeping.
Requests carry a ``priority`` class (higher = more urgent; FIFO within a
class, which makes the all-default case exactly the PR-1..4 FIFO), an
anti-starvation aging knob bounds queue wait, and under pool pressure the
scheduler preempts lower-priority actives block-by-block (requeue-as-
prefill — see the scheduler module docstring for the policy and its
rationale). Mechanically, prompts are absorbed ``chunk`` tokens per slot
per step through one fused ``prefill`` call (decode IS prefill with
C = 1), mixed (B, chunk)/(B, 1) steps, freed slots refilled with no
draining barrier. Two compiled shapes × greedy/sampled variants: at most
four compilations per engine.

Sampling: per-request temperature, top-k, top-p and PRNG seed (see
:mod:`repro.serving.sampling`), fused into the jitted step;
``temperature=0`` (default) is greedy argmax.

Speculative decoding (PR 6, ``spec_k > 0``): pure-decode steps widen
into ``(B, 1 + spec_k)`` *verify* steps. A zero-parameter n-gram
proposer (:mod:`repro.serving.speculative`) drafts tokens from each
request's own history; the drafts ride the existing chunked prefill
path with ``last_only=False``, whose chunk-causal logits verify every
draft position in one call; the host accepts the longest prefix of
drafts matching the per-position targets and emits them plus the
first-divergence target. Acceptance is exact-match against the tokens
the non-speculative engine would emit — greedy argmax, or the
per-``(seed, len(generated))`` PRNG draw — so the output stream is
**bitwise identical** to a ``spec_k=0`` run, always; drafts only change
how many steps it takes. Rejected drafts cost nothing to undo: their
K/V writes sit at positions ``>= pos`` that chunk-causal attention
never reads and the next step overwrites (``CacheSpec.spec_decode``
gates this on positional pure-KV state). Default ``spec_k=0`` — the
engine is byte-for-byte the PR-5 engine unless asked.

Quantized KV pools (PR 10, ``kv_dtype="int8"``/``"fp8"``): the paged
pools store int8 (or fp8 where the platform dtype exists) with
per-(token-slot, kv-head) float32 scale leaves riding alongside
(``"k_scale"``/``"v_scale"``). Quant fuses into the write scatter,
dequant into the attention walk (in VMEM on the Pallas path) — no
dequantized pool ever materializes in HBM, and every host-side
subsystem (allocator, tiering spill/fetch, prefix store, tp sharding)
carries the scale leaves automatically because they are ordinary KV
leaves with the block axis at 1. Resolution order for the dtype:
explicit ``kv_dtype=`` > ``$REPRO_KV_DTYPE`` > ``CacheSpec.kv_dtype``.
``kv_bytes_per_token()`` reports the realized per-token HBM cost
(pool + scales); at D = 64, int8 is ~0.53x of bf16.

Per-request metrics on ``Request.metrics``: queue wait, time-to-first-
token, decode tokens/s, prefill/decode step counts, prefix-hit tokens.
Accessors are NaN-safe — reading ``ttft`` before the first token lands or
``decode_tok_per_s`` of a single-token generation returns ``nan``, never a
garbage epoch delta or a fake 0.0.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import os
import time
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as nn
from repro.core import context as _ctx
from repro.distributed import sharding as _sh
from repro.kernels import quant
from repro.models.registry import ModelApi
from repro.serving import sampling
from repro.serving.scheduler import Scheduler

# Every KV-pool leaf key: the quantized pools carry per-(slot, head)
# scale arrays next to the int8/fp8 payload. One tuple feeds both
# consumers — _is_kv_leaf (spill/fetch, layout fingerprint, byte
# accounting) and _admit's recurrent-state reset skip — so a new leaf
# kind can never be spilled but not reset-protected (or vice versa).
_KV_KEYS = ("k", "v", "k_scale", "v_scale")


@dataclasses.dataclass
class RequestMetrics:
    submit_t: float = 0.0       # time.monotonic at submit()
    admit_t: float = 0.0        # latest admission into a slot
    first_token_t: float = 0.0  # first sampled token appended
    done_t: float = 0.0
    prefill_steps: int = 0
    decode_steps: int = 0
    prefix_hit_tokens: int = 0  # prompt tokens skipped via the prefix cache
    host_hit_tokens: int = 0    # ... of which were fetched from the host tier
    preemptions: int = 0        # times this request was evicted mid-flight
    # sum of per-stint queue waits (submit->admit plus every re-admit gap),
    # maintained by Scheduler.admit; NaN until first admitted
    queued_s: float = float("nan")
    spec_proposed: int = 0      # draft tokens this request verified
    spec_accepted: int = 0      # ... of which matched the token stream

    @property
    def queue_wait(self) -> float:
        """Total time spent queued, summed over stints — a preempted
        request's time *running* between stints is service, not wait.
        NaN until the request is admitted. Falls back to the single-stint
        ``admit_t - submit_t`` when the stint accumulator never ran (e.g.
        metrics objects populated by hand)."""
        if not math.isnan(self.queued_s):
            return self.queued_s
        if self.admit_t == 0.0 or self.submit_t == 0.0:
            return float("nan")
        return self.admit_t - self.submit_t

    @property
    def ttft(self) -> float:
        """Time to first token, from submit; NaN until that token lands."""
        if self.first_token_t == 0.0 or self.submit_t == 0.0:
            return float("nan")
        return self.first_token_t - self.submit_t

    def decode_tok_per_s(self, n_generated: int) -> float:
        """Steady-state decode rate; NaN when undefined (single-token
        generations have no decode interval, unfinished requests no span).
        """
        if n_generated <= 1:
            return float("nan")
        dt = self.done_t - self.first_token_t
        if not dt > 0.0:
            return float("nan")
        return (n_generated - 1) / dt


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    # scheduling class: higher = more urgent; FIFO within a class. The
    # default 0 everywhere reproduces plain FIFO admission exactly.
    priority: int = 0
    # sampling knobs: temperature 0 = greedy; top_k <= 0 / top_p >= 1 disable
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int | None = None     # None -> uid; PRNG is per (seed, token index)
    # streaming hook: called from inside the step loop every time this
    # request emits tokens — ``on_tokens(req, new_tokens, done)`` with the
    # tokens appended THIS step (>= 1; a speculative verify step can emit
    # several) and whether the request just completed. The callback runs
    # on whichever thread drives the engine (the frontend's worker thread
    # wraps it in call_soon_threadsafe to reach asyncio consumers); it
    # must be cheap and must not touch the engine. None = no streaming.
    on_tokens: Any = dataclasses.field(default=None, repr=False)
    # filled by the engine
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # set by Scheduler.submit when the prompt was clipped to max_seq - 1:
    # the response continues a truncated prompt, not the one submitted
    truncated: bool = False
    # terminal failure reason, set before the final on_tokens fires:
    # deadline expiry, worker crash, failed migration, abandoned drain
    error: str | None = None
    # set by Router.harvest when a dead replica's request was moved to a
    # survivor and resumed through the requeue-as-prefill path
    migrated: bool = False
    metrics: RequestMetrics = dataclasses.field(default_factory=RequestMetrics)


class ServingEngine:
    def __init__(self, api: ModelApi, params: dict[str, Any], *,
                 max_batch: int = 4, max_seq: int = 256, chunk: int = 16,
                 cache_dtype=jnp.float32, paged: bool | None = None,
                 block_size: int = 16, num_blocks: int | None = None,
                 prefix_cache: bool = True,
                 kernels: _ctx.KernelMode | None = None,
                 mesh=None, tp: int | None = None,
                 scheduler: str = "priority", aging_s: float = 0.0,
                 preemption: bool = True,
                 spec_k: int = 0, spec_ngram: int = 3,
                 host_cache_blocks: int | None = None,
                 host_cache_gb: float = 0.0, kv_store: str | None = None,
                 kv_dtype: str | None = None):
        self.api = api
        self.params = params
        # tensor parallelism: tp=N builds a (1, N) (data, model) host mesh
        # (or pass an explicit mesh with a "model" axis). tp=1 / no mesh is
        # the unchanged single-device engine — no env, no device_put, the
        # exact pre-mesh trace.
        if tp is not None and tp < 1:
            raise ValueError(f"tp must be >= 1, got {tp}")
        if mesh is None and tp is not None and tp > 1:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh(tp)
        elif mesh is not None:
            if "model" not in mesh.axis_names:
                raise ValueError("serving mesh needs a 'model' axis, got "
                                 f"{mesh.axis_names}")
            if tp is not None and tp != mesh.shape["model"]:
                raise ValueError(
                    f"tp={tp} conflicts with the mesh's model axis of "
                    f"{mesh.shape['model']} — pass one or the other")
        self.mesh = mesh
        self.tp = int(mesh.shape["model"]) if mesh is not None else 1
        if mesh is not None:
            from repro.launch.serve_shardings import make_serve_env
            self._env = make_serve_env(mesh, api.cfg)
            with _sh.sharding_env(self._env):
                self.params = jax.device_put(
                    params, _sh.params_shardings(params))
        else:
            self._env = None
        # kernel-mode override for the jitted step (None = ambient context):
        # "pallas" runs the paged-attention page-table walk on real TPUs,
        # "pallas_interpret" the same kernel logic on CPU, "xla*" the
        # gather-then-dense references. Reject typos here, at the boundary —
        # an unknown string would otherwise dispatch to compiled Pallas and
        # die deep inside Mosaic lowering.
        if kernels and kernels not in _ctx.KERNEL_MODES:
            raise ValueError(f"unknown kernels mode {kernels!r}; "
                             f"one of {_ctx.KERNEL_MODES}")
        self.kernels = kernels
        self.B = max_batch
        self.max_seq = max_seq
        # APIs without a prefill entry fall back to one-token absorption
        # (a C=1 prefill is exactly one decode step)
        self.chunk = max(1, int(chunk)) if api.prefill is not None else 1
        self._prefill_fn = api.prefill if api.prefill is not None else (
            lambda t, s, p, l: api.decode_step(t, s, p))
        self.completed: list[Request] = []
        # incremented by a crashing EngineWorker so the failure is visible
        # in metrics_summary even when the dead replica completed nothing
        self.worker_crashed = 0

        can_page = api.prefill_paged is not None and api.cache_spec.paged
        self.paged = can_page if paged is None else (paged and can_page)
        # paged-pool storage dtype: explicit arg > $REPRO_KV_DTYPE > the
        # family default in CacheSpec.kv_dtype. "int8"/"fp8" allocate
        # quantized pools with per-(slot, head) scale leaves (see
        # :mod:`repro.kernels.quant`); "native" keeps cache_dtype. Dense
        # fallback engines ignore the knob — quantization is a paged-pool
        # layout, the dense cache always stays in the compute dtype.
        if kv_dtype is None:
            kv_dtype = (os.environ.get("REPRO_KV_DTYPE")
                        or api.cache_spec.kv_dtype)
        self.kv_pool_dtype = (quant.resolve_kv_dtype(kv_dtype, cache_dtype)
                              if self.paged else jnp.dtype(cache_dtype))
        self.kv_dtype = quant.kv_dtype_name(self.kv_pool_dtype)
        # tiered KV cache: a host-RAM pool cold registered prefixes spill
        # into instead of being dropped (and a disk store for warm
        # restarts). Only meaningful where the prefix cache itself is —
        # paged engines of prefix_reuse families.
        tiering_ok = (self.paged and prefix_cache
                      and api.cache_spec.prefix_reuse)
        host_blocks = 0
        if tiering_ok:
            if host_cache_blocks is not None:
                host_blocks = int(host_cache_blocks)
            elif host_cache_gb > 0:
                from repro.serving.tiering import blocks_for_bytes
                host_blocks = blocks_for_bytes(
                    host_cache_gb,
                    self._per_block_bytes(block_size, self.kv_pool_dtype))
            elif kv_store:
                # a persistent store with no explicit host sizing still
                # needs a host tier to warm-load into: default to 4x the
                # usable HBM pool (the "~10x effective capacity" lever
                # scales with this knob, not a magic constant)
                mb = -(-(max_seq + self.chunk) // block_size)
                nb = (num_blocks if num_blocks is not None
                      else max_batch * mb + 1)
                host_blocks = 4 * (nb - 1)
        self._kv_store = kv_store if tiering_ok else None
        # every scheduling decision — queue order, placement, eviction,
        # preemption — and all per-slot bookkeeping lives in the scheduler;
        # it is host-side and layout-blind, so tp=N engines construct it
        # identically to tp=1
        self.scheduler = Scheduler(
            max_batch=max_batch, max_seq=max_seq, chunk=self.chunk,
            paged=self.paged, block_size=block_size, num_blocks=num_blocks,
            prefix_cache=prefix_cache and api.cache_spec.prefix_reuse,
            policy=scheduler, aging_s=aging_s, preemption=preemption,
            host_cache_blocks=host_blocks)
        # speculative decoding: spec_k > 0 turns pure-decode steps into
        # (B, 1 + spec_k) verify steps over n-gram drafts. Sound only for
        # positional pure-KV state (CacheSpec.spec_decode) on the paged
        # path — rejecting here beats silently decoding a corrupt stream.
        if spec_k:
            if not (self.paged and api.cache_spec.spec_decode):
                raise ValueError(
                    f"spec_k={spec_k} needs a paged pure-KV cache: family "
                    f"{api.cfg.family!r} has paged={self.paged}, "
                    f"spec_decode={api.cache_spec.spec_decode} — "
                    f"speculative rollback cannot rewind recurrent state")
            from repro.serving.speculative import NgramProposer
            self.spec = NgramProposer(k=int(spec_k),
                                      max_ngram=int(spec_ngram))
        else:
            self.spec = None
        if self.paged:
            with self._env_scope():
                self.state = api.paged_state_init(
                    max_batch, self.scheduler.num_blocks,
                    self.scheduler.block_size, self.kv_pool_dtype)
            if host_blocks > 0:
                # the tiered cache is layout-blind; the engine — which
                # owns the pools — injects the block extract/insert I/O
                self.scheduler.prefix.bind_device_io(
                    self._extract_blocks, self._insert_blocks)
                if self._kv_store:
                    self._warm_restart()
            # 8 replicated metadata args: pages, pos, length + 5 sampling
            self._step = self._jit_step(self._step_paged_fn, n_meta=8)
            if self.spec is not None:
                self._step_spec = self._jit_step(self._step_spec_fn,
                                                 n_meta=8)
        else:
            # dense fallback: one (max_seq + chunk)-deep region per slot.
            # chunk-1 headroom: a C-wide cache write starting at pos <=
            # max_seq-1 must never clamp (pad columns past a row's valid
            # length would otherwise shift onto live entries)
            with self._env_scope():
                self.state = api.decode_state_init(
                    max_batch, max_seq + self.chunk, cache_dtype)
            self._step = self._jit_step(self._step_fn, n_meta=7)

    # ------------------------------------------------------------------ #
    # read-only views into the scheduler (benchmarks/tests introspect
    # these; the engine itself never touches allocator or prefix-cache
    # internals — that is the scheduler's job)
    # ------------------------------------------------------------------ #
    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def active(self):
        return self.scheduler.active

    @property
    def pos(self):
        return self.scheduler.pos

    @property
    def alloc(self):
        return self.scheduler.alloc

    @property
    def prefix(self):
        return self.scheduler.prefix

    @property
    def num_blocks(self):
        return self.scheduler.num_blocks

    @property
    def block_size(self):
        return self.scheduler.block_size

    @property
    def max_blocks(self):
        return self.scheduler.max_blocks

    # ------------------------------------------------------------------ #
    def _sample_or_greedy(self, logits, temps, top_k, top_p, seeds, counts,
                          do_sample):
        last = logits[:, -1, :].astype(jnp.float32)
        if do_sample:
            return sampling.sample(last, temps, top_k, top_p, seeds, counts)
        # all-greedy batch (the default): skip the (B, V) sort pipeline
        return jnp.argmax(last, axis=-1).astype(jnp.int32)

    def _env_scope(self):
        """The engine's ShardingEnv, active while building state and while
        TRACING the jitted step: ``constrain`` calls in the models and the
        shard_map wrapping in :mod:`repro.kernels.ops` both read the
        thread-local env at trace time. Null without a mesh."""
        if self._env is None:
            return contextlib.nullcontext()
        return _sh.sharding_env(self._env)

    def _kernel_scope(self):
        """Context overrides applied while TRACING the jitted step — kernel
        dispatch in :mod:`repro.kernels.ops` reads the ambient context at
        trace time, so scoping the trace pins the engine's kernel mode (and
        its serving mesh) regardless of what the caller's context says."""
        stack = contextlib.ExitStack()
        if self.kernels:              # None/"" -> ambient context
            stack.enter_context(_ctx.context_scope(dataclasses.replace(
                _ctx.get_default_context(), kernels=self.kernels)))
        stack.enter_context(self._env_scope())
        return stack

    def _jit_step(self, fn, *, n_meta: int):
        """Compile the step. Single-device engines keep the plain jit of
        PRs 1-3 (bitwise-identical trace). Under a mesh the step is pinned
        with explicit in/out shardings: params and state keep their
        placement fixed-point (no first-step reshard, no sharding drift
        between the state returned by step N and consumed by step N+1),
        tokens/pages/positions/sampling knobs and the sampled token
        replicate. ``n_meta`` counts those replicated metadata args."""
        if self._env is None:
            return jax.jit(fn, static_argnames=("do_sample",))
        from jax.sharding import NamedSharding, PartitionSpec
        repl = NamedSharding(self.mesh, PartitionSpec())

        def put(a):
            sh = getattr(a, "sharding", None)
            return sh if isinstance(sh, NamedSharding) else repl

        p_sh = jax.tree.map(put, self.params)
        s_sh = jax.tree.map(put, self.state)
        # jit rejects kwargs once in_shardings is given, so the static
        # do_sample flag is pre-bound: one jitted callee per variant
        # (exactly the two traces the single-device path compiles lazily)
        jitted = {
            ds: jax.jit(functools.partial(fn, do_sample=ds),
                        in_shardings=(p_sh, repl, s_sh) + (repl,) * n_meta,
                        out_shardings=(repl, s_sh))
            for ds in (False, True)
        }
        return lambda *args, do_sample: jitted[do_sample](*args)

    def tp_layout(self) -> dict[str, str]:
        """Realized state placement (leaf path -> spec or "replicated");
        {} for single-device engines. See ``CacheSpec.tp_note`` for the
        per-family rationale behind replicated leaves."""
        if self._env is None:
            return {}
        from repro.launch.serve_shardings import state_layout
        return state_layout(self.state)

    # ------------------------------------------------------------------ #
    # tiered-cache device I/O and persistence: the TieredPrefixCache is
    # layout-blind, so the engine — owner of the pools — provides the
    # hooks that move block contents between HBM and host numpy, and the
    # layout descriptor the disk store checks compatibility against.
    # ------------------------------------------------------------------ #
    @staticmethod
    def _is_kv_leaf(path) -> bool:
        """KV pool leaves are keyed by ``_KV_KEYS`` — payload pools plus
        the quantized pools' scale arrays, the same set _admit's
        recurrent-state reset skips. Their block axis is axis 1:
        ``(n_layers, num_blocks, block_size, n_kv_heads, head_dim)`` for
        pools, ``(n_layers, num_blocks, block_size, n_kv_heads)`` for
        scales — so spill/fetch/layout code slicing axis 1 covers both."""
        last = path[-1]
        return (isinstance(last, jax.tree_util.DictKey)
                and last.key in _KV_KEYS)

    def _per_block_bytes(self, block_size: int, pool_dtype) -> int:
        """Host-RAM bytes one spilled block occupies across every KV pool
        leaf — scale arrays included for quantized pools (sizes
        ``--host-cache-gb`` into a block count). Computed from specs with
        a 2-block probe pool — no device allocation."""
        specs = self.api.paged_state_specs(1, 2, block_size, pool_dtype)
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
            if self._is_kv_leaf(path) and leaf.shape[1] == 2:
                total += (int(np.prod(leaf.shape)) // 2
                          * np.dtype(leaf.dtype).itemsize)
        return total

    def _extract_blocks(self, bids: list[int]) -> dict[str, np.ndarray]:
        """Pull blocks ``bids`` of every KV leaf to host numpy, stacked on
        axis 1 (one gather per leaf for the whole batch — the spill path
        calls this once per eviction pass). ``copy_to_host_async`` is a
        best-effort overlap hint: real on TPU/GPU, a no-op on CPU jax."""
        idx = jnp.asarray(bids, jnp.int32)
        subs: list[tuple[str, Any]] = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.state)[0]:
            if self._is_kv_leaf(path):
                sub = leaf[:, idx]
                try:
                    sub.copy_to_host_async()
                except (AttributeError, RuntimeError):
                    pass
                subs.append((jax.tree_util.keystr(path), sub))
        return {k: np.asarray(v) for k, v in subs}

    def _insert_blocks(self, bids: list[int],
                       data: dict[str, np.ndarray]) -> None:
        """Write host block data back into freshly allocated HBM blocks
        (one scatter per leaf for the whole fetched chain). Under a mesh
        the scatter result is pinned back to the leaf's sharding so the
        state's placement fixed-point survives the update."""
        idx = jnp.asarray(bids, jnp.int32)

        def put(path, leaf):
            if not self._is_kv_leaf(path):
                return leaf
            arr = jnp.asarray(data[jax.tree_util.keystr(path)], leaf.dtype)
            new = leaf.at[:, idx].set(arr)
            if self.mesh is not None:
                new = jax.device_put(new, leaf.sharding)
            return new

        self.state = jax.tree_util.tree_map_with_path(put, self.state)

    def kv_bytes_per_token(self) -> float:
        """HBM bytes one cached token costs across every KV leaf — pools
        plus scale arrays for quantized dtypes, summed over layers/sites.
        Pure spec arithmetic (no device reads); NaN for dense engines.
        ``bench_serving --quant`` reports this and ``compare.py`` gates
        it lower-is-better."""
        if not self.paged:
            return float("nan")
        bs = self.scheduler.block_size
        return self._per_block_bytes(bs, self.kv_pool_dtype) / bs

    def kv_layout(self) -> dict:
        """The pool layout the disk store records and checks on load: a
        store written under any other block size, family, dtype or leaf
        geometry is unusable bytes and must fail the warm restart."""
        leaves = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.state)[0]:
            if self._is_kv_leaf(path):
                shape = list(leaf.shape[:1]) + list(leaf.shape[2:])
                leaves[jax.tree_util.keystr(path)] = [
                    shape, str(np.dtype(leaf.dtype))]
        return {"block_size": self.scheduler.block_size,
                "kind": self.api.cache_spec.kind,
                "family": self.api.cfg.family,
                "leaves": leaves}

    def save_kv_store(self) -> int:
        """Persist every registered prefix block — both tiers — to the
        ``kv_store`` directory (atomic, CRC'd, layout-stamped). Returns
        the number of entries written; 0 when no store is configured."""
        if not self._kv_store:
            return 0
        from repro.checkpoint.manager import PrefixStore
        entries = self.scheduler.prefix.snapshot()
        PrefixStore(self._kv_store).save(entries, self.kv_layout())
        return len(entries)

    def _warm_restart(self) -> None:
        """Load a previous run's prefix store into the HOST tier. Any
        failure — missing, corrupt, layout mismatch — means serve cold;
        a stale store must never crash startup."""
        from repro.checkpoint.manager import PrefixStore
        try:
            entries = PrefixStore(self._kv_store).load(self.kv_layout())
        except FileNotFoundError:
            return          # first run: nothing to warm from
        except Exception as e:   # corrupt npz/meta, CRC, layout mismatch
            warnings.warn(
                f"kv-store {self._kv_store!r} unusable ({e}); serving cold",
                RuntimeWarning)
            return
        self.scheduler.prefix.preload_host(entries)

    def _step_fn(self, params, tokens, state, pos, length,
                 temps, top_k, top_p, seeds, counts, *, do_sample):
        with self._kernel_scope():
            logits, new_state = nn.apply(
                lambda t, s, p, l: self._prefill_fn(t, s, p, l),
                params, tokens, state, pos, length)
        next_tok = self._sample_or_greedy(logits, temps, top_k, top_p,
                                          seeds, counts, do_sample)
        return next_tok, new_state

    def _step_paged_fn(self, params, tokens, state, pages, pos, length,
                       temps, top_k, top_p, seeds, counts, *, do_sample):
        with self._kernel_scope():
            logits, new_state = nn.apply(
                lambda t, s, g, p, l: self.api.prefill_paged(t, s, g, p, l),
                params, tokens, state, pages, pos, length)
        next_tok = self._sample_or_greedy(logits, temps, top_k, top_p,
                                          seeds, counts, do_sample)
        return next_tok, new_state

    def _step_spec_fn(self, params, tokens, state, pages, pos, length,
                      temps, top_k, top_p, seeds, cnt0, *, do_sample):
        """Speculative verify step: the same chunked paged prefill, but
        keeping the FULL (B, C, V) chunk-causal logits (``last_only=
        False``) and turning every position into a target token — the
        token non-speculative decoding would emit at that stream index
        (position i of row b draws with PRNG coordinate ``cnt0[b] + i``).
        The host compares drafts against targets and accepts the longest
        matching prefix; pad positions compute garbage targets nobody
        reads."""
        with self._kernel_scope():
            logits, new_state = nn.apply(
                lambda t, s, g, p, l: self.api.prefill_paged(
                    t, s, g, p, l, last_only=False),
                params, tokens, state, pages, pos, length)
        logits = logits.astype(jnp.float32)
        if do_sample:
            targets = sampling.sample_chunk(logits, temps, top_k, top_p,
                                            seeds, cnt0)
        else:
            targets = sampling.greedy_chunk(logits)
        return targets, new_state

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        """Enqueue a request (may raise when it can never fit the pool —
        see :meth:`Scheduler.submit`)."""
        self.scheduler.submit(req, time.monotonic())

    def resubmit(self, req: Request) -> None:
        """Adopt a request that already ran (and possibly generated
        tokens) on another engine — replica death, worker crash. Its
        generated tokens fold into a resume prompt via the scheduler's
        requeue-as-prefill path, so the continued stream is bitwise the
        uninterrupted one (see :meth:`Scheduler.resubmit`; raises
        ValueError when the resume prompt can no longer fit)."""
        self.scheduler.resubmit(req, time.monotonic())

    def cancel(self, uid: int) -> bool:
        """Drop a queued or active request by uid, freeing its blocks;
        False when the uid is unknown (already completed — benign)."""
        return self.scheduler.cancel(uid)

    def _admit(self, now: float) -> None:
        fresh = self.scheduler.admit(now)
        if fresh:
            idx = jnp.asarray(fresh, jnp.int32)
            # Zero the admitted rows of every *recurrent* state leaf so a
            # freed slot's SSM state can't leak forward (batch is axis 1,
            # see registry docstring). KV-cache leaves — _KV_KEYS, i.e.
            # "k"/"v" plus quantized pools' "k_scale"/"v_scale" — are
            # skipped: paged pools have no batch axis at all (axis 1 is
            # the BLOCK axis; zeroing a scale leaf there would corrupt
            # live blocks), and a dense cache is positionally overwritten
            # and length-masked.
            def reset(path, a):
                last = path[-1]
                if (isinstance(last, jax.tree_util.DictKey)
                        and last.key in _KV_KEYS):
                    return a
                return a.at[:, idx].set(0)
            self.state = jax.tree_util.tree_map_with_path(reset, self.state)

    def step(self) -> int:
        """One synchronized mixed prefill/decode step; returns #active."""
        sched = self.scheduler
        self._admit(time.monotonic())
        active_slots = [s for s, r in enumerate(sched.active)
                        if r is not None]
        if not active_slots:
            return 0
        prefilling = any(len(sched.pending_prompt[s]) > 1
                         for s in active_slots)
        if self.spec is not None and not prefilling:
            # no slot is mid-prompt: run the (B, 1 + spec_k) verify step
            # instead of a (B, 1) decode step. Prefill steps stay on the
            # plain path — bitwise identical to the non-speculative engine.
            return self._step_speculative(active_slots)
        C = self.chunk if prefilling else 1
        B = self.B
        tokens = np.zeros((B, C), np.int32)
        length = np.ones(B, np.int32)
        temps = np.zeros(B, np.float32)
        top_k = np.zeros(B, np.int32)
        top_p = np.ones(B, np.float32)
        seeds = np.zeros(B, np.int32)
        counts = np.zeros(B, np.int32)
        emits = [False] * B
        prompt_done = []
        for s in active_slots:
            req = sched.active[s]
            pend = sched.pending_prompt[s]
            if pend:
                k = min(C, len(pend))
                for i in range(k):
                    tokens[s, i] = pend.popleft()
                length[s] = k
                emits[s] = not pend   # prompt fully absorbed: sample now
                if not pend:
                    prompt_done.append(s)
                req.metrics.prefill_steps += 1
            else:
                tokens[s, 0] = (req.generated[-1] if req.generated
                                else (req.prompt[-1] if req.prompt else 0))
                emits[s] = True
                req.metrics.decode_steps += 1
            temps[s] = req.temperature
            top_k[s] = req.top_k
            top_p[s] = req.top_p
            # mask to 31 bits: callers often derive 64-bit seeds (hashes)
            seeds[s] = (req.seed if req.seed is not None
                        else req.uid) & 0x7FFFFFFF
            # count = tokens generated so far: a preempted-then-resumed
            # request keeps its generated list, so the per-(seed, count)
            # PRNG stream continues exactly where it left off
            counts[s] = len(req.generated)
        do_sample = any(temps[s] > 0.0 for s in active_slots)
        args = (self.params, jnp.asarray(tokens), self.state)
        if self.paged:
            args += (jnp.asarray(sched.pages),)
        next_tok, self.state = self._step(
            *args, jnp.asarray(sched.pos), jnp.asarray(length),
            jnp.asarray(temps), jnp.asarray(top_k), jnp.asarray(top_p),
            jnp.asarray(seeds), jnp.asarray(counts), do_sample=do_sample)
        next_tok = np.asarray(next_tok)
        now = time.monotonic()
        if self.paged:
            for s in prompt_done:
                sched.register_prompt_blocks(s)
        for s in active_slots:
            req = sched.active[s]
            sched.advance(s, int(length[s]))
            if not emits[s]:
                continue  # still absorbing prompt
            req.generated.append(int(next_tok[s]))
            if req.metrics.first_token_t == 0.0:
                req.metrics.first_token_t = now
            hit_eos = (req.eos_id is not None
                       and req.generated[-1] == req.eos_id)
            if (len(req.generated) >= req.max_new_tokens or hit_eos
                    or sched.pos[s] >= self.max_seq - 1):
                req.done = True
                req.metrics.done_t = now
                self.completed.append(req)
                sched.finish(s)
            if req.on_tokens is not None:
                req.on_tokens(req, [req.generated[-1]], req.done)
        return sum(1 for r in sched.active if r is not None)

    def _step_speculative(self, active_slots: list[int]) -> int:
        """One (B, 1 + spec_k) speculative verify step over pure-decode
        slots.

        Per decoding slot the n-gram proposer drafts up to ``k_s`` tokens
        from the request's own ``prompt + generated`` history, where
        ``k_s = min(spec_k, remaining - 1, max_seq - 2 - pos)`` caps the
        window so acceptance can never overshoot the request's token
        budget or the ``max_seq`` finish boundary (the emitted stream
        truncates at exactly the same length a token-at-a-time run
        would). The step feeds ``[t0, d_1 .. d_k]`` as a chunk — the KV
        writes land at ``pos .. pos + k``, the chunk-causal kernels give
        verification logits for every position in ONE call — and the
        host accepts the longest prefix of drafts matching the
        per-position targets, then emits the accepted drafts plus the
        first-divergence target (the "bonus" token the verify logits
        already paid for). ``pos`` advances by the number of emitted
        tokens; the rejected tail needs no cleanup because positions
        ``>= pos`` are invisible to chunk-causal attention and the next
        step overwrites them.

        A slot holding exactly one pending prompt token rides the step
        draft-free (its target at position 0 IS its first sampled token);
        idle rows write into the garbage block as always.
        """
        sched = self.scheduler
        C = 1 + self.spec.k
        B = self.B
        tokens = np.zeros((B, C), np.int32)
        length = np.ones(B, np.int32)
        temps = np.zeros(B, np.float32)
        top_k = np.zeros(B, np.int32)
        top_p = np.ones(B, np.float32)
        seeds = np.zeros(B, np.int32)
        cnt0 = np.zeros(B, np.int32)
        drafts: dict[int, list[int]] = {}
        prompt_done = []
        for s in active_slots:
            req = sched.active[s]
            pend = sched.pending_prompt[s]
            if pend:   # single leftover prompt token: absorb, no drafts
                tokens[s, 0] = pend.popleft()
                drafts[s] = []
                prompt_done.append(s)
                req.metrics.prefill_steps += 1
            else:
                g = len(req.generated)
                cap = min(self.spec.k, req.max_new_tokens - g - 1,
                          self.max_seq - 2 - int(sched.pos[s]))
                d = (self.spec.propose(
                        req.prompt[: self.max_seq - 1] + req.generated, cap)
                     if cap > 0 else [])
                drafts[s] = d
                tokens[s, 0] = (req.generated[-1] if req.generated
                                else (req.prompt[-1] if req.prompt else 0))
                for i, tok in enumerate(d):
                    tokens[s, 1 + i] = tok
                length[s] = 1 + len(d)
                req.metrics.decode_steps += 1
            temps[s] = req.temperature
            top_k[s] = req.top_k
            top_p[s] = req.top_p
            seeds[s] = (req.seed if req.seed is not None
                        else req.uid) & 0x7FFFFFFF
            # PRNG coordinate base: position i of this row draws with
            # count = len(generated) + i, exactly the coordinates a
            # token-at-a-time run would use for those stream indices
            cnt0[s] = len(req.generated)
        do_sample = any(temps[s] > 0.0 for s in active_slots)
        targets, self.state = self._step_spec(
            self.params, jnp.asarray(tokens), self.state,
            jnp.asarray(sched.pages), jnp.asarray(sched.pos),
            jnp.asarray(length), jnp.asarray(temps), jnp.asarray(top_k),
            jnp.asarray(top_p), jnp.asarray(seeds), jnp.asarray(cnt0),
            do_sample=do_sample)
        targets = np.asarray(targets)
        now = time.monotonic()
        for s in prompt_done:
            sched.register_prompt_blocks(s)
        for s in active_slots:
            req = sched.active[s]
            d = drafts[s]
            t = targets[s]
            a = 0
            while a < len(d) and d[a] == int(t[a]):
                a += 1
            # accepted drafts + the target at the first divergence (when
            # every draft matched, that's the position-after-the-last one)
            emitted = d[:a] + [int(t[a])]
            if req.eos_id is not None and req.eos_id in emitted:
                emitted = emitted[: emitted.index(req.eos_id) + 1]
            req.generated.extend(emitted)
            kept = len(emitted) - 1
            sched.commit_spec(s, len(d), kept)   # pos += len(emitted)
            req.metrics.spec_proposed += len(d)
            req.metrics.spec_accepted += kept
            if req.metrics.first_token_t == 0.0:
                req.metrics.first_token_t = now
            hit_eos = (req.eos_id is not None
                       and req.generated[-1] == req.eos_id)
            if (len(req.generated) >= req.max_new_tokens or hit_eos
                    or sched.pos[s] >= self.max_seq - 1):
                req.done = True
                req.metrics.done_t = now
                self.completed.append(req)
                sched.finish(s)
            if req.on_tokens is not None:
                req.on_tokens(req, emitted, req.done)
        return sum(1 for r in sched.active if r is not None)

    def has_work(self) -> bool:
        """Anything queued or active? (Delegates to the scheduler; the
        frontend's worker thread polls this to decide whether to step or
        sleep.)"""
        return self.scheduler.has_work()

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.scheduler.has_work():
                return self.completed
        if not self.scheduler.has_work():
            return self.completed
        # a wedged pool (or a genuinely longer workload) must not
        # masquerade as a clean drain — report what is still stuck
        queued = len(self.scheduler.queue)
        active = sum(1 for r in self.scheduler.active if r is not None)
        raise RuntimeError(
            f"run_until_drained: {max_steps} steps exhausted with {active} "
            f"active and {queued} queued requests undrained — the pool may "
            f"be wedged; raise max_steps only if the workload is genuinely "
            f"this long ({len(self.completed)} requests did complete)")

    # ------------------------------------------------------------------ #
    def metrics_summary(self) -> dict[str, float]:
        """Aggregate per-request metrics over completed requests (NaN
        entries — e.g. decode rate of single-token generations — are
        excluded from the means, never averaged in)."""
        done = self.completed
        if not done:
            # a replica whose worker crashed before completing anything
            # must still surface the crash, not an empty summary
            return ({"worker_crashed": float(self.worker_crashed)}
                    if self.worker_crashed else {})

        def finite_mean(vals):
            vals = [v for v in vals if not math.isnan(v)]
            return sum(vals) / len(vals) if vals else float("nan")

        out = {
            "requests": float(len(done)),
            "mean_ttft_s": finite_mean(r.metrics.ttft for r in done),
            "mean_queue_wait_s": finite_mean(
                r.metrics.queue_wait for r in done),
            "mean_decode_tok_per_s": finite_mean(
                r.metrics.decode_tok_per_s(len(r.generated)) for r in done),
            # responses that continue a CLIPPED prompt (Scheduler.submit
            # truncated it to max_seq - 1): callers watching this summary
            # must be able to see that without scanning every request
            "truncated_requests": float(
                sum(1 for r in done if r.truncated)),
        }
        out.update(self.scheduler.stats())  # preemptions/requeues[/blocks]
        if self.worker_crashed:
            out["worker_crashed"] = float(self.worker_crashed)
        if self.paged:
            out["mean_prefix_hit_tokens"] = (
                sum(r.metrics.prefix_hit_tokens for r in done) / len(done))
            out["mean_host_hit_tokens"] = (
                sum(r.metrics.host_hit_tokens for r in done) / len(done))
            # realized pool layout cost (the dtype name itself is on
            # ``engine.kv_dtype``; this summary is float-valued)
            out["kv_bytes_per_token"] = self.kv_bytes_per_token()
        return out
