"""Serving runtime: continuous batching with chunked prefill and sampling.

A fixed-slot batch (compiled once per step shape); requests stream in and
out of slots without recompilation:

* each slot carries its own position (per-row KV-cache / SSM-state writes
  via the vmap'd scatters in the model prefill/decode paths);
* a freed slot (EOS / max_tokens / cache full) is refilled from the queue on
  the next step — no draining barrier, the Orca/vLLM scheduling insight on
  top of a fixed-shape TPU step — and the new occupant's state rows are
  zeroed so a previous request's SSM state cannot leak;
* prompts are absorbed through the model's ``prefill`` entry: up to
  ``chunk`` tokens per slot per step in ONE fused jitted call that writes
  the KV cache / SSM state for the whole chunk and returns last-position
  logits, instead of ``chunk`` teacher-forced decode steps;
* scheduling is mixed: while any slot still holds >1 pending prompt tokens
  the engine runs the (B, chunk) step — decoding slots ride along with
  length 1 — and drops back to the cheap (B, 1) step (decode IS prefill
  with C = 1) once all prompts are absorbed. Two compiled shapes, each
  with a greedy and a sampled variant (``do_sample`` is a static jit arg,
  so an all-greedy batch skips the sort/sampling pipeline entirely): at
  most four compilations per engine.

Sampling replaces the old greedy-only argmax: per-request temperature,
top-k, top-p and PRNG seed (see :mod:`repro.serving.sampling`), fused into
the jitted step. ``temperature=0`` (default) is greedy argmax.

Per-request metrics are recorded on ``Request.metrics``: queue wait,
time-to-first-token, decode tokens/s, prefill/decode step counts.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as nn
from repro.models.registry import ModelApi
from repro.serving import sampling


@dataclasses.dataclass
class RequestMetrics:
    submit_t: float = 0.0       # time.monotonic at submit()
    admit_t: float = 0.0        # first scheduled into a slot
    first_token_t: float = 0.0  # first sampled token appended
    done_t: float = 0.0
    prefill_steps: int = 0
    decode_steps: int = 0

    @property
    def queue_wait(self) -> float:
        return self.admit_t - self.submit_t

    @property
    def ttft(self) -> float:
        """Time to first token, from submit."""
        return self.first_token_t - self.submit_t

    def decode_tok_per_s(self, n_generated: int) -> float:
        dt = self.done_t - self.first_token_t
        return (n_generated - 1) / dt if dt > 0 and n_generated > 1 else 0.0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    # sampling knobs: temperature 0 = greedy; top_k <= 0 / top_p >= 1 disable
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int | None = None     # None -> uid; PRNG is per (seed, token index)
    # filled by the engine
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    metrics: RequestMetrics = dataclasses.field(default_factory=RequestMetrics)


class ServingEngine:
    def __init__(self, api: ModelApi, params: dict[str, Any], *,
                 max_batch: int = 4, max_seq: int = 256, chunk: int = 16,
                 cache_dtype=jnp.float32):
        self.api = api
        self.params = params
        self.B = max_batch
        self.max_seq = max_seq
        # APIs without a prefill entry fall back to one-token absorption
        # (a C=1 prefill is exactly one decode step)
        self.chunk = max(1, int(chunk)) if api.prefill is not None else 1
        self._prefill_fn = api.prefill if api.prefill is not None else (
            lambda t, s, p, l: api.decode_step(t, s, p))
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)          # next write index
        self.pending_prompt: list[deque[int]] = [deque() for _ in range(max_batch)]
        # chunk-1 headroom: a C-wide cache write starting at pos <= max_seq-1
        # must never clamp (pad columns past a row's valid length would
        # otherwise shift onto live entries)
        self.state = api.decode_state_init(
            max_batch, max_seq + self.chunk, cache_dtype)
        self._step = jax.jit(self._step_fn, static_argnames=("do_sample",))
        self.completed: list[Request] = []

    # ------------------------------------------------------------------ #
    def _step_fn(self, params, tokens, state, pos, length,
                 temps, top_k, top_p, seeds, counts, *, do_sample):
        logits, new_state = nn.apply(
            lambda t, s, p, l: self._prefill_fn(t, s, p, l),
            params, tokens, state, pos, length)
        last = logits[:, -1, :].astype(jnp.float32)
        if do_sample:
            next_tok = sampling.sample(last, temps, top_k, top_p,
                                       seeds, counts)
        else:
            # all-greedy batch (the default): skip the (B, V) sort pipeline
            next_tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return next_tok, new_state

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        req.metrics.submit_t = time.monotonic()
        self.queue.append(req)

    def _admit(self, now: float) -> None:
        fresh = []
        for slot in range(self.B):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                self.active[slot] = req
                self.pos[slot] = 0
                # truncate: at most max_seq-1 prompt tokens fit the cache
                # while leaving room for one generated token
                self.pending_prompt[slot] = deque(
                    req.prompt[: self.max_seq - 1])
                req.metrics.admit_t = now
                fresh.append(slot)
        if fresh:
            idx = jnp.asarray(fresh, jnp.int32)
            # Zero the admitted rows of every *recurrent* state leaf so a
            # freed slot's SSM state can't leak forward (batch is axis 1,
            # see registry docstring). KV-cache leaves — keyed "k"/"v" —
            # are skipped: a fresh occupant starts at pos=0 and attention
            # only ever sees entries it has written, so zeroing them would
            # just copy the whole cache per admission.
            def reset(path, a):
                last = path[-1]
                if (isinstance(last, jax.tree_util.DictKey)
                        and last.key in ("k", "v")):
                    return a
                return a.at[:, idx].set(0)
            self.state = jax.tree_util.tree_map_with_path(reset, self.state)

    def step(self) -> int:
        """One synchronized mixed prefill/decode step; returns #active."""
        self._admit(time.monotonic())
        active_slots = [s for s, r in enumerate(self.active) if r is not None]
        if not active_slots:
            return 0
        prefilling = any(len(self.pending_prompt[s]) > 1
                         for s in active_slots)
        C = self.chunk if prefilling else 1
        B = self.B
        tokens = np.zeros((B, C), np.int32)
        length = np.ones(B, np.int32)
        temps = np.zeros(B, np.float32)
        top_k = np.zeros(B, np.int32)
        top_p = np.ones(B, np.float32)
        seeds = np.zeros(B, np.int32)
        counts = np.zeros(B, np.int32)
        emits = [False] * B
        for s in active_slots:
            req = self.active[s]
            pend = self.pending_prompt[s]
            if pend:
                k = min(C, len(pend))
                for i in range(k):
                    tokens[s, i] = pend.popleft()
                length[s] = k
                emits[s] = not pend   # prompt fully absorbed: sample now
                req.metrics.prefill_steps += 1
            else:
                tokens[s, 0] = (req.generated[-1] if req.generated
                                else (req.prompt[-1] if req.prompt else 0))
                emits[s] = True
                req.metrics.decode_steps += 1
            temps[s] = req.temperature
            top_k[s] = req.top_k
            top_p[s] = req.top_p
            # mask to 31 bits: callers often derive 64-bit seeds (hashes)
            seeds[s] = (req.seed if req.seed is not None
                        else req.uid) & 0x7FFFFFFF
            counts[s] = len(req.generated)
        do_sample = any(temps[s] > 0.0 for s in active_slots)
        next_tok, self.state = self._step(
            self.params, jnp.asarray(tokens), self.state,
            jnp.asarray(self.pos), jnp.asarray(length), jnp.asarray(temps),
            jnp.asarray(top_k), jnp.asarray(top_p), jnp.asarray(seeds),
            jnp.asarray(counts), do_sample=do_sample)
        next_tok = np.asarray(next_tok)
        now = time.monotonic()
        for s in active_slots:
            req = self.active[s]
            self.pos[s] += int(length[s])
            if not emits[s]:
                continue  # still absorbing prompt
            req.generated.append(int(next_tok[s]))
            if req.metrics.first_token_t == 0.0:
                req.metrics.first_token_t = now
            hit_eos = (req.eos_id is not None
                       and req.generated[-1] == req.eos_id)
            if (len(req.generated) >= req.max_new_tokens or hit_eos
                    or self.pos[s] >= self.max_seq - 1):
                req.done = True
                req.metrics.done_t = now
                self.completed.append(req)
                self.active[s] = None   # slot refilled next step
                self.pos[s] = 0
                self.pending_prompt[s] = deque()
        return sum(1 for r in self.active if r is not None)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.queue:
                break
        return self.completed

    # ------------------------------------------------------------------ #
    def metrics_summary(self) -> dict[str, float]:
        """Aggregate per-request metrics over completed requests."""
        done = self.completed
        if not done:
            return {}
        ttfts = [r.metrics.ttft for r in done]
        waits = [r.metrics.queue_wait for r in done]
        tps = [r.metrics.decode_tok_per_s(len(r.generated)) for r in done
               if len(r.generated) > 1]
        return {
            "requests": float(len(done)),
            "mean_ttft_s": sum(ttfts) / len(ttfts),
            "mean_queue_wait_s": sum(waits) / len(waits),
            "mean_decode_tok_per_s": sum(tps) / len(tps) if tps else 0.0,
        }
