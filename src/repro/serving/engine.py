"""Serving runtime: batched decode with continuous batching (lite).

A fixed-slot decode batch (compiled once); requests stream in and out of
slots without recompilation:

* each slot carries its own position (per-row KV-cache writes via the
  vmap'd scatter in the attention decode path);
* a freed slot (EOS / max_tokens) is refilled from the queue on the next
  step — no draining barrier, the Orca/vLLM scheduling insight on top of a
  fixed-shape TPU step;
* prompts are absorbed via teacher-forced decode steps (a dedicated chunked
  prefill step is the recorded follow-up optimization).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as nn
from repro.models.registry import ModelApi


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    # filled by the engine
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, api: ModelApi, params: dict[str, Any], *,
                 max_batch: int = 4, max_seq: int = 256,
                 cache_dtype=jnp.float32):
        self.api = api
        self.params = params
        self.B = max_batch
        self.max_seq = max_seq
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)          # next write index
        self.pending_prompt: list[deque[int]] = [deque() for _ in range(max_batch)]
        self.state = api.decode_state_init(max_batch, max_seq, cache_dtype)
        self._step = jax.jit(self._decode_fn)
        self.completed: list[Request] = []

    # ------------------------------------------------------------------ #
    def _decode_fn(self, params, tokens, state, pos):
        logits, new_state = nn.apply(
            lambda t, s, p: self.api.decode_step(t, s, p),
            params, tokens, state, pos)
        next_tok = jnp.argmax(logits[:, -1, :].astype(jnp.float32), axis=-1)
        return next_tok.astype(jnp.int32), new_state

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.B):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                self.active[slot] = req
                self.pos[slot] = 0
                self.pending_prompt[slot] = deque(req.prompt)

    def step(self) -> int:
        """One synchronized decode step across all slots; returns #active."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        tokens = np.zeros((self.B, 1), np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            if self.pending_prompt[slot]:
                tokens[slot, 0] = self.pending_prompt[slot].popleft()
            elif req.generated:
                tokens[slot, 0] = req.generated[-1]
            else:
                tokens[slot, 0] = req.prompt[-1]
        next_tok, self.state = self._step(
            self.params, jnp.asarray(tokens), self.state,
            jnp.asarray(self.pos))
        next_tok = np.asarray(next_tok)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[slot] += 1
            if self.pending_prompt[slot]:
                continue  # still absorbing prompt; ignore sampled token
            req.generated.append(int(next_tok[slot]))
            hit_eos = (req.eos_id is not None
                       and req.generated[-1] == req.eos_id)
            if (len(req.generated) >= req.max_new_tokens or hit_eos
                    or self.pos[slot] >= self.max_seq - 1):
                req.done = True
                self.completed.append(req)
                self.active[slot] = None   # slot refilled next step
        return sum(1 for r in self.active if r is not None)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.queue:
                break
        return self.completed
