"""Deterministic fault injection for the serving stack.

Chaos testing is only useful when a chaos scenario is a *reproducible
unit test*: "kill replica 0 at step 7" must mean the same thing on every
run, on every machine, or a recovery bug found once can never be
bisected. This module therefore injects faults by **step index**, never
by wall clock: a :class:`FaultInjector` wraps an engine's ``step`` (an
instance-attribute shadow of the bound method — the engine class is
untouched, and an engine with no injector installed is byte-for-byte the
stock engine) and consults a scripted :class:`FaultPlan` before every
step attempt.

Fault taxonomy
--------------
Three fault kinds cover the failure modes a replica actually exhibits in
production, each mapped to the detection path that must catch it:

* ``"die"`` — replica death. Every step attempt from ``step`` onward
  raises :class:`ReplicaDead` (for ``steps = N > 0``, only attempts in
  ``[step, step + N)`` — a replica that *recovers*, which is what
  probe-based re-admission exists for). Models a crashed process or a
  lost host. Detected by the step loop's exception path: the sync
  :class:`~repro.serving.router.Router` driver marks the replica DEAD and
  migrates; the async :class:`~repro.serving.frontend.EngineWorker`
  crash handler does the same through its ``on_crash`` hook.
* ``"error"`` — a single raised exception mid-step (:class:`InjectedError`
  at exactly step ``step``). Models a transient blow-up (OOM retry, a
  poisoned batch). Same detection path as death, but probes succeed
  afterwards, so it exercises re-admission.
* ``"stall"`` — a sustained slowdown: every step in ``[step, step +
  steps)`` sleeps ``stall_s`` before running. The step *completes* —
  nothing raises — so only the wall-time watchdogs can see it: the
  router's step-deadline check and
  :class:`~repro.distributed.resilience.StragglerMonitor` EWMA z-score
  (HEALTHY -> SUSPECT -> DEAD), or the frontend's stuck-step watchdog
  task.

Faults fire at **step boundaries** (before the wrapped step runs). That
is not a test simplification, it is the recovery contract: a step either
completed — its tokens were appended and emitted — or it never ran.
There is no half-step state to reason about, so the migration below can
treat ``req.generated`` as the exact resume point.

Why migration is bitwise exact
------------------------------
When a replica dies, the router harvests its queued *and* in-flight
requests and resubmits them to survivors through the scheduler's
requeue-as-prefill path (:meth:`~repro.serving.scheduler.Scheduler.
resubmit` — the cross-replica face of :meth:`~repro.serving.scheduler.
Scheduler.preempt`): the tokens generated so far fold into a resume
prompt ``prompt + generated``, and the survivor re-prefills it like any
fresh request. Exactness rests on three established invariants:

1. **Replicas compute the same function** — same params, and steps are
   batch-composition-independent, so *where* a request runs never
   changes its logits (the PR 7 router bench asserts this bitwise).
2. **Chunked prefill of ``prompt + generated`` reproduces the decode
   state** — the PR 5 preemption tests assert a requeued victim's
   continued stream equals the uninterrupted one.
3. **The sampling PRNG is coordinate-keyed, not stateful** — every draw
   is keyed by ``(seed, len(generated))``, with ``seed`` defaulting to
   the request's uid. A migrated request's next draw uses the same
   coordinates on the survivor as it would have used on the dead
   replica, so sampled streams continue exactly (greedy is trivially
   exact).

Hence a completed stream is bitwise identical to a fault-free run —
recovery costs latency (re-prefill of the resume prompt) but never
correctness. The one refusal: a request whose resume prompt would exceed
``max_seq - 1`` cannot migrate without dropping generated tokens, so it
is failed loudly (it was within one position of its forced finish).
"""

from __future__ import annotations

import dataclasses
import time

from repro.serving.engine import ServingEngine

KINDS = ("die", "error", "stall")


class InjectedError(RuntimeError):
    """A scripted transient mid-step exception (fault kind ``"error"``)."""


class ReplicaDead(RuntimeError):
    """A scripted replica death (fault kind ``"die"``): raised on every
    step attempt inside the fault's window."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scripted fault: ``kind`` fires relative to the injector's
    step-attempt counter (0-indexed, counted from :meth:`FaultInjector.
    install`). ``steps`` is the window length — for ``"die"``, 0 means
    forever (the replica never recovers); ``"error"`` always fires once,
    at exactly ``step``; ``"stall"`` sleeps ``stall_s`` before each step
    in the window."""
    step: int
    kind: str
    stall_s: float = 0.0
    steps: int = 1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")
        if self.kind == "stall" and not self.stall_s > 0.0:
            raise ValueError("a stall fault needs stall_s > 0")
        if self.steps < 0 or (self.steps == 0 and self.kind != "die"):
            raise ValueError(f"steps={self.steps} invalid for "
                             f"kind {self.kind!r} (0 = forever is "
                             f"die-only)")


class FaultPlan:
    """A scripted chaos scenario: per-replica fault lists, keyed by
    replica id. A plain list is shorthand for ``{0: faults}`` (single
    engine). :meth:`install` arms one :class:`FaultInjector` per planned
    replica."""

    def __init__(self, faults: dict[int, list[Fault]] | list[Fault]):
        if isinstance(faults, list):
            faults = {0: faults}
        for rid, fs in faults.items():
            if rid < 0:
                raise ValueError(f"replica id must be >= 0, got {rid}")
            for f in fs:
                if not isinstance(f, Fault):
                    raise TypeError(f"replica {rid}: expected Fault, "
                                    f"got {type(f).__name__}")
        self.faults = {rid: list(fs) for rid, fs in faults.items()}

    def for_replica(self, rid: int) -> list[Fault]:
        return list(self.faults.get(rid, []))

    def install(self, engines: list[ServingEngine]) -> list["FaultInjector"]:
        """Arm injectors on ``engines`` (one per replica the plan names);
        returns them so callers can inspect ``fired`` / uninstall."""
        for rid in self.faults:
            if rid >= len(engines):
                raise ValueError(f"plan names replica {rid} but only "
                                 f"{len(engines)} engines were given")
        out = []
        for rid, fs in sorted(self.faults.items()):
            inj = FaultInjector(engines[rid], fs)
            inj.install()
            out.append(inj)
        return out


class FaultInjector:
    """Wrap one engine's ``step`` to fire scripted faults by step index.

    ``install()`` shadows ``engine.step`` with an instance attribute
    (``uninstall()`` deletes it, restoring the class method — nothing
    about the engine changes when no injector is armed). Every *step
    attempt* — including attempts that raise, and empty probe steps —
    advances the counter, so a death window of ``steps = N`` is consumed
    by probes deterministically. ``fired`` records ``(attempt, kind)``
    for every fault that triggered; ``sleep`` is injectable so stall
    tests need not actually wait."""

    def __init__(self, engine: ServingEngine, faults: list[Fault], *,
                 sleep=time.sleep):
        self.engine = engine
        self.faults = list(faults)
        self.steps = 0                    # step-attempt counter
        self.fired: list[tuple[int, str]] = []
        self._sleep = sleep
        self._installed = False

    @property
    def installed(self) -> bool:
        return self._installed

    def install(self) -> "FaultInjector":
        if self._installed:
            raise RuntimeError("injector already installed")
        if "step" in self.engine.__dict__:
            raise RuntimeError("engine.step is already wrapped (one "
                               "injector per engine)")
        self._orig = self.engine.step     # bound class method
        self.engine.step = self._step     # instance shadow
        self._installed = True
        return self

    def uninstall(self) -> None:
        if self._installed:
            del self.engine.step          # unshadow the class method
            self._installed = False

    def _step(self) -> int:
        i = self.steps
        self.steps += 1
        for f in self.faults:
            if f.kind == "die":
                if i >= f.step and (f.steps == 0 or i < f.step + f.steps):
                    self.fired.append((i, "die"))
                    raise ReplicaDead(
                        f"injected replica death at step attempt {i} "
                        f"(scripted at step {f.step})")
            elif f.kind == "error":
                if i == f.step:
                    self.fired.append((i, "error"))
                    raise InjectedError(
                        f"injected step exception at step attempt {i}")
            elif f.kind == "stall":
                if f.step <= i < f.step + f.steps:
                    self.fired.append((i, "stall"))
                    self._sleep(f.stall_s)
        return self._orig()
