"""Per-request token sampling for the serving engine.

One fused (B, V) -> (B,) op: temperature scaling, top-k and top-p (nucleus)
filtering, and a categorical draw — all per row, so one batched call serves
requests with heterogeneous sampling settings. Runs inside the engine's
jitted step. :func:`sample_chunk` is the (B, C, V) extension used by
speculative verification: position ``i`` of row ``b`` draws with the PRNG
coordinate ``(seed[b], count0[b] + i)``, so the per-position targets are
exactly the tokens non-speculative decoding would have drawn one step at a
time.

Determinism: the key for row b is ``fold_in(key(seed[b]), count[b])`` where
``count`` is the request's generated-token index. A request therefore samples
the same token stream regardless of which slot it lands in, how deep the
queue was, or what chunk size absorbed its prompt.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, temperature: jax.Array, top_k: jax.Array,
           top_p: jax.Array, seed: jax.Array, count: jax.Array) -> jax.Array:
    """Sample one token per row.

    logits (B, V); temperature (B,) — ``0`` selects greedy argmax;
    top_k (B,) int32 — ``<= 0`` disables; top_p (B,) — ``>= 1`` disables
    (the canonical off value the serve CLI documents), and ``<= 0`` is
    treated identically — never as "keep nothing"; seed / count (B,) int32
    per-request PRNG coordinates. Returns (B,) int32 token ids.

    A ``temperature = 0`` row inside a sampled batch is *bitwise* the
    greedy argmax an all-greedy batch computes: scaling is applied only to
    rows with ``temp > 0`` (no ``logits / 1e-6`` blow-up feeding inf/nan
    through the sort pipeline), and the final select reads the untouched
    argmax.
    """
    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1)

    # scale only rows that actually sample: greedy rows divide by 1 so the
    # filter pipeline sees finite values (their output is discarded anyway)
    temp = jnp.where(temperature > 0.0, temperature, 1.0)
    scaled = logits / temp[:, None]

    # one descending sort serves both filters; everything below is O(V)
    k = jnp.where(top_k <= 0, V, jnp.minimum(top_k, V))   # <= 0 disables
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(desc, (k - 1)[:, None], axis=1)
    desc = jnp.where(jnp.arange(V)[None, :] < k[:, None], desc, -jnp.inf)

    # top-p over the top-k survivors: keep the smallest prefix of
    # descending probs whose mass reaches p (crossing token included);
    # the lowest kept *logit* is the threshold, so boundary ties share it
    p = jnp.where((top_p <= 0.0) | (top_p >= 1.0), 1.0, top_p)
    p_desc = jax.nn.softmax(desc, axis=-1)
    csum = jnp.cumsum(p_desc, axis=-1)
    n_keep = jnp.maximum(jnp.sum((csum - p_desc) < p[:, None], axis=-1), 1)
    thr = jnp.take_along_axis(desc, (n_keep - 1)[:, None], axis=1)
    scaled = jnp.where((scaled >= kth) & (scaled >= thr), scaled, -jnp.inf)

    def draw(s, c, row):
        key = jax.random.fold_in(jax.random.key(s), c)
        return jax.random.categorical(key, row)

    sampled = jax.vmap(draw)(seed.astype(jnp.uint32),
                             count.astype(jnp.uint32), scaled)
    return jnp.where(temperature <= 0.0, greedy, sampled).astype(jnp.int32)


def sample_chunk(logits: jax.Array, temperature: jax.Array,
                 top_k: jax.Array, top_p: jax.Array, seed: jax.Array,
                 count0: jax.Array) -> jax.Array:
    """Per-position targets over a chunk: (B, C, V) -> (B, C) int32.

    Row ``b``, position ``i`` is sampled exactly as :func:`sample` would
    sample it with ``count = count0[b] + i`` — the flattened (B*C, V) call
    IS :func:`sample`, so a C = 1 chunk is bitwise the single-token path
    and every position of a wider chunk reproduces the token the
    non-speculative engine would have drawn at that stream index. The
    speculative verify step compares drafts against these targets;
    positions whose coordinate is meaningless for a row (pad columns,
    prefill positions before the row's emit point) compute garbage targets
    that the engine never reads.
    """
    B, C, V = logits.shape

    def rep(a):
        return jnp.repeat(a, C)

    counts = (count0[:, None]
              + jnp.arange(C, dtype=count0.dtype)[None, :]).reshape(-1)
    flat = sample(logits.reshape(B * C, V), rep(temperature), rep(top_k),
                  rep(top_p), rep(seed), counts)
    return flat.reshape(B, C)


def greedy_chunk(logits: jax.Array) -> jax.Array:
    """All-greedy per-position targets: (B, C, V) -> (B, C) argmax (the
    sampled pipeline skipped entirely, as in the single-token step)."""
    return jnp.argmax(logits.astype(jnp.float32), axis=-1).astype(jnp.int32)
