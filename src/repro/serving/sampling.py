"""Per-request token sampling for the serving engine.

One fused (B, V) -> (B,) op: temperature scaling, top-k and top-p (nucleus)
filtering, and a categorical draw — all per row, so one batched call serves
requests with heterogeneous sampling settings. Runs inside the engine's
jitted step.

Determinism: the key for row b is ``fold_in(key(seed[b]), count[b])`` where
``count`` is the request's generated-token index. A request therefore samples
the same token stream regardless of which slot it lands in, how deep the
queue was, or what chunk size absorbed its prompt.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, temperature: jax.Array, top_k: jax.Array,
           top_p: jax.Array, seed: jax.Array, count: jax.Array) -> jax.Array:
    """Sample one token per row.

    logits (B, V); temperature (B,) — ``0`` selects greedy argmax;
    top_k (B,) int32 — ``<= 0`` disables; top_p (B,) — ``<= 0`` or ``>= 1``
    disables; seed / count (B,) int32 per-request PRNG coordinates.
    Returns (B,) int32 token ids.
    """
    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1)

    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]

    # one descending sort serves both filters; everything below is O(V)
    k = jnp.where(top_k <= 0, V, jnp.minimum(top_k, V))   # <= 0 disables
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(desc, (k - 1)[:, None], axis=1)
    desc = jnp.where(jnp.arange(V)[None, :] < k[:, None], desc, -jnp.inf)

    # top-p over the top-k survivors: keep the smallest prefix of
    # descending probs whose mass reaches p (crossing token included);
    # the lowest kept *logit* is the threshold, so boundary ties share it
    p = jnp.where((top_p <= 0.0) | (top_p >= 1.0), 1.0, top_p)
    p_desc = jax.nn.softmax(desc, axis=-1)
    csum = jnp.cumsum(p_desc, axis=-1)
    n_keep = jnp.maximum(jnp.sum((csum - p_desc) < p[:, None], axis=-1), 1)
    thr = jnp.take_along_axis(desc, (n_keep - 1)[:, None], axis=1)
    scaled = jnp.where((scaled >= kth) & (scaled >= thr), scaled, -jnp.inf)

    def draw(s, c, row):
        key = jax.random.fold_in(jax.random.key(s), c)
        return jax.random.categorical(key, row)

    sampled = jax.vmap(draw)(seed.astype(jnp.uint32),
                             count.astype(jnp.uint32), scaled)
    return jnp.where(temperature <= 0.0, greedy, sampled).astype(jnp.int32)
