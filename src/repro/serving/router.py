"""Multi-replica request router: prefix-affinity placement over R engines.

One :class:`~repro.serving.engine.ServingEngine` is one replica — its own
params copy, KV block pool, prefix cache and scheduler, optionally pinned
to its own device slice (:func:`repro.launch.mesh.make_replica_meshes`
carves the device set into R disjoint ``(1, tp)`` meshes — the realized
``data`` axis of the production mesh). The :class:`Router` owns R such
replicas and decides, per request, which one serves it.

Routing policy (``policy="affinity"``, the default)
---------------------------------------------------
Prefix caches are per-replica, so *where* a request lands decides whether
its prompt prefix is a cache hit or a cold re-prefill. The router reuses
the exact key chain the :class:`~repro.serving.paged.PrefixCache` already
computes (:func:`repro.serving.paged.prefix_keys` — chained 128-bit
blake2b digests, one per full prompt block) as its affinity signal, in
escalating order:

1. **Live-cache affinity** — ``peek`` every replica's prefix map with the
   request's key chain (a pure read; no refcount/LRU/stat skew). If any
   replica holds cached blocks for this prompt, route to the replica with
   the *deepest* hit run (ties broken by load): the request rides blocks
   that already exist and skips prefill over them.
2. **Cold-hash affinity** — no replica holds the prefix yet: route by a
   stable hash of the chain's *first* key (``keys[0]`` commits to the
   whole first prompt block, so every request sharing a leading block
   hashes to the same replica). The first arrival of a prefix family
   warms exactly the replica its siblings will hash to — sticky sessions
   without any shared state between router and replicas. A load escape
   hatch overrides the hash when the target is clearly overloaded
   (queue+active depth exceeds the lightest replica's by more than
   ``imbalance``, or it cannot admit while another replica can — the
   :meth:`~repro.serving.scheduler.Scheduler.would_admit` probe): a hot
   replica must not absorb unbounded traffic just because a popular
   prefix hashes to it.
3. **Pure load** — prompts shorter than one block have no keys: route to
   the least-loaded replica (queue+active depth, then the EWMA-TTFT
   signal fed back by :meth:`Router.observe_ttft`, then replica id).

``policy="random"`` (seeded) and ``policy="round_robin"`` ignore affinity
entirely — they are the control arms the router benchmark compares
against (affinity must strictly beat them on shared-prefix traffic).

Correctness note: routing NEVER changes a request's token stream. Every
replica computes the same function (same params, same per-``(seed,
len(generated))`` PRNG coordinates, batch-composition-independent steps),
so placement affects latency and cache hits only — the router benchmark
asserts streams are bitwise identical to a single-replica run.

Concurrency note: the sync driver (:meth:`step` / :meth:`run_until_
drained`) steps replicas in-process. Under the async frontend each
replica is stepped by its own worker thread and :meth:`route` runs on the
asyncio thread — its reads of replica state (``peek``, queue depth,
``would_admit``) are racy-but-safe: single dict/list reads under the GIL
that can only yield a slightly stale *placement*, never corrupt state.

Replica health (PR 8)
---------------------
Each replica carries a state machine ``HEALTHY -> SUSPECT -> DEAD`` plus
probe-based re-admission. The signals: a per-replica
:class:`~repro.distributed.resilience.StragglerMonitor` EWMA z-score on
per-step wall time flags *sustained* slowdowns (SUSPECT — informational,
it accelerates the deadline path but never changes routing), a hard
step-deadline overrun escalates SUSPECT and then kills (two consecutive
overruns -> DEAD), and any exception out of ``step`` kills immediately.
A fast step heals SUSPECT back to HEALTHY. DEAD replicas are excluded
from all routing — live-cache affinity, the cold ``keys[0]`` hash (which
re-maps onto the live set), load fallback, random and round-robin — and
their queued + in-flight requests are **migrated**: harvested off the
dead scheduler (blocks freed host-side) and resubmitted to survivors
through :meth:`~repro.serving.scheduler.Scheduler.resubmit`, the
requeue-as-prefill path, so completed streams are bitwise identical to a
fault-free run (see :mod:`repro.serving.faults` for the exactness
argument). The sync driver probes DEAD replicas once per :meth:`step`
(an empty ``step()`` attempt — a recovered replica stops raising);
``probe_successes`` consecutive clean probes readmit it with a reset
watchdog and a flushed prefix cache (post-crash cache contents are
untrusted). With every replica HEALTHY all of this is inert: the routing
pool is the full replica set and every decision is byte-for-byte the
health-free router.
"""

from __future__ import annotations

import math
import random
import time

from repro.distributed.resilience import StragglerMonitor
from repro.serving.engine import Request, ServingEngine
from repro.serving.paged import prefix_keys

POLICIES = ("affinity", "random", "round_robin")

# replica health states (the full machine: HEALTHY <-> SUSPECT -> DEAD,
# DEAD -> HEALTHY only through probe-based re-admission)
HEALTHY, SUSPECT, DEAD = "healthy", "suspect", "dead"


class Router:
    """Route requests across homogeneous serving-engine replicas.

    ``engines`` must be interchangeable — same model, ``max_seq``, paged
    layout and block size — because routing must never change what a
    request computes, only where. Heterogeneous pools would also break
    key-chain affinity (keys are per-``block_size``).
    """

    def __init__(self, engines: list[ServingEngine], *,
                 policy: str = "affinity", imbalance: int = 2,
                 seed: int = 0, step_deadline_s: float = 30.0,
                 probe_successes: int = 2, auto_probe: bool = True):
        if not engines:
            raise ValueError("need at least one engine replica")
        if policy not in POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"one of {POLICIES}")
        e0 = engines[0]
        for i, e in enumerate(engines[1:], 1):
            if (e.max_seq, e.paged, e.block_size) != (
                    e0.max_seq, e0.paged, e0.block_size):
                raise ValueError(
                    f"replica {i} differs from replica 0: "
                    f"(max_seq, paged, block_size) = "
                    f"{(e.max_seq, e.paged, e.block_size)} vs "
                    f"{(e0.max_seq, e0.paged, e0.block_size)} — replicas "
                    f"must be interchangeable")
        self.engines = engines
        self.policy = policy
        self.imbalance = int(imbalance)
        self.max_seq = e0.max_seq
        self.block_size = e0.block_size
        # affinity needs per-replica prefix caches to aim at
        self._affine = (policy == "affinity" and e0.paged
                        and e0.scheduler.prefix is not None)
        self._rng = random.Random(seed)
        self._rr = 0
        # routing stats (the bench and /metrics read these)
        self.routed = [0] * len(engines)       # per-replica request count
        self.affinity_hits = 0    # routed onto a live cached prefix
        self.affinity_hit_blocks = 0   # ... total peeked depth
        self.cold_affinity = 0    # cold prefix, routed by key hash
        self.load_fallbacks = 0   # hash target overloaded -> least-load
        self.load_routed = 0      # no keys: pure load routing
        # per-replica EWMA of observed TTFT (s): a soft load signal the
        # driver feeds back via observe_ttft; NaN until first observation
        self.ewma_ttft = [float("nan")] * len(engines)
        # sync-driver bookkeeping: completed-list watermark per replica
        # (step() scans the tail for fresh completions to feed the EWMA)
        self._done_seen = [0] * len(engines)
        # ---- replica health (HEALTHY -> SUSPECT -> DEAD + re-admission)
        self.step_deadline_s = float(step_deadline_s)
        self.probe_successes = int(probe_successes)
        self.auto_probe = bool(auto_probe)
        self.health = [HEALTHY] * len(engines)
        self.health_reason = [""] * len(engines)
        self.watchdog = [StragglerMonitor() for _ in engines]
        self._probe_ok = [0] * len(engines)     # consecutive clean probes
        self.death_t = [float("nan")] * len(engines)
        self.last_death_t = float("nan")
        self.replica_deaths = 0
        self.readmissions = 0
        self.migrated_requests = 0
        self.migration_failures = 0

    # ------------------------------------------------------------------ #
    # load signals
    # ------------------------------------------------------------------ #
    def depth(self, rid: int) -> int:
        """Queue + active depth of one replica (the primary load signal)."""
        sched = self.engines[rid].scheduler
        return sched.queue_depth + sum(
            1 for r in sched.active if r is not None)

    def _load_key(self, rid: int):
        t = self.ewma_ttft[rid]
        return (self.depth(rid), 0.0 if math.isnan(t) else t, rid)

    def observe_ttft(self, rid: int, ttft_s: float,
                     alpha: float = 0.2) -> None:
        """Fold one observed TTFT into replica ``rid``'s EWMA load signal
        (the async frontend calls this from its first-token events; the
        sync driver from completion scans)."""
        if math.isnan(ttft_s):
            return
        prev = self.ewma_ttft[rid]
        self.ewma_ttft[rid] = (ttft_s if math.isnan(prev)
                               else (1 - alpha) * prev + alpha * ttft_s)

    def _overloaded(self, rid: int, req: Request,
                    pool: list[int]) -> bool:
        """Is the hash-affine target a bad idea right now? True when its
        depth exceeds the lightest live replica's by more than
        ``imbalance``, or when it cannot admit the request while some
        other live replica can (the scheduler's pure would_admit probe)."""
        depths = {r: self.depth(r) for r in pool}
        if depths[rid] > min(depths.values()) + self.imbalance:
            return True
        if not self.engines[rid].scheduler.would_admit(req):
            return any(self.engines[r].scheduler.would_admit(req)
                       for r in pool if r != rid)
        return False

    # ------------------------------------------------------------------ #
    # replica health: HEALTHY -> SUSPECT -> DEAD, probe re-admission
    # ------------------------------------------------------------------ #
    def alive(self) -> list[int]:
        """Replica ids eligible for routing (everything not DEAD; SUSPECT
        is informational — a suspect replica still computes correctly,
        just slowly, and yanking its traffic on a z-score would make
        routing jitter-sensitive)."""
        return [r for r, h in enumerate(self.health) if h != DEAD]

    def record_step_time(self, rid: int, dt: float) -> None:
        """Feed one observed step wall time into replica ``rid``'s
        watchdog. A sustained straggler verdict (EWMA z-score) marks
        SUSPECT; a hard ``step_deadline_s`` overrun marks SUSPECT and, on
        a second consecutive overrun, DEAD (the caller migrates); a fast
        step heals SUSPECT back to HEALTHY."""
        if self.health[rid] == DEAD:
            return
        verdict = self.watchdog[rid].observe(dt)
        if dt >= self.step_deadline_s:
            if self.health[rid] == SUSPECT:
                self.mark_dead(
                    rid, f"step deadline: {dt:.3f}s >= "
                         f"{self.step_deadline_s:.3f}s, sustained")
            else:
                self.health[rid] = SUSPECT
                self.health_reason[rid] = (
                    f"step deadline miss ({dt:.3f}s)")
        elif verdict.is_straggler:
            if self.health[rid] == HEALTHY:
                self.health[rid] = SUSPECT
                self.health_reason[rid] = (
                    f"sustained straggler (z={verdict.z_score:.1f})")
        elif self.health[rid] == SUSPECT:
            self.health[rid] = HEALTHY
            self.health_reason[rid] = ""

    def mark_dead(self, rid: int, reason: str = "") -> None:
        """Transition ``rid`` to DEAD (idempotent). Marks only — callers
        that own the engine's thread follow up with :meth:`harvest` /
        :meth:`migrate` to move its work."""
        if self.health[rid] == DEAD:
            return
        self.health[rid] = DEAD
        self.health_reason[rid] = reason
        self.death_t[rid] = self.last_death_t = time.monotonic()
        self._probe_ok[rid] = 0
        self.replica_deaths += 1

    def harvest(self, rid: int) -> list[Request]:
        """Pull every in-flight and queued request off replica ``rid``,
        freeing its host-side blocks (finish decrefs; a later revival
        starts from a clean scheduler). Must run on whichever thread owns
        the engine — the sync driver, or a crashed worker's own thread
        after its step loop exited. Actives first (they hold generated
        tokens — the oldest work), then the queue in scheduling order."""
        sched = self.engines[rid].scheduler
        out: list[Request] = []
        for slot, req in enumerate(sched.active):
            if req is None:
                continue
            sched.finish(slot)
            out.append(req)
        out.extend(sched.drain_queue())
        for req in out:
            req.migrated = True
        return out

    def place_migrated(self, req: Request,
                       submit=None) -> int | None:
        """Route one harvested request to a survivor and resubmit it
        through the requeue-as-prefill path (bitwise resume — see
        :mod:`repro.serving.faults`). ``submit(rid, req)`` overrides the
        direct engine resubmit (the frontend hands off to worker inboxes
        instead). Returns the target rid, or None when the request could
        not be placed — no survivor, or a resume prompt that no longer
        fits — in which case it is failed loudly (``req.error`` set, the
        stream's final callback fired)."""
        try:
            rid = self.route(req)
            if submit is None:
                self.engines[rid].resubmit(req)
            else:
                submit(rid, req)
        except (RuntimeError, ValueError, MemoryError) as e:
            req.error = f"migration failed: {e}"
            req.done = True
            self.migration_failures += 1
            if req.on_tokens is not None:
                req.on_tokens(req, [], True)
            return None
        self.migrated_requests += 1
        return rid

    def migrate(self, rid: int, reason: str = "") -> int:
        """Kill ``rid`` and move its work to survivors (the sync-driver
        path: mark DEAD, harvest, re-route each request). Returns how
        many requests were successfully migrated."""
        self.mark_dead(rid, reason)
        return sum(1 for req in self.harvest(rid)
                   if self.place_migrated(req) is not None)

    def probe(self, rid: int) -> bool:
        """One liveness probe of a DEAD replica: attempt a (normally
        empty) ``step()`` — a still-dead engine raises, a recovered one
        no-ops. ``probe_successes`` consecutive clean probes readmit."""
        try:
            self.engines[rid].step()
        except Exception:
            self._probe_ok[rid] = 0
            return False
        self._probe_ok[rid] += 1
        if self._probe_ok[rid] >= self.probe_successes:
            self.readmit(rid)
        return True

    def readmit(self, rid: int) -> None:
        """Bring a recovered replica back into the routing pool: fresh
        watchdog statistics (the distribution that killed it is stale)
        and a flushed prefix cache — after a real crash the pool's
        contents are untrusted, and re-prefilling a cold cache is always
        correct (prefix hits never change tokens, only latency)."""
        self.health[rid] = HEALTHY
        self.health_reason[rid] = ""
        self.watchdog[rid].reset()
        self._probe_ok[rid] = 0
        self.readmissions += 1
        sched = self.engines[rid].scheduler
        if sched.prefix is not None:
            sched.prefix.evict(sched.num_blocks)

    # ------------------------------------------------------------------ #
    # routing
    # ------------------------------------------------------------------ #
    def route(self, req: Request) -> int:
        """Pick the replica for ``req`` (records stats, mutates no
        replica state). The frontend calls this then submits to the
        chosen replica's worker; :meth:`submit` does both for sync use.
        DEAD replicas are excluded — with every replica alive the pool is
        the full set and each policy's decision sequence is exactly the
        health-free one. Raises RuntimeError when no replica is alive."""
        pool = self.alive()
        if not pool:
            raise RuntimeError(
                "no live replicas: every replica is marked dead")
        if self.policy == "random":
            rid = pool[self._rng.randrange(len(pool))]
        elif self.policy == "round_robin":
            while True:
                rid = self._rr % len(self.engines)
                self._rr += 1
                if self.health[rid] != DEAD:
                    break
        else:
            rid = self._route_affinity(req, pool)
        self.routed[rid] += 1
        return rid

    def _route_affinity(self, req: Request, pool: list[int]) -> int:
        keys = (prefix_keys(req.prompt[: self.max_seq - 1],
                            self.block_size) if self._affine else [])
        if keys:
            # peek_depth, not len(peek(..)): tier-aware — a replica whose
            # prefix chain spilled to its host pool still attracts the
            # request (the fetch there is far cheaper than a re-prefill
            # anywhere else). Identical for single-tier replicas.
            depths = {
                r: (self.engines[r].scheduler.prefix.peek_depth(keys)
                    if self.engines[r].scheduler.prefix is not None else 0)
                for r in pool
            }
            best = max(depths.values())
            if best > 0:
                # a replica already holds this prefix: deepest hit wins,
                # load breaks ties
                rid = min((r for r in pool if depths[r] == best),
                          key=self._load_key)
                self.affinity_hits += 1
                self.affinity_hit_blocks += best
                return rid
            # cold prefix: stable hash of the first block's key over the
            # live pool, so the whole prefix family converges on one
            # replica (and re-converges onto a survivor after a death)
            rid = pool[int.from_bytes(keys[0][:8], "little") % len(pool)]
            if len(pool) > 1 and self._overloaded(rid, req, pool):
                self.load_fallbacks += 1
                return min(pool, key=self._load_key)
            self.cold_affinity += 1
            return rid
        self.load_routed += 1
        return min(pool, key=self._load_key)

    def submit(self, req: Request) -> int:
        """Route and enqueue; returns the chosen replica id."""
        rid = self.route(req)
        self.engines[rid].submit(req)
        return rid

    # ------------------------------------------------------------------ #
    # sync driver (benchmarks/tests; the async frontend threads replicas)
    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """One step on every live replica that has work; returns total
        active. Also harvests fresh completions into the TTFT EWMA. This
        is where the sync driver's fault tolerance lives: a step that
        raises kills the replica and migrates its work; a step whose wall
        time trips the watchdog (:meth:`record_step_time`) does the same
        once the state machine reaches DEAD; DEAD replicas are probed for
        re-admission instead of stepped."""
        total = 0
        for rid, eng in enumerate(self.engines):
            if self.health[rid] == DEAD:
                if self.auto_probe:
                    self.probe(rid)
                continue
            if eng.has_work():
                t0 = time.monotonic()
                try:
                    total += eng.step()
                except Exception as e:
                    self.migrate(rid, f"step raised: {e!r}")
                    continue
                self.record_step_time(rid, time.monotonic() - t0)
                if self.health[rid] == DEAD:
                    # the watchdog killed it on this step's wall time;
                    # the step itself completed, so generated tokens are
                    # consistent and the harvest resumes after them
                    self.migrate(rid)
                    continue
            done = eng.completed
            for req in done[self._done_seen[rid]:]:
                self.observe_ttft(rid, req.metrics.ttft)
            self._done_seen[rid] = len(done)
        return total

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.engines)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            self.step()
            if not self.has_work():
                return self.completed
        raise RuntimeError(
            f"router drain: {max_steps} steps exhausted with work left on "
            f"{sum(1 for e in self.engines if e.has_work())} replicas")

    @property
    def completed(self) -> list[Request]:
        out: list[Request] = []
        for e in self.engines:
            out.extend(e.completed)
        return out

    # ------------------------------------------------------------------ #
    def stats(self) -> dict[str, float]:
        """Routing-layer counters (per-replica spread + affinity mix)."""
        total = sum(self.routed)
        keyed = self.affinity_hits + self.cold_affinity + self.load_fallbacks
        out = {
            "replicas": float(len(self.engines)),
            "routed_total": float(total),
            "affinity_hits": float(self.affinity_hits),
            "affinity_hit_blocks": float(self.affinity_hit_blocks),
            "cold_affinity": float(self.cold_affinity),
            "load_fallbacks": float(self.load_fallbacks),
            "load_routed": float(self.load_routed),
        }
        if keyed:
            out["affinity_hit_rate"] = self.affinity_hits / keyed
        for rid, c in enumerate(self.routed):
            out[f"replica{rid}_routed"] = float(c)
        out["replicas_alive"] = float(len(self.alive()))
        if self.replica_deaths:
            out["replica_deaths"] = float(self.replica_deaths)
            out["migrated_requests"] = float(self.migrated_requests)
            out["migration_failures"] = float(self.migration_failures)
            out["readmissions"] = float(self.readmissions)
        return out

    def metrics_summary(self) -> dict[str, float]:
        """Cross-replica aggregate of the engines' per-request summaries
        (means weighted by completed-request count) plus routing stats."""
        summaries = [(e.metrics_summary(), e) for e in self.engines]
        summaries = [(m, e) for m, e in summaries if m]
        out: dict[str, float] = {}
        if summaries:
            # .get: a crashed replica with zero completions reports only
            # {"worker_crashed": n} — it carries no request weight
            total = sum(m.get("requests", 0.0) for m, _ in summaries)
            out["requests"] = total
            for key in ("mean_ttft_s", "mean_queue_wait_s",
                        "mean_decode_tok_per_s", "mean_prefix_hit_tokens",
                        "mean_host_hit_tokens"):
                vals = [(m[key], m.get("requests", 0.0))
                        for m, _ in summaries
                        if key in m and not math.isnan(m[key])]
                w = sum(n for _, n in vals)
                if vals and w:
                    out[key] = sum(v * n for v, n in vals) / w
            for key in ("preemptions", "requeues", "truncated_requests",
                        "spec_proposed", "spec_accepted", "cancelled",
                        "worker_crashed"):
                s = sum(m.get(key, 0.0) for m, _ in summaries)
                if key in summaries[0][0] or s:
                    out[key] = s
        out.update(self.stats())
        return out


def make_replica_engines(api, params, *, replicas: int, tp: int = 1,
                         use_meshes: bool | None = None,
                         **engine_kw) -> list[ServingEngine]:
    """Build ``replicas`` interchangeable engines for a :class:`Router`.

    ``use_meshes=True`` pins each replica to its own device slice via
    :func:`repro.launch.mesh.make_replica_meshes` (needs ``replicas * tp``
    devices — the realized data axis); ``False`` co-locates every replica
    on the default device (distinct pools and schedulers, shared compute —
    fine for tests and CPU benches); ``None`` (default) uses meshes when
    the devices are there. ``tp > 1`` always needs meshes.
    """
    import jax

    if use_meshes is None:
        use_meshes = tp > 1 or jax.device_count() >= replicas * tp
    if tp > 1 and not use_meshes:
        raise ValueError("tp > 1 replicas need per-replica meshes")
    if use_meshes:
        from repro.launch.mesh import make_replica_meshes
        meshes = make_replica_meshes(replicas, tp)
    else:
        meshes = None
    return [
        ServingEngine(api, params,
                      mesh=None if meshes is None else meshes[r],
                      **engine_kw)
        for r in range(replicas)
    ]
