"""Multi-replica request router: prefix-affinity placement over R engines.

One :class:`~repro.serving.engine.ServingEngine` is one replica — its own
params copy, KV block pool, prefix cache and scheduler, optionally pinned
to its own device slice (:func:`repro.launch.mesh.make_replica_meshes`
carves the device set into R disjoint ``(1, tp)`` meshes — the realized
``data`` axis of the production mesh). The :class:`Router` owns R such
replicas and decides, per request, which one serves it.

Routing policy (``policy="affinity"``, the default)
---------------------------------------------------
Prefix caches are per-replica, so *where* a request lands decides whether
its prompt prefix is a cache hit or a cold re-prefill. The router reuses
the exact key chain the :class:`~repro.serving.paged.PrefixCache` already
computes (:func:`repro.serving.paged.prefix_keys` — chained 128-bit
blake2b digests, one per full prompt block) as its affinity signal, in
escalating order:

1. **Live-cache affinity** — ``peek`` every replica's prefix map with the
   request's key chain (a pure read; no refcount/LRU/stat skew). If any
   replica holds cached blocks for this prompt, route to the replica with
   the *deepest* hit run (ties broken by load): the request rides blocks
   that already exist and skips prefill over them.
2. **Cold-hash affinity** — no replica holds the prefix yet: route by a
   stable hash of the chain's *first* key (``keys[0]`` commits to the
   whole first prompt block, so every request sharing a leading block
   hashes to the same replica). The first arrival of a prefix family
   warms exactly the replica its siblings will hash to — sticky sessions
   without any shared state between router and replicas. A load escape
   hatch overrides the hash when the target is clearly overloaded
   (queue+active depth exceeds the lightest replica's by more than
   ``imbalance``, or it cannot admit while another replica can — the
   :meth:`~repro.serving.scheduler.Scheduler.would_admit` probe): a hot
   replica must not absorb unbounded traffic just because a popular
   prefix hashes to it.
3. **Pure load** — prompts shorter than one block have no keys: route to
   the least-loaded replica (queue+active depth, then the EWMA-TTFT
   signal fed back by :meth:`Router.observe_ttft`, then replica id).

``policy="random"`` (seeded) and ``policy="round_robin"`` ignore affinity
entirely — they are the control arms the router benchmark compares
against (affinity must strictly beat them on shared-prefix traffic).

Correctness note: routing NEVER changes a request's token stream. Every
replica computes the same function (same params, same per-``(seed,
len(generated))`` PRNG coordinates, batch-composition-independent steps),
so placement affects latency and cache hits only — the router benchmark
asserts streams are bitwise identical to a single-replica run.

Concurrency note: the sync driver (:meth:`step` / :meth:`run_until_
drained`) steps replicas in-process. Under the async frontend each
replica is stepped by its own worker thread and :meth:`route` runs on the
asyncio thread — its reads of replica state (``peek``, queue depth,
``would_admit``) are racy-but-safe: single dict/list reads under the GIL
that can only yield a slightly stale *placement*, never corrupt state.
"""

from __future__ import annotations

import math
import random

from repro.serving.engine import Request, ServingEngine
from repro.serving.paged import prefix_keys

POLICIES = ("affinity", "random", "round_robin")


class Router:
    """Route requests across homogeneous serving-engine replicas.

    ``engines`` must be interchangeable — same model, ``max_seq``, paged
    layout and block size — because routing must never change what a
    request computes, only where. Heterogeneous pools would also break
    key-chain affinity (keys are per-``block_size``).
    """

    def __init__(self, engines: list[ServingEngine], *,
                 policy: str = "affinity", imbalance: int = 2,
                 seed: int = 0):
        if not engines:
            raise ValueError("need at least one engine replica")
        if policy not in POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"one of {POLICIES}")
        e0 = engines[0]
        for i, e in enumerate(engines[1:], 1):
            if (e.max_seq, e.paged, e.block_size) != (
                    e0.max_seq, e0.paged, e0.block_size):
                raise ValueError(
                    f"replica {i} differs from replica 0: "
                    f"(max_seq, paged, block_size) = "
                    f"{(e.max_seq, e.paged, e.block_size)} vs "
                    f"{(e0.max_seq, e0.paged, e0.block_size)} — replicas "
                    f"must be interchangeable")
        self.engines = engines
        self.policy = policy
        self.imbalance = int(imbalance)
        self.max_seq = e0.max_seq
        self.block_size = e0.block_size
        # affinity needs per-replica prefix caches to aim at
        self._affine = (policy == "affinity" and e0.paged
                        and e0.scheduler.prefix is not None)
        self._rng = random.Random(seed)
        self._rr = 0
        # routing stats (the bench and /metrics read these)
        self.routed = [0] * len(engines)       # per-replica request count
        self.affinity_hits = 0    # routed onto a live cached prefix
        self.affinity_hit_blocks = 0   # ... total peeked depth
        self.cold_affinity = 0    # cold prefix, routed by key hash
        self.load_fallbacks = 0   # hash target overloaded -> least-load
        self.load_routed = 0      # no keys: pure load routing
        # per-replica EWMA of observed TTFT (s): a soft load signal the
        # driver feeds back via observe_ttft; NaN until first observation
        self.ewma_ttft = [float("nan")] * len(engines)
        # sync-driver bookkeeping: completed-list watermark per replica
        # (step() scans the tail for fresh completions to feed the EWMA)
        self._done_seen = [0] * len(engines)

    # ------------------------------------------------------------------ #
    # load signals
    # ------------------------------------------------------------------ #
    def depth(self, rid: int) -> int:
        """Queue + active depth of one replica (the primary load signal)."""
        sched = self.engines[rid].scheduler
        return sched.queue_depth + sum(
            1 for r in sched.active if r is not None)

    def _load_key(self, rid: int):
        t = self.ewma_ttft[rid]
        return (self.depth(rid), 0.0 if math.isnan(t) else t, rid)

    def observe_ttft(self, rid: int, ttft_s: float,
                     alpha: float = 0.2) -> None:
        """Fold one observed TTFT into replica ``rid``'s EWMA load signal
        (the async frontend calls this from its first-token events; the
        sync driver from completion scans)."""
        if math.isnan(ttft_s):
            return
        prev = self.ewma_ttft[rid]
        self.ewma_ttft[rid] = (ttft_s if math.isnan(prev)
                               else (1 - alpha) * prev + alpha * ttft_s)

    def _overloaded(self, rid: int, req: Request) -> bool:
        """Is the hash-affine target a bad idea right now? True when its
        depth exceeds the lightest replica's by more than ``imbalance``,
        or when it cannot admit the request while some other replica can
        (the scheduler's pure would_admit probe)."""
        depths = [self.depth(r) for r in range(len(self.engines))]
        if depths[rid] > min(depths) + self.imbalance:
            return True
        if not self.engines[rid].scheduler.would_admit(req):
            return any(e.scheduler.would_admit(req)
                       for r, e in enumerate(self.engines) if r != rid)
        return False

    # ------------------------------------------------------------------ #
    # routing
    # ------------------------------------------------------------------ #
    def route(self, req: Request) -> int:
        """Pick the replica for ``req`` (records stats, mutates no
        replica state). The frontend calls this then submits to the
        chosen replica's worker; :meth:`submit` does both for sync use."""
        n = len(self.engines)
        if self.policy == "random":
            rid = self._rng.randrange(n)
        elif self.policy == "round_robin":
            rid = self._rr % n
            self._rr += 1
        else:
            rid = self._route_affinity(req)
        self.routed[rid] += 1
        return rid

    def _route_affinity(self, req: Request) -> int:
        n = len(self.engines)
        keys = (prefix_keys(req.prompt[: self.max_seq - 1],
                            self.block_size) if self._affine else [])
        if keys:
            depths = [
                len(e.scheduler.prefix.peek(keys))
                if e.scheduler.prefix is not None else 0
                for e in self.engines
            ]
            best = max(depths)
            if best > 0:
                # a replica already holds this prefix: deepest hit wins,
                # load breaks ties
                rid = min((r for r in range(n) if depths[r] == best),
                          key=self._load_key)
                self.affinity_hits += 1
                self.affinity_hit_blocks += best
                return rid
            # cold prefix: stable hash of the first block's key, so the
            # whole prefix family converges on one replica
            rid = int.from_bytes(keys[0][:8], "little") % n
            if n > 1 and self._overloaded(rid, req):
                self.load_fallbacks += 1
                return min(range(n), key=self._load_key)
            self.cold_affinity += 1
            return rid
        self.load_routed += 1
        return min(range(n), key=self._load_key)

    def submit(self, req: Request) -> int:
        """Route and enqueue; returns the chosen replica id."""
        rid = self.route(req)
        self.engines[rid].submit(req)
        return rid

    # ------------------------------------------------------------------ #
    # sync driver (benchmarks/tests; the async frontend threads replicas)
    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """One step on every replica that has work; returns total active.
        Also harvests fresh completions into the TTFT EWMA."""
        total = 0
        for rid, eng in enumerate(self.engines):
            if eng.has_work():
                total += eng.step()
            done = eng.completed
            for req in done[self._done_seen[rid]:]:
                self.observe_ttft(rid, req.metrics.ttft)
            self._done_seen[rid] = len(done)
        return total

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.engines)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            self.step()
            if not self.has_work():
                return self.completed
        raise RuntimeError(
            f"router drain: {max_steps} steps exhausted with work left on "
            f"{sum(1 for e in self.engines if e.has_work())} replicas")

    @property
    def completed(self) -> list[Request]:
        out: list[Request] = []
        for e in self.engines:
            out.extend(e.completed)
        return out

    # ------------------------------------------------------------------ #
    def stats(self) -> dict[str, float]:
        """Routing-layer counters (per-replica spread + affinity mix)."""
        total = sum(self.routed)
        keyed = self.affinity_hits + self.cold_affinity + self.load_fallbacks
        out = {
            "replicas": float(len(self.engines)),
            "routed_total": float(total),
            "affinity_hits": float(self.affinity_hits),
            "affinity_hit_blocks": float(self.affinity_hit_blocks),
            "cold_affinity": float(self.cold_affinity),
            "load_fallbacks": float(self.load_fallbacks),
            "load_routed": float(self.load_routed),
        }
        if keyed:
            out["affinity_hit_rate"] = self.affinity_hits / keyed
        for rid, c in enumerate(self.routed):
            out[f"replica{rid}_routed"] = float(c)
        return out

    def metrics_summary(self) -> dict[str, float]:
        """Cross-replica aggregate of the engines' per-request summaries
        (means weighted by completed-request count) plus routing stats."""
        summaries = [(e.metrics_summary(), e) for e in self.engines]
        summaries = [(m, e) for m, e in summaries if m]
        out: dict[str, float] = {}
        if summaries:
            total = sum(m["requests"] for m, _ in summaries)
            out["requests"] = total
            for key in ("mean_ttft_s", "mean_queue_wait_s",
                        "mean_decode_tok_per_s", "mean_prefix_hit_tokens"):
                vals = [(m[key], m["requests"]) for m, _ in summaries
                        if key in m and not math.isnan(m[key])]
                if vals:
                    w = sum(n for _, n in vals)
                    out[key] = sum(v * n for v, n in vals) / w
            for key in ("preemptions", "requeues", "truncated_requests",
                        "spec_proposed", "spec_accepted"):
                s = sum(m.get(key, 0.0) for m, _ in summaries)
                if key in summaries[0][0] or s:
                    out[key] = s
        out.update(self.stats())
        return out


def make_replica_engines(api, params, *, replicas: int, tp: int = 1,
                         use_meshes: bool | None = None,
                         **engine_kw) -> list[ServingEngine]:
    """Build ``replicas`` interchangeable engines for a :class:`Router`.

    ``use_meshes=True`` pins each replica to its own device slice via
    :func:`repro.launch.mesh.make_replica_meshes` (needs ``replicas * tp``
    devices — the realized data axis); ``False`` co-locates every replica
    on the default device (distinct pools and schedulers, shared compute —
    fine for tests and CPU benches); ``None`` (default) uses meshes when
    the devices are there. ``tp > 1`` always needs meshes.
    """
    import jax

    if use_meshes is None:
        use_meshes = tp > 1 or jax.device_count() >= replicas * tp
    if tp > 1 and not use_meshes:
        raise ValueError("tp > 1 replicas need per-replica meshes")
    if use_meshes:
        from repro.launch.mesh import make_replica_meshes
        meshes = make_replica_meshes(replicas, tp)
    else:
        meshes = None
    return [
        ServingEngine(api, params,
                      mesh=None if meshes is None else meshes[r],
                      **engine_kw)
        for r in range(replicas)
    ]
