"""Zero-parameter n-gram draft proposer for speculative decoding.

Speculative decoding splits a decode step into *propose* (cheap guesses
for the next ``k`` tokens) and *verify* (one model call over all ``k``
drafts at once). The proposer here is the cheapest one that works: it
guesses that the stream will repeat itself. For each decoding slot it
matches the longest recent suffix of ``prompt + generated`` against an
earlier occurrence in the same request's history and proposes the tokens
that followed that occurrence — no draft model, no extra parameters, no
device work. On repetitive or structured outputs (code, JSON, quoted
context, the short cycles tiny greedy models fall into) acceptance rates
are high enough to multiply decode throughput; on incompressible text it
degrades to proposing nothing, which costs one O(history) host-side scan
and nothing on device.

Correctness never depends on the proposer: every draft is verified by the
engine's chunk-causal ``(B, 1 + k)`` decode-prefill, and only the longest
prefix of drafts that *exactly matches* what non-speculative decoding
would have emitted (greedy argmax, or the per-``(seed, len(generated))``
PRNG draw) is accepted. A bad proposal wastes a little compute; it can
never change the token stream.

The proposer is a plain function over a token list, deliberately
stateless: the engine's per-request history IS the state, so preemption /
requeue-as-prefill (which rebuilds ``prompt + generated``) needs no extra
bookkeeping here.
"""

from __future__ import annotations

from dataclasses import dataclass


def propose_ngram(history: list[int], k: int, *, max_ngram: int = 3,
                  min_ngram: int = 1) -> list[int]:
    """Propose up to ``k`` draft tokens continuing ``history``.

    Matches the longest suffix n-gram (``max_ngram`` down to
    ``min_ngram`` tokens) of ``history`` against an earlier occurrence
    and returns the tokens that followed it, capped at ``k``. Longer
    n-grams are tried first (more context, higher acceptance); among
    matches, the most recent occurrence with a FULL ``k``-token
    continuation wins — recency makes local repetition beat stale
    repetition, but a match flush against the end of history proposes
    almost nothing (inside a constant run the nearest match yields a
    1-token continuation; the full-window match a few positions left
    yields ``k``). When no match has ``k`` tokens of continuation the
    longest one found is returned. Returns ``[]`` when nothing matches —
    the engine then falls back to a plain one-token decode step for
    that slot.
    """
    if k <= 0:
        return []
    L = len(history)
    best: list[int] = []
    for n in range(min(max_ngram, L - 1), min_ngram - 1, -1):
        pattern = history[L - n:]
        # scan candidate start positions right-to-left: the match must end
        # strictly before the suffix itself so the continuation is real
        for i in range(L - n - 1, -1, -1):
            if history[i:i + n] == pattern:
                cont = history[i + n:i + n + k]
                if len(cont) == k:
                    return cont
                if len(cont) > len(best):
                    best = cont
    return best


@dataclass
class NgramProposer:
    """Configured proposer handle the engine holds: ``k`` drafts per slot
    from (``min_ngram`` .. ``max_ngram``)-token suffix matches. ``k`` is
    the *ceiling* — the engine further caps per-slot drafts by the chunk
    width, the request's remaining token budget and the ``max_seq``
    boundary so acceptance can never overrun either."""
    k: int = 4
    max_ngram: int = 3
    min_ngram: int = 1

    def __post_init__(self):
        if self.k < 0:
            raise ValueError(f"spec k must be >= 0, got {self.k}")
        if not 1 <= self.min_ngram <= self.max_ngram:
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram, got "
                f"{self.min_ngram}..{self.max_ngram}")

    def propose(self, history: list[int], k: int | None = None) -> list[int]:
        k = self.k if k is None else min(k, self.k)
        return propose_ngram(history, k, max_ngram=self.max_ngram,
                             min_ngram=self.min_ngram)
