"""Tiered KV cache: host-RAM spill tier + disk persistence for prefixes.

The paged pools in HBM are the only tier PRs 2–8 knew: when the prefix
map ran out of room, :meth:`~repro.serving.paged.PrefixCache.evict`
dropped cold entries and the next request paid a full re-prefill. This
module adds the two tiers below and the policy that moves blocks between
them.

Tier-transition state machine
-----------------------------
A registered prefix block is always in exactly ONE tier::

        register                   evict pressure
   (new) ───────► HBM ───────────────────────────► host
                   ▲    spill: batched device_get,  │
                   │    block freed in HBM          │ host pool full /
                   │                                │ lower priority
        fetch_into_hbm: batched                     ▼
        device write into a fresh                 (dropped)
        block, entry removed from
        host pool
                  HBM ◄─────────────── host
                          prefix hit

   host ──save_kv_store()──► disk ──engine restart──► host
          (snapshot of BOTH            (preload_host: digest-keyed,
           tiers, digest-keyed,         layout-checked; first hit
           CRC + layout meta)           then fetches into HBM)

* **HBM → host (spill)**: under eviction pressure, instead of dropping a
  cold entry, its block contents are pulled to host RAM (one batched
  ``device_get`` per eviction pass — victims are gathered first, then
  extracted in a single indexed slice per pool leaf) and the HBM block
  is freed. The host pool admits by priority: an incoming entry may
  evict host entries of priority <= its own (priority-ascending, LRU
  within a class) but never a hotter one; if room still cannot be made,
  the entry is dropped exactly as the single-tier cache would have.
* **host → HBM (fetch)**: on a prefix hit whose chain continues into the
  host tier, the continuation is fetched back *before admission*: fresh
  HBM blocks are allocated — spilling colder idle map entries down to
  host first when the free list is short (*evict-to-fetch*; the current
  admission's own HBM hit run is pinned and can never be chosen, and a
  chain never self-evicts because its keys are not in the map while they
  are being fetched) — one batched device write inserts the data, and
  the entries move back into the map. The admitting request then sees
  them as ordinary HBM hits. If admission still falls through, the
  fetched entries simply remain in the map as evictable entries — the
  next attempt peeks them as HBM hits, so the work converges rather
  than thrashing. Capacity accounting is unmoved by evict-to-fetch:
  every spill frees exactly the block its fetch consumes, so
  ``would_admit``'s free+evictable bound holds before and after.
* **host ⇄ disk (persist / warm restart)**: ``engine.save_kv_store()``
  snapshots both tiers (digest key → per-leaf numpy block) through
  :class:`repro.checkpoint.manager.PrefixStore` — atomic tmp + rename,
  CRC-checked, with the pool layout recorded in meta. On restart the
  store is loaded into the *host* pool (never straight into HBM — the
  new process's pool is cold and admission decides what is hot); a
  stale or corrupt store logs a warning and the engine serves cold.

Bitwise identity
----------------
Serving through the tiers is bitwise identical to the untiered path.
A prefix hit — from either tier — means the admitted request *skips*
prefill for those blocks and reads their K/V through the page table;
a miss means it recomputes exactly the same K/V values from the same
tokens (prefill is deterministic given the prompt). Spill/fetch moves
block bytes verbatim (``device_get`` then a device write of the same
array), so a spilled-then-refetched block is bit-exact by construction,
and the only observable difference between tier configurations is
*latency*, never token streams.

"Pinned" host memory: on TPU/GPU backends ``device_get`` into a
preallocated pinned buffer would make the spill DMA async; under the CPU
jax used in CI the arrays are plain numpy and the ``copy_to_host_async``
hint in the engine's extract hook is a no-op. The accounting here is
backend-blind either way.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.serving.paged import BlockAllocator, PrefixCache

# extract(bids) -> {leaf path: stacked per-block array}; insert(bids, data)
# writes them back. Bound by the engine, which owns the device pools.
ExtractFn = Callable[[list[int]], dict[str, np.ndarray]]
InsertFn = Callable[[list[int], dict[str, np.ndarray]], None]


@dataclass
class _HostEntry:
    """One spilled prefix block resident in host RAM."""
    data: dict[str, np.ndarray]      # leaf path -> per-block array
    priority: int = 0
    nbytes: int = field(default=0)

    def __post_init__(self) -> None:
        if not self.nbytes:
            self.nbytes = sum(int(a.nbytes) for a in self.data.values())


class HostPool:
    """Fixed-capacity host-RAM pool of spilled prefix blocks.

    Keyed by the same 128-bit prefix digests as the HBM map, one entry
    per block. Admission is priority-aware: :meth:`put` makes room by
    evicting resident entries whose priority class is <= the incoming
    entry's (lowest class first, LRU within a class) and rejects the
    incoming entry when even that cannot free a slot — a cold
    low-priority spill never displaces a hot high-priority one.
    """

    def __init__(self, capacity_blocks: int):
        self.capacity = int(capacity_blocks)
        self._map: OrderedDict[bytes, _HostEntry] = OrderedDict()
        self.evicted = 0          # host entries dropped to make room
        self.rejected = 0         # incoming spills refused (pool too hot)

    def __len__(self) -> int:
        return len(self._map)

    def __contains__(self, key: bytes) -> bool:
        return key in self._map

    @property
    def used_blocks(self) -> int:
        return len(self._map)

    @property
    def free_blocks(self) -> int:
        return self.capacity - len(self._map)

    def get(self, key: bytes) -> _HostEntry | None:
        return self._map.get(key)

    def keys(self) -> list[bytes]:
        return list(self._map)

    def put(self, key: bytes, data: dict[str, np.ndarray],
            priority: int = 0) -> bool:
        """Admit a spilled block; returns False when it was refused.
        Re-putting an existing key refreshes data/recency and bumps the
        entry's class to the max of old and new."""
        if self.capacity <= 0:
            self.rejected += 1
            return False
        if key in self._map:
            old = self._map[key]
            self._map[key] = _HostEntry(data, max(old.priority, priority))
            self._map.move_to_end(key)
            return True
        if len(self._map) >= self.capacity:
            # evict only classes <= the incoming one: priority asc, LRU
            # within a class (stable sort over the OrderedDict's LRU order)
            victims = sorted(
                (k for k, e in self._map.items() if e.priority <= priority),
                key=lambda k: self._map[k].priority)
            need = len(self._map) - self.capacity + 1
            if len(victims) < need:
                self.rejected += 1
                return False
            for k in victims[:need]:
                del self._map[k]
                self.evicted += 1
        self._map[key] = _HostEntry(data, priority)
        return True

    def pop(self, key: bytes) -> _HostEntry | None:
        """Remove and return an entry (fetch path: the block is moving
        back to HBM — no dual residency)."""
        return self._map.pop(key, None)

    def touch(self, key: bytes) -> None:
        if key in self._map:
            self._map.move_to_end(key)

    def flush(self) -> int:
        n = len(self._map)
        self._map.clear()
        return n


class TieredPrefixCache(PrefixCache):
    """:class:`PrefixCache` whose eviction spills into a :class:`HostPool`
    and whose hit path re-fetches spilled chains into HBM.

    Drop-in for the scheduler: ``peek``/``acquire``/``commit``/
    ``register``/``evict`` keep their single-tier contracts; the tier
    machinery hides behind :meth:`evict` (spill instead of drop),
    :meth:`fetch_into_hbm` (called by the scheduler between peek and
    placement) and :meth:`peek_depth` (tier-aware — the router's
    affinity and any capacity probe see host-resident chain depth).

    The device I/O is injected via :meth:`bind_device_io` because this
    object is layout-blind: the engine owns the pools and knows how to
    slice block ``bid`` out of every K/V leaf. Until bound (or when the
    host pool has zero capacity), eviction degrades to the plain drop
    of the base class — correctness never depends on the host tier.
    """

    def __init__(self, alloc: BlockAllocator, host: HostPool):
        super().__init__(alloc)
        self.host = host
        self._extract: ExtractFn | None = None
        self._insert: InsertFn | None = None
        self.spilled_blocks = 0
        self.fetched_blocks = 0
        self.dropped_blocks = 0        # evicted with nowhere to spill
        self.host_hits = 0             # chain blocks served from host tier
        self.fetch_ewma_s = 0.0        # per-batch fetch latency EWMA

    def bind_device_io(self, extract: ExtractFn, insert: InsertFn) -> None:
        self._extract = extract
        self._insert = insert

    # -- spill ---------------------------------------------------------- #
    def evict(self, n_blocks: int) -> int:
        """Free up to ``n_blocks`` idle entries (priority-then-LRU, same
        order as the base class) — but spill each victim's block contents
        into the host pool first when it has room for that entry's class.
        One batched extract covers the whole pass."""
        victims = self._evict_order()[:n_blocks]
        if not victims:
            return 0
        if self._extract is not None and self.host.capacity > 0:
            bids = [self._map[k] for k in victims]
            stacked = self._extract(bids)      # ONE device_get for the pass
            for i, k in enumerate(victims):
                data = {path: np.ascontiguousarray(arr[:, i])
                        for path, arr in stacked.items()}
                if self.host.put(k, data, self._pri.get(k, 0)):
                    self.spilled_blocks += 1
                else:
                    self.dropped_blocks += 1
                self._drop_entry(k)
        else:
            for k in victims:
                self.dropped_blocks += 1
                self._drop_entry(k)
        return len(victims)

    # -- fetch ---------------------------------------------------------- #
    def fetch_into_hbm(self, keys: list[bytes], hits: list[int],
                       max_hits: int) -> list[int]:
        """Extend the HBM hit run through host-resident continuation
        blocks: allocate fresh HBM blocks, one batched insert, move the
        entries back into the map (removed from the host pool — a block
        is never resident in two tiers). Capped at ``max_hits`` so the
        caller's never-skip-the-whole-prompt rule stays intact.

        When the free list cannot fund the whole chain, colder idle map
        entries are spilled down first (evict-to-fetch): a revisited
        prefix displaces idle strangers instead of re-prefilling. The
        caller's own HBM hit run is temporarily pinned so it can never
        be chosen, and the chain cannot self-evict (its keys are not in
        the map while in flight). The eviction's own spills may displace
        chain entries *from the host pool* (priority-ordered), so the
        chain is re-scanned afterwards."""
        if self._insert is None or len(self.host) == 0:
            return hits

        def scan() -> list[bytes]:
            out: list[bytes] = []
            for k in keys[len(hits):max_hits]:
                if k not in self.host:
                    break
                out.append(k)
            return out

        chain = scan()
        if not chain:
            return hits
        short = len(chain) - self.alloc.free_blocks
        if short > 0 and self.evictable() > 0:
            self.acquire(hits)     # the admission's hit run is off-limits
            self.evict(short)
            self.release(hits)
            chain = scan()         # spills may have displaced chain entries
        n = min(len(chain), self.alloc.free_blocks)
        if n <= 0:
            return hits
        chain = chain[:n]
        t0 = time.monotonic()
        entries = [self.host.pop(k) for k in chain]
        bids = self.alloc.alloc(len(chain))    # refcount 1 = the map's ref
        stacked = {path: np.stack([e.data[path] for e in entries], axis=1)
                   for path in entries[0].data}
        self._insert(bids, stacked)            # ONE device write for the run
        for k, bid, e in zip(chain, bids, entries):
            self._map[k] = bid
            if e.priority:
                self._pri[k] = max(self._pri.get(k, 0), e.priority)
        dt = time.monotonic() - t0
        self.fetch_ewma_s = (dt if self.fetch_ewma_s == 0.0
                             else 0.8 * self.fetch_ewma_s + 0.2 * dt)
        self.fetched_blocks += len(chain)
        self.host_hits += len(chain)
        return hits + bids

    # -- tier-aware reads ----------------------------------------------- #
    def peek_depth(self, keys: list[bytes]) -> int:
        """HBM hit run plus its host-resident continuation. Pure read —
        the router's affinity policy counts spilled chains as hits so
        traffic keeps landing where its prefix lives, in either tier."""
        d = len(self.peek(keys))
        for k in keys[d:]:
            if k not in self.host:
                break
            d += 1
        return d

    # -- persistence hooks ---------------------------------------------- #
    def preload_host(self, entries: dict[bytes, tuple[int, dict[str, np.ndarray]]]
                     ) -> int:
        """Warm restart: load persisted entries into the HOST tier (never
        straight into HBM — admission decides what gets fetched up).
        Stops when the pool is full; returns how many were loaded."""
        n = 0
        for key, (priority, data) in entries.items():
            if self.host.free_blocks <= 0:
                break
            if self.host.put(key, data, priority):
                n += 1
        return n

    def snapshot(self) -> dict[bytes, tuple[int, dict[str, np.ndarray]]]:
        """Both tiers as ``{digest: (priority, per-leaf block data)}`` for
        the disk store. HBM entries go through one batched extract."""
        out: dict[bytes, tuple[int, dict[str, np.ndarray]]] = {}
        if self._extract is not None and self._map:
            hbm_keys = list(self._map)
            stacked = self._extract([self._map[k] for k in hbm_keys])
            for i, k in enumerate(hbm_keys):
                data = {path: np.ascontiguousarray(arr[:, i])
                        for path, arr in stacked.items()}
                out[k] = (self._pri.get(k, 0), data)
        for k in self.host.keys():
            e = self.host.get(k)
            out[k] = (e.priority, e.data)
        return out

    def tier_stats(self) -> dict[str, float]:
        return {
            "tier_spilled_blocks": float(self.spilled_blocks),
            "tier_fetched_blocks": float(self.fetched_blocks),
            "tier_dropped_blocks": float(self.dropped_blocks),
            "tier_host_hits": float(self.host_hits),
            "host_pool_blocks": float(self.host.used_blocks),
            "host_pool_capacity": float(self.host.capacity),
            "tier_fetch_ewma_s": self.fetch_ewma_s,
        }


def blocks_for_bytes(host_cache_gb: float, block_bytes: int) -> int:
    """How many host-pool blocks fit in ``host_cache_gb`` gigabytes given
    the per-block byte footprint across every K/V leaf."""
    if host_cache_gb <= 0 or block_bytes <= 0:
        return 0
    return int(host_cache_gb * (1 << 30)) // block_bytes
