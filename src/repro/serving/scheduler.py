"""Scheduling policy for the serving engine: priority admission, block
placement and block-level preemption, carved out of ``ServingEngine``.

The engine (:mod:`repro.serving.engine`) keeps only the device-facing
machinery — the jitted step, the sharding env, metrics aggregation. Every
*decision* about which request runs where, and which blocks it holds,
lives here, host-side and layout-blind: block ids mean the same thing on
every tensor-parallel shard, so a ``tp=N`` engine constructs exactly the
same scheduler as ``tp=1`` and the policy never sees the mesh.

Queue policy
------------
``policy="priority"`` (default): a priority queue over
``Request.priority`` classes (higher = more urgent), FIFO within a class
by submit order. With every request at the default priority 0 the queue
degenerates to the exact FIFO of PRs 1–4 — the default engine behavior is
unchanged. ``policy="fifo"`` ignores the priority field entirely (and
disables preemption): the literal pre-scheduler queue.

Anti-starvation aging: with ``aging_s > 0``, a queued request's
*effective* priority grows by one class per ``aging_s`` seconds of queue
wait, so a bulk request can only be starved for a bounded time by a
steady interactive stream. ``aging_s = 0`` (default) disables aging.
Wait is measured from the **current stint's** enqueue time — submit, or
requeue after a preemption — never from ``submit_t``: time spent
*running* between stints is not starvation, and counting it would let a
preempted bulk request carry an inflated aged class back into the queue.
Within a class, never-preempted requests age from monotone submit times
and keep exact FIFO; a requeued victim restarts its aging clock (its
FIFO *ticket* is still the original). Aging affects **admission order
only**: preemption eligibility always compares *static* classes, so an
aged bulk request gains precedence for the next free slot but never the
right to evict running work of its own class — and a long-running
active cannot age itself un-preemptible.

Admission is head-of-line blocking in queue order: if the best-ranked
request cannot be placed (even after eviction and preemption), nothing
behind it is tried. Skip-ahead would let a stream of small requests
starve a large one forever; head-of-line keeps the bound from aging
meaningful.

Placement (paged)
-----------------
Two-phase, per request: ``peek`` the prefix cache for reusable leading
prompt blocks (pure read), compute the fresh-block need, and only then
``acquire``/``alloc``/``commit`` — a *failed* attempt mutates nothing, so
per-step retries of a blocked admission are free of refcount churn and
LRU skew. Under pool pressure the shortfall is covered in escalating
order:

1. **prefix eviction** — LRU idle entries of the prefix map are freed
   (only when eviction actually covers the shortfall; flushing hot
   prefixes that still leave the request unplaceable buys nothing);
2. **preemption** — if eviction cannot cover it, the lowest-effective-
   priority active request is preempted, but only when its priority is
   *strictly below* the candidate's (equal-priority workloads — e.g. the
   all-default FIFO case — never preempt, so there is no thrash cycle).
   Victims are chosen lowest priority first, most-recently-admitted on
   ties (least work lost). A cheap reclaimable-blocks pre-check runs
   first: if even preempting every eligible victim cannot cover the
   need, no victim is disturbed.

Preemption fires for *slot* contention as well as block shortage: when
every slot is busy and the queue head strictly outranks some active
request, the cheapest such victim yields its slot (and with it, its
blocks) — a high-priority arrival never waits out a full bulk decode.

Preemption = requeue-as-prefill
-------------------------------
A preempted victim's blocks are decref'd straight back to the free list
(its prefix-registered blocks survive in the map — the map holds its own
reference — and become evictable like any idle entry). The victim itself
is re-queued with its generated-so-far tokens **folded into the resume
prompt**, so resuming is a plain re-prefill of ``prompt + generated``
that can ride its own prefix hits (including blocks the victim itself
registered before being preempted).

Why requeue-as-prefill rather than snapshotting KV state: a snapshot
would have to spill ``O(len · layers)`` KV bytes somewhere off-pool —
exactly the memory we are reclaiming — or pin the blocks it is supposed
to free. Recomputing the prefix is pure compute on data we still have
(the tokens), costs no pool memory while the victim waits, and reuses
the chunked-prefill path that already exists; with the prefix cache on,
the victim's own published blocks often make the re-prefill partial.
The PRNG sampling stream is keyed by ``(seed, len(generated))``, so a
resumed request continues sampling exactly where it left off.

Bookkeeping owned here: the queue, the :class:`~repro.serving.paged.
BlockAllocator` and :class:`~repro.serving.paged.PrefixCache` handles,
per-slot block lists / prefix keys / hit counts / prompt lengths, the
``(B, max_blocks)`` page-table rows, and the prompt-key memo (keyed by
``Request.uid`` — never ``id(req)``, which can alias after GC — and
dropped whenever a request leaves the queue for any reason).
"""

from __future__ import annotations

import math
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import TYPE_CHECKING

import numpy as np

from repro.serving.paged import (BlockAllocator, PrefixCache,
                                 blocks_for_tokens, prefix_keys)

if TYPE_CHECKING:   # pragma: no cover - typing only, no engine import cycle
    from repro.serving.engine import Request

POLICIES = ("priority", "fifo")


@dataclass
class _Entry:
    """One queued request plus its scheduling state.

    ``prompt`` is the *effective* prompt — the original at first submit,
    ``original + generated`` after a preemption — so placement and
    prefill never need to know whether this is a resume. ``seq`` is the
    submit ticket used for FIFO tie-breaks; a preempted request keeps its
    original ticket and so resumes at its old FIFO position within its
    class. ``enq_t`` is when THIS queue stint began (submit, or requeue
    after a preemption): aging and queue-wait accounting read it, never
    ``metrics.submit_t`` — a victim's *running* time is not queue wait
    and must not inflate its aged class.
    """
    req: "Request"
    seq: int
    prompt: list[int]
    enq_t: float = field(default=0.0)
    resumed: bool = field(default=False)


class Scheduler:
    """Owns every scheduling decision and all host-side slot bookkeeping.

    The engine calls, in order, per step: :meth:`admit` (fills free slots,
    possibly evicting/preempting), reads ``active`` / ``pending_prompt``
    / ``pages`` / ``pos`` to build the batch, then :meth:`advance` per
    stepped slot, :meth:`register_prompt_blocks` when a slot's prompt is
    fully absorbed, and :meth:`release` when a request completes.
    """

    def __init__(self, *, max_batch: int, max_seq: int, chunk: int,
                 paged: bool, block_size: int = 16,
                 num_blocks: int | None = None, prefix_cache: bool = True,
                 policy: str = "priority", aging_s: float = 0.0,
                 preemption: bool = True, host_cache_blocks: int = 0):
        if policy not in POLICIES:
            raise ValueError(f"unknown scheduler policy {policy!r}; "
                             f"one of {POLICIES}")
        self.B = max_batch
        self.max_seq = max_seq
        self.policy = policy
        self.aging_s = float(aging_s)
        # "fifo" is the literal pre-scheduler queue: priorities ignored,
        # nothing ever preempted
        self.preemption = bool(preemption) and policy == "priority"
        self.paged = paged

        self._queue: list[_Entry] = []
        self._seq = 0                     # submit ticket counter
        # uid -> ticket, held while the request is anywhere inside the
        # scheduler (queued OR active) so a preempted victim requeues at
        # its original FIFO position; dropped at finish(). In-flight uids
        # must be unique — the ticket and key memos key on them.
        self._ticket: dict[int, int] = {}
        self.active: list["Request" | None] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)       # next write index
        self.pending_prompt: list[deque[int]] = [
            deque() for _ in range(max_batch)]
        self.preemptions = 0              # victims evicted mid-flight
        self.requeues = 0                 # preempted requests re-admitted
        self.cancelled = 0                # requests dropped via cancel()
        self.spec_proposed = 0            # speculative draft tokens verified
        self.spec_accepted = 0            # ... of which matched the stream
        self._placing: list[int] = []     # slots filled by the live admit

        if paged:
            self.block_size = int(block_size)
            # tables must cover every write of a padded chunk starting at
            # pos <= max_seq - 1 (pads past that spill into garbage blk 0)
            self.max_blocks = -(-(max_seq + chunk) // self.block_size)
            # default pool: every slot can hold a max-length request, + the
            # garbage block; size it down to oversubscribe slots on memory
            self.num_blocks = (num_blocks if num_blocks is not None
                               else max_batch * self.max_blocks + 1)
            self.alloc = BlockAllocator(self.num_blocks, self.block_size)
            if not prefix_cache:
                self.prefix = None
            elif host_cache_blocks > 0:
                # tiered: eviction pressure spills registered prefixes to a
                # host-RAM pool instead of dropping them; the engine binds
                # the device extract/insert hooks after state init
                from repro.serving.tiering import HostPool, TieredPrefixCache
                self.prefix = TieredPrefixCache(
                    self.alloc, HostPool(host_cache_blocks))
            else:
                self.prefix = PrefixCache(self.alloc)
            self.pages = np.zeros((max_batch, self.max_blocks), np.int32)
            self._prompt_keys: dict[int, list[bytes]] = {}  # req.uid -> keys
            self._slot_blocks: list[list[int]] = [[] for _ in range(max_batch)]
            self._slot_keys: list[list[bytes]] = [[] for _ in range(max_batch)]
            self._slot_hits = np.zeros(max_batch, np.int32)
            self._slot_plen = np.zeros(max_batch, np.int32)
        else:
            self.block_size = 0
            self.max_blocks = 0
            self.num_blocks = 0
            self.alloc = None
            self.prefix = None
            self.pages = None

    # ------------------------------------------------------------------ #
    # queue
    # ------------------------------------------------------------------ #
    @property
    def queue(self) -> list["Request"]:
        """Queued requests in current scheduling order (head admits first)."""
        self._sort(time.monotonic())
        return [e.req for e in self._queue]

    def effective_priority(self, entry: _Entry, now: float) -> int:
        """Static class + aging boost (one class per ``aging_s`` of the
        current queue stint — measured from ``entry.enq_t``, so a
        preempted request's time spent running never counts as wait)."""
        if self.policy == "fifo":
            return 0
        boost = 0
        if self.aging_s > 0:
            boost = int(max(0.0, now - entry.enq_t) / self.aging_s)
        return entry.req.priority + boost

    def _sort(self, now: float) -> None:
        self._queue.sort(
            key=lambda e: (-self.effective_priority(e, now), e.seq))

    def submit(self, req: "Request", now: float | None = None) -> None:
        """Validate, memoize prefix keys, and enqueue. Raises when the
        request can never fit the pool (a mid-scheduling failure would
        wedge the head-of-line queue forever). An over-long prompt is
        truncated to ``max_seq - 1`` tokens — loudly: a warning fires and
        ``req.truncated`` is set so callers can tell the response
        continues a clipped prompt, not the one they sent."""
        now = time.monotonic() if now is None else now
        if req.uid in self._ticket:
            # the ticket and prompt-key memos key on uid: a duplicate
            # would alias this request onto the other's prefix keys and
            # could license prefix hits on the wrong prompt's KV blocks
            raise ValueError(
                f"request uid {req.uid} is already in flight — uids must "
                f"be unique among queued/active requests")
        prompt = req.prompt[: self.max_seq - 1]
        if len(prompt) < len(req.prompt):
            req.truncated = True
            warnings.warn(
                f"request {req.uid}: prompt of {len(req.prompt)} tokens "
                f"truncated to {len(prompt)} (max_seq={self.max_seq} "
                f"keeps one position for generation)",
                RuntimeWarning, stacklevel=2)
        if self.paged:
            need = self._entry_blocks(prompt, req)
            if need > self.num_blocks - 1:
                raise ValueError(
                    f"request {req.uid} needs {need} blocks; pool has "
                    f"{self.num_blocks - 1} usable — raise num_blocks or "
                    f"lower max_seq/max_new_tokens")
        req.metrics.submit_t = now
        self._ticket[req.uid] = self._seq
        self._enqueue(_Entry(req, self._seq, prompt, enq_t=now))
        self._seq += 1

    def resubmit(self, req: "Request", now: float | None = None) -> None:
        """Re-enqueue a request that already ran — and possibly generated
        tokens — on ANOTHER engine: the cross-replica face of the
        requeue-as-prefill path (replica death, worker crash). The
        generated-so-far tokens fold into the resume prompt exactly as
        :meth:`preempt` does locally, so the next admission re-prefills
        ``prompt + generated`` and the per-``(seed, len(generated))``
        PRNG stream continues bitwise. Metrics carry over (``submit_t``
        is preserved so TTFT spans the failure); a fresh FIFO ticket is
        issued — the original belonged to the dead engine's queue.

        Raises ValueError when the resume prompt no longer fits
        ``max_seq - 1``: such a request was within one position of its
        forced finish, and migrating it would drop generated tokens and
        corrupt the stream — fail it loudly instead (same finish-over-
        evict rule as :meth:`_resumable`)."""
        now = time.monotonic() if now is None else now
        if req.uid in self._ticket:
            raise ValueError(
                f"request uid {req.uid} is already in flight here — uids "
                f"must be unique among queued/active requests")
        resume = req.prompt[: self.max_seq - 1] + req.generated
        if len(resume) > self.max_seq - 1:
            raise ValueError(
                f"request {req.uid} cannot migrate: resume prompt of "
                f"{len(resume)} tokens exceeds max_seq - 1 = "
                f"{self.max_seq - 1} — resuming would drop generated "
                f"tokens")
        if self.paged:
            need = self._entry_blocks(resume, req)
            if need > self.num_blocks - 1:
                raise ValueError(
                    f"request {req.uid} needs {need} blocks; pool has "
                    f"{self.num_blocks - 1} usable")
        if req.metrics.submit_t == 0.0:
            req.metrics.submit_t = now
        self._ticket[req.uid] = self._seq
        self._enqueue(_Entry(req, self._seq, resume, enq_t=now,
                             resumed=bool(req.generated)))
        self._seq += 1

    def cancel(self, uid: int) -> bool:
        """Drop a request wherever it is — queued or active — freeing its
        blocks and ticket (client disconnect, deadline expiry). Returns
        False when the uid is unknown (already completed or never
        submitted): cancellation racing completion is benign."""
        for entry in self._queue:
            if entry.req.uid == uid:
                self._dequeue(entry)
                self._ticket.pop(uid, None)
                self.cancelled += 1
                return True
        for slot, req in enumerate(self.active):
            if req is not None and req.uid == uid:
                self.finish(slot)
                self.cancelled += 1
                return True
        return False

    def drain_queue(self) -> list["Request"]:
        """Remove and return every queued request in scheduling order,
        dropping tickets and key memos — the router's migration harvest
        pulls a dead replica's backlog through this."""
        self._sort(time.monotonic())
        entries = list(self._queue)
        for entry in entries:
            self._dequeue(entry)
            self._ticket.pop(entry.req.uid, None)
        return [e.req for e in entries]

    def _enqueue(self, entry: _Entry) -> None:
        if self.paged and self.prefix is not None:
            # memoize: admission may retry every step while the pool is
            # short; the O(plen) key build must not repeat. Keyed by uid —
            # id(req) can alias a recycled object onto stale keys.
            self._prompt_keys[entry.req.uid] = prefix_keys(
                entry.prompt, self.block_size)
        self._queue.append(entry)

    def _dequeue(self, entry: _Entry) -> None:
        """A request leaves the queue for any reason: drop its key memo."""
        self._queue.remove(entry)
        if self.paged:
            self._prompt_keys.pop(entry.req.uid, None)

    # ------------------------------------------------------------------ #
    # placement
    # ------------------------------------------------------------------ #
    def _entry_blocks(self, prompt: list[int], req: "Request") -> int:
        """Total block footprint: what the slot will actually write
        (truncated effective prompt + remaining generation), NOT max_seq.
        Prefix hits reduce *fresh* allocation, never this total (hit
        blocks occupy the pool and stay pinned for the whole request)."""
        remaining = max(1, req.max_new_tokens - len(req.generated))
        return min(blocks_for_tokens(len(prompt) + remaining,
                                     self.block_size), self.max_blocks)

    def _try_place(self, slot: int, entry: _Entry) -> bool:
        """Two-phase paged placement: prefix peek, then block-based
        admission control. Returns False when the pool is short even
        after prefix eviction; a failed attempt mutates nothing."""
        req, prompt = entry.req, entry.prompt
        plen = len(prompt)
        keys = (self._prompt_keys.get(req.uid, [])
                if self.prefix is not None else [])
        hits = self.prefix.peek(keys) if self.prefix is not None else []
        host_hits = 0
        if self.prefix is not None:
            # tiered cache: extend the HBM run through host-resident
            # continuation blocks before admission. Capped at max_hits =
            # (plen-1)//block_size so a fetched block can never trip the
            # never-skip-the-whole-prompt pop below (max_hits * block_size
            # <= plen - 1 < plen). If admission still falls through, the
            # fetched entries stay in the map as evictable HBM hits — the
            # next attempt peeks them directly, so the work converges.
            max_hits = (plen - 1) // self.block_size
            if len(hits) < max_hits:
                n0 = len(hits)
                hits = self.prefix.fetch_into_hbm(keys, hits, max_hits)
                host_hits = len(hits) - n0
        peeked = len(hits)     # pre-pop count: stats/LRU credit ALL hits
        # never skip the whole prompt: >= 1 token must still run through
        # prefill so the step has logits to sample the next token from
        while hits and len(hits) * self.block_size >= plen:
            hits.pop()
        need = self._entry_blocks(prompt, req)
        fresh = need - len(hits)
        if self.prefix is not None:
            # incref hits before any eviction so it can't reclaim them
            self.prefix.acquire(hits)
        short = fresh - self.alloc.free_blocks
        if short > 0:
            # evict only when it actually covers the shortfall — otherwise
            # admission is doomed until an active request completes, and
            # flushing hot prefixes would buy nothing
            if self.prefix is None or self.prefix.evictable() < short:
                if self.prefix is not None:
                    self.prefix.release(hits)
                return False
            self.prefix.evict(short)
        blocks = hits + self.alloc.alloc(fresh)
        if self.prefix is not None:
            # peeked, not len(hits): a full-prompt repeat still touched its
            # deepest block — keep its LRU recency hot and count the hit;
            # the committing request's class also bumps entry priorities
            self.prefix.commit(keys, peeked, priority=req.priority)
        self.active[slot] = req
        self._slot_blocks[slot] = blocks
        self._slot_keys[slot] = keys
        self._slot_hits[slot] = len(hits)
        self._slot_plen[slot] = plen
        self.pages[slot, :] = 0
        self.pages[slot, :len(blocks)] = blocks
        skip = len(hits) * self.block_size
        self.pos[slot] = skip
        self.pending_prompt[slot] = deque(prompt[skip:])
        req.metrics.prefix_hit_tokens = skip
        req.metrics.host_hit_tokens = host_hits * self.block_size
        return True

    def _place_dense(self, slot: int, entry: _Entry) -> None:
        self.active[slot] = entry.req
        self.pos[slot] = 0
        self.pending_prompt[slot] = deque(entry.prompt)

    # ------------------------------------------------------------------ #
    # preemption
    # ------------------------------------------------------------------ #
    def _resumable(self, req: "Request") -> bool:
        """Whether preempting ``req`` loses nothing: its resume prompt
        ``prompt + generated`` must fit in ``max_seq - 1`` positions.
        Past that boundary the old requeue path silently sliced off the
        request's most recent *generated* tokens — the resumed request
        would re-decode from a truncated history and emit a stream that
        never matches an unpreempted run. Such requests are close to the
        ``pos >= max_seq - 1`` finish anyway: finish-over-evict."""
        return (len(req.prompt[: self.max_seq - 1]) + len(req.generated)
                <= self.max_seq - 1)

    def _victims(self, pri: int) -> list[int]:
        """Active slots preemptible for a candidate of STATIC priority
        class ``pri``: strictly lower class, cheapest first (lowest
        class, most recently admitted — least work lost). Preemption
        rights deliberately ignore aging: aging grants a starved request
        admission *precedence*, not the right to evict running work of
        its own class — and an old active must not age itself into
        un-preemptibility either. Slots placed in the CURRENT admit pass
        are off-limits: admitting an aged request and evicting it before
        it runs a single step would be pure churn. Slots whose resume
        prompt would no longer fit (:meth:`_resumable`) are off-limits
        too — evicting them would corrupt their token stream, and they
        are about to free their blocks by finishing anyway."""
        cand = [s for s, r in enumerate(self.active)
                if r is not None and r.priority < pri
                and s not in self._placing and self._resumable(r)]
        cand.sort(key=lambda s: (self.active[s].priority,
                                 -self.active[s].metrics.admit_t))
        return cand

    def preempt(self, slot: int, now: float | None = None) -> "Request":
        """Evict ``slot``'s request mid-flight: every block it holds is
        decref'd back toward the free list (prefix-registered blocks stay
        pinned by the map only, i.e. become evictable), and the request is
        re-queued with ``generated`` folded into its resume prompt so the
        next admission re-prefills it — possibly riding prefix hits on its
        own previously registered blocks. Public so tests and drivers can
        force a deterministic preemption trace."""
        now = time.monotonic() if now is None else now
        req = self.active[slot]
        if req is None:
            raise ValueError(f"slot {slot} is idle — nothing to preempt")
        if not self._resumable(req):
            # the resume prompt would have to drop trailing GENERATED
            # tokens to fit max_seq - 1 — the resumed stream would diverge
            # from an unpreempted run. _victims() never offers such slots;
            # a direct caller gets the loud version of the same rule.
            raise ValueError(
                f"slot {slot} (request {req.uid}) is not preemptible: "
                f"prompt + {len(req.generated)} generated tokens exceed "
                f"max_seq - 1 = {self.max_seq - 1}; resuming would drop "
                f"generated tokens. Let it finish instead")
        self._clear_slot(slot)
        resume = req.prompt[: self.max_seq - 1] + req.generated
        req.metrics.preemptions += 1
        self.preemptions += 1
        # the original ticket: the victim resumes at its old FIFO
        # position within its class, ahead of later arrivals. Fresh
        # enq_t: aging and queue-wait meter this stint only.
        self._enqueue(_Entry(req, self._ticket[req.uid], resume,
                             enq_t=now, resumed=True))
        return req

    def _reclaimable(self, pri: int) -> int:
        """Blocks a full eviction + preemption pass could actually free
        for a candidate of static priority class ``pri``. A victim block
        counts only if dropping every eligible victim's references would
        leave it free (refcount 0) or map-only (evictable); a block a
        non-victim peer slot still shares frees nothing."""
        out = self.alloc.free_blocks
        registered: set[int] = set()
        if self.prefix is not None:
            out += self.prefix.evictable()
            registered = self.prefix.registered_blocks()
        drops: dict[int, int] = {}
        for s in self._victims(pri):
            for bid in self._slot_blocks[s]:
                drops[bid] = drops.get(bid, 0) + 1
        for bid, d in drops.items():
            rc = self.alloc.refcount(bid) - d
            # rc == 1 map-only entries are NOT in evictable() yet (their
            # current refcount is > 1), so this never double-counts
            if rc == 0 or (rc == 1 and bid in registered):
                out += 1
        return out

    def _preempt_for(self, slot: int, entry: _Entry, now: float) -> bool:
        """Eviction fell short: preempt strictly-lower-class victims one
        at a time until ``entry`` places or no victim remains. The
        reclaimable pre-check keeps a doomed candidate from evicting
        victims it cannot benefit from."""
        pri = entry.req.priority
        if self._entry_blocks(entry.prompt, entry.req) \
                > self._reclaimable(pri):
            return False
        while True:
            victims = self._victims(pri)
            if not victims:
                return False
            self.preempt(victims[0], now)
            if self._try_place(slot, entry):
                return True

    # ------------------------------------------------------------------ #
    # the engine-facing step surface
    # ------------------------------------------------------------------ #
    def admit(self, now: float) -> list[int]:
        """Fill slots from the queue in priority order; returns the
        freshly admitted slot ids (the engine zeroes their recurrent
        state rows). Head-of-line blocking: the first unplaceable request
        stops admission for this step. When every slot is busy, a
        strictly-higher-priority head may take a victim's slot (the
        preempted victim's blocks come with it); equal priorities — the
        all-FIFO default — never preempt."""
        fresh: list[int] = []
        self._placing = fresh             # aliased: grows as slots fill
        while self._queue:
            self._sort(now)   # re-rank each fill: preemption can requeue
            entry = self._queue[0]
            slot = next((s for s in range(self.B)
                         if self.active[s] is None), None)
            if slot is None:
                if not self.preemption:
                    break
                pri = entry.req.priority   # static class: aging grants
                victims = self._victims(pri)  # no eviction rights
                # no slot worth taking, or taking one still leaves the
                # request unplaceable block-wise: disturb nobody
                if not victims or (self.paged and self._entry_blocks(
                        entry.prompt, entry.req) > self._reclaimable(pri)):
                    break
                slot = victims[0]
                self.preempt(slot, now)
            if self.paged:
                if not self._try_place(slot, entry) and not (
                        self.preemption
                        and self._preempt_for(slot, entry, now)):
                    break   # pool short: hold queue order, wait for frees
            else:
                self._place_dense(slot, entry)
            self._dequeue(entry)
            m = entry.req.metrics
            m.admit_t = now
            # queue wait is the SUM of stints: submit->first admit plus
            # every preempt->re-admit gap (time running in between is
            # service, not wait). NaN means "never admitted yet".
            wait = max(0.0, now - entry.enq_t)
            m.queued_s = wait if math.isnan(m.queued_s) else m.queued_s + wait
            if entry.resumed:
                self.requeues += 1
            fresh.append(slot)
        # drop the aliased placement guard: slots placed THIS pass were
        # off-limits to _victims only while the pass ran. Leaving the list
        # populated would make the next would_admit() probe (which may run
        # between steps, from another thread's routing decision) treat
        # long-settled slots as untouchable.
        self._placing = []
        return fresh

    @property
    def queue_depth(self) -> int:
        """Number of queued (not active) requests. Unlike the ``queue``
        property this never re-sorts — it is a load signal the router and
        frontend poll from outside the step loop, possibly concurrently
        with it, so it must be a single atomic read."""
        return len(self._queue)

    def would_admit(self, req: "Request") -> bool:
        """Pure probe: could ``req`` be placed right now if it stood at
        the head of the queue? Mutates nothing — no refcounts, no LRU
        recency, no stats — so the router can poll it every request as a
        per-replica load/backpressure signal without skewing admission.

        The answer mirrors :meth:`admit`'s placement logic: a free slot
        (or, with preemption on, a strictly-lower-class resumable victim)
        must exist, and for paged engines the block shortfall must be
        coverable by free + prefix-evictable blocks — or, through the
        victim path, by :meth:`_reclaimable`. Queued requests are
        deliberately ignored: head-of-line order is the *caller's*
        concern (pair with :attr:`queue_depth`), this answers capacity.
        """
        prompt = req.prompt[: self.max_seq - 1]
        slot_free = any(r is None for r in self.active)
        victims = (self._victims(req.priority)
                   if self.preemption else [])
        if not slot_free and not victims:
            return False
        if not self.paged:
            return True
        need = self._entry_blocks(prompt, req)
        if need > self.num_blocks - 1:
            return False
        keys = (prefix_keys(prompt, self.block_size)
                if self.prefix is not None else [])
        hits = self.prefix.peek(keys) if self.prefix is not None else []
        while hits and len(hits) * self.block_size >= len(prompt):
            hits.pop()
        # hits is the HBM run only — deliberately. A host-tier hit still
        # costs one fresh block to fetch into, so block demand is exactly
        # need - hbm_hits with or without a tier below; counting host hits
        # here would overstate capacity. (Tier-aware depth for *affinity*
        # is peek_depth, which the router uses.)
        fresh = need - len(hits)
        avail = self.alloc.free_blocks
        if self.prefix is not None:
            avail += self.prefix.evictable()
        if slot_free and fresh <= avail:
            return True
        # no free slot, or blocks short even after eviction: the remaining
        # route is preemption — same pre-check admit() runs
        return bool(victims) and need <= self._reclaimable(req.priority)

    def advance(self, slot: int, n: int) -> None:
        """The jitted step absorbed ``n`` tokens for this slot."""
        self.pos[slot] += n

    def commit_spec(self, slot: int, proposed: int, accepted: int) -> None:
        """A speculative verify step resolved for this slot: ``proposed``
        draft tokens went in, the longest stream-matching prefix of
        ``accepted`` of them survived, and the verify logits contributed
        one ordinary token on top. ``pos`` advances by ``1 + accepted`` —
        rolling back the rejected tail IS this arithmetic: the rejected
        drafts' K/V entries sit at positions ``>= pos`` where the
        chunk-causal kernels never look, and the next write at ``pos``
        overwrites them."""
        self.pos[slot] += 1 + accepted
        self.spec_proposed += proposed
        self.spec_accepted += accepted

    def register_prompt_blocks(self, slot: int) -> None:
        """Prompt fully absorbed: publish its full, exclusively-written
        blocks to the prefix map so later requests can share them."""
        if self.prefix is None:
            return
        plen = int(self._slot_plen[slot])
        keys = self._slot_keys[slot]
        blocks = self._slot_blocks[slot]
        pri = self.active[slot].priority if self.active[slot] else 0
        for j in range(int(self._slot_hits[slot]),
                       plen // self.block_size):
            self.prefix.register(keys[j], blocks[j], priority=pri)

    def finish(self, slot: int) -> None:
        """The slot's request completed: return its blocks, clear the
        bookkeeping and its ticket. Slot refills on the next
        :meth:`admit`."""
        req = self.active[slot]
        if req is not None:
            self._ticket.pop(req.uid, None)
        self._clear_slot(slot)

    def _clear_slot(self, slot: int) -> None:
        self.active[slot] = None
        self.pos[slot] = 0
        self.pending_prompt[slot] = deque()
        if self.paged:
            for bid in self._slot_blocks[slot]:
                self.alloc.decref(bid)
            self._slot_blocks[slot] = []
            self._slot_keys[slot] = []
            self._slot_hits[slot] = 0
            self._slot_plen[slot] = 0
            self.pages[slot, :] = 0

    # ------------------------------------------------------------------ #
    def has_work(self) -> bool:
        return bool(self._queue) or any(r is not None for r in self.active)

    def stats(self) -> dict[str, float]:
        out = {"preemptions": float(self.preemptions),
               "requeues": float(self.requeues)}
        if self.cancelled:
            out["cancelled"] = float(self.cancelled)
        if self.paged:
            out["free_blocks"] = float(self.alloc.free_blocks)
        if self.prefix is not None and hasattr(self.prefix, "tier_stats"):
            out.update(self.prefix.tier_stats())
        if self.spec_proposed:
            out["spec_proposed"] = float(self.spec_proposed)
            out["spec_accepted"] = float(self.spec_accepted)
            out["spec_accept_rate"] = self.spec_accepted / self.spec_proposed
        return out
