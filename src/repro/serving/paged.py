"""Block-paged KV-cache bookkeeping: allocator, refcounts, prefix cache.

Host-side (pure Python / numpy) twin of the device-side paged pools that
:mod:`repro.kernels.ops` reads through page tables. The device never sees
this module — the engine translates its decisions into ``(B, max_blocks)``
int32 page tables passed to the jitted step.

Layout invariants the engine relies on:

* block ids run ``1 .. num_blocks-1``; **block 0 is the garbage block** —
  never handed out, it absorbs writes from pad columns and idle batch rows
  (their page-table entries stay 0) so the jitted scatter needs no masking.
  Nothing ever reads block 0 through a valid length/position mask.
* a block is writable only while exactly one page table references it
  (refcount 1). Shared blocks (prefix hits, refcount > 1) are always *full*
  prompt blocks and sit strictly below every writer's write offset, so the
  copy-on-write case degenerates to "recompute the partial tail block"
  — :class:`BlockAllocator.fork` exists for completeness and tests.
* the prefix map holds one reference per registered block, keeping reusable
  prompt blocks alive after their owner completes; eviction (LRU, only
  entries nothing else references) turns them back into free blocks under
  pool pressure.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Number of blocks covering ``n_tokens`` positions."""
    return -(-max(0, n_tokens) // block_size)


def prefix_keys(tokens: list[int], block_size: int) -> list[bytes]:
    """Per-block prefix keys for every *full* block of ``tokens``.

    ``key[i]`` is a chained 128-bit blake2b digest committing to every
    token in blocks ``0..i`` — a hit on ``key[i]`` licenses reuse of block
    ``i`` given blocks ``0..i-1`` already hit. The chain keeps the build
    O(plen) total and each key O(1) resident (an exact-prefix-tuple key
    would cost O(plen²/block_size) in map memory and per-peek hashing),
    while 128 bits make a cross-prompt collision — serving another
    prompt's KV blocks — cryptographically negligible, unlike Python's
    64-bit ``hash()``. Keys are built once per request at submit and
    memoized by the engine.
    """
    out: list[bytes] = []
    d = b"repro-paged-prefix-v1"
    for i in range(len(tokens) // block_size):
        blk = tokens[i * block_size:(i + 1) * block_size]
        h = hashlib.blake2b(d, digest_size=16)
        h.update(",".join(map(str, blk)).encode())
        d = h.digest()
        out.append(d)
    return out


class BlockAllocator:
    """Fixed pool of ``num_blocks`` blocks with a free list and refcounts.

    ``alloc`` pops from the free list (refcount 1); ``incref`` shares a live
    block; ``decref`` returns it to the free list when the count hits 0.
    Double-free and touching a free block raise — the property tests in
    ``tests/test_paged_cache.py`` drive these invariants.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))
        self._ref: dict[int, int] = {}   # live blocks only

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return len(self._ref)

    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Pop ``n`` blocks (each refcount 1). Raises if the pool is short."""
        if n > len(self._free):
            raise MemoryError(
                f"requested {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._ref[b] = 1
        return out

    def incref(self, bid: int) -> None:
        if bid not in self._ref:
            raise ValueError(f"incref on non-live block {bid}")
        self._ref[bid] += 1

    def decref(self, bid: int) -> bool:
        """Drop one reference; returns True when the block was freed."""
        if bid not in self._ref:
            raise ValueError(f"double free of block {bid}")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            del self._ref[bid]
            self._free.append(bid)
            return True
        return False

    def fork(self, bid: int) -> int | None:
        """Copy-on-write helper: given a shared block, allocate a private
        one (caller copies device contents and decrefs the original).
        Returns None when the block is already exclusive."""
        if self.refcount(bid) <= 1:
            return None
        new = self.alloc(1)[0]
        self.decref(bid)
        return new

    def check_conservation(self) -> bool:
        """free + live == usable pool, with no id in both sets."""
        ids = set(self._free) | set(self._ref)
        return (len(self._free) + len(self._ref) == self.num_blocks - 1
                and len(ids) == self.num_blocks - 1
                and 0 not in ids
                and all(c > 0 for c in self._ref.values()))


class PrefixCache:
    """LRU map ``prefix key -> block id`` over full prompt blocks.

    Each entry holds one allocator reference, so registered blocks outlive
    their first owner. Admission is two-phase so a *failed* attempt (pool
    short) leaves no trace: ``peek`` finds the leading hit run without
    touching refcounts, stats or LRU order; the caller then ``acquire``\\ s
    the hits (incref — protects them from its own eviction pass) and, once
    the admission is certain, ``commit``\\ s (stats + LRU recency). ``evict``
    frees idle entries (refcount 1 — nothing but the map) when the pool
    runs dry.

    Eviction is **priority-then-LRU**: every entry carries the priority
    class of the request that registered it (bumped to the max priority of
    any later hit, so a prefix serving high-priority traffic stays
    protected even if a low-priority request registered it first), and
    :meth:`evict` frees the lowest-priority idle entries first, LRU within
    a class. With every request at the default priority 0 — the all-FIFO
    case — this degenerates to the exact LRU order of PRs 2–8.
    """

    def __init__(self, alloc: BlockAllocator):
        self.alloc = alloc
        self._map: OrderedDict[bytes, int] = OrderedDict()
        self._pri: dict[bytes, int] = {}   # entry priority (default 0)
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._map)

    def peek(self, keys: list[bytes]) -> list[int]:
        """Block ids for the longest leading run of hits. Pure read: no
        refcount, stat or LRU mutation — safe to call on every retry of a
        blocked admission."""
        out: list[int] = []
        for k in keys:
            bid = self._map.get(k)
            if bid is None:
                break
            out.append(bid)
        return out

    def peek_depth(self, keys: list[bytes]) -> int:
        """Tier-aware hit depth: how many leading blocks of ``keys`` this
        cache could serve without recomputing them. For the single-tier
        cache that is exactly ``len(peek(keys))``; the tiered subclass
        extends the run through its host pool, so the router's affinity
        policy sees spilled chains as hits too. Pure read."""
        return len(self.peek(keys))

    def fetch_into_hbm(self, keys: list[bytes], hits: list[int],
                       max_hits: int) -> list[int]:
        """Extend an HBM hit run from lower tiers before admission.

        The single-tier cache has no lower tier: the run is returned
        unchanged. :class:`~repro.serving.tiering.TieredPrefixCache`
        overrides this to re-fetch spilled host-resident blocks into
        freshly allocated HBM blocks (capped at ``max_hits`` total so the
        caller's never-skip-the-whole-prompt rule stays intact)."""
        return hits

    def acquire(self, bids: list[int]) -> None:
        """Incref peeked hit blocks (the caller now references them)."""
        for b in bids:
            self.alloc.incref(b)

    def release(self, bids: list[int]) -> None:
        """Undo ``acquire`` (admission fell through after all)."""
        for b in bids:
            self.alloc.decref(b)

    def commit(self, keys: list[bytes], n_hits: int,
               priority: int | None = None) -> None:
        """Admission succeeded: record stats, refresh LRU recency (and,
        with ``priority``, bump each touched entry's class to at least the
        hitting request's — a prefix hot with high-priority traffic must
        not be evicted ahead of a cold low-priority one).

        A peeked key may be gone by commit time: the deepest hit popped
        by the never-skip-the-whole-prompt rule is *not* acquired, so the
        caller's own eviction pass (between peek and commit) can free it.
        Refresh what is still present rather than KeyError-ing."""
        for k in keys[:n_hits]:
            if k in self._map:
                self._map.move_to_end(k)
                if priority is not None and priority > self._pri.get(k, 0):
                    self._pri[k] = priority
        self.hits += n_hits
        if n_hits < len(keys):
            self.misses += 1

    def lookup(self, keys: list[bytes]) -> list[int]:
        """One-shot peek + acquire + commit (hits come back incref'd)."""
        bids = self.peek(keys)
        self.acquire(bids)
        self.commit(keys, len(bids))
        return bids

    def register(self, key: bytes, bid: int, priority: int = 0) -> None:
        """Pin a freshly written full prompt block under its prefix key.
        First writer wins: an existing entry is kept (it may be shared),
        though a higher-priority re-registration still bumps its class."""
        if key in self._map:
            if priority > self._pri.get(key, 0):
                self._pri[key] = priority
            return
        self.alloc.incref(bid)
        self._map[key] = bid
        if priority:
            self._pri[key] = priority

    def evictable(self) -> int:
        """How many entries :meth:`evict` could free right now."""
        return sum(1 for bid in self._map.values()
                   if self.alloc.refcount(bid) == 1)

    def registered_blocks(self) -> set[int]:
        """The block ids currently pinned by the map (the scheduler's
        preemption pre-check asks which victim blocks would become
        map-only — i.e. evictable — rather than free)."""
        return set(self._map.values())

    def priority_of(self, key: bytes) -> int:
        """The priority class recorded for a registered entry (0 when the
        key is unknown or was never prioritized)."""
        return self._pri.get(key, 0)

    def _evict_order(self) -> list[bytes]:
        """Idle entries in eviction order: lowest priority class first,
        LRU within a class (the OrderedDict *is* the LRU order, and the
        sort is stable, so the all-priority-0 case is exactly the plain
        LRU scan of PRs 2–8)."""
        return sorted(
            (k for k, bid in self._map.items()
             if self.alloc.refcount(bid) == 1),
            key=lambda k: self._pri.get(k, 0))

    def _drop_entry(self, key: bytes) -> None:
        bid = self._map.pop(key)
        self._pri.pop(key, None)
        self.alloc.decref(bid)

    def evict(self, n_blocks: int) -> int:
        """Free up to ``n_blocks`` idle entries (priority-then-LRU).
        Returns the number actually freed; in-use entries are skipped,
        not stalled on."""
        freed = 0
        for k in self._evict_order():
            if freed >= n_blocks:
                break
            self._drop_entry(k)
            freed += 1
        return freed
