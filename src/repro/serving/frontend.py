"""Async streaming frontend: an HTTP/SSE server over the serving engine.

Pure-stdlib asyncio (no framework dependency — the CI container installs
only jax + numpy): a hand-rolled HTTP/1.1 parser over
``asyncio.start_server``, Server-Sent Events for token streaming. The
design decouples *submission* from *computation* from *streaming*:

* **Worker threads** (:class:`EngineWorker`, one per replica) drive the
  blocking jitted step loop continuously — the asyncio event loop never
  blocks on device compute. Each worker is the ONLY thread that touches
  its engine: HTTP handlers hand requests over through a thread-safe
  inbox the worker drains before every step, so the engine and scheduler
  stay single-threaded with zero locks in the hot path.
* **Per-request asyncio queues** carry tokens out: the engine's
  ``Request.on_tokens`` callback fires inside the step loop and posts
  ``(tokens, done, t)`` onto the request's queue via
  ``loop.call_soon_threadsafe`` — the one safe thread boundary — and the
  HTTP handler awaits the queue and writes SSE events as they land.
  A slow client therefore never stalls the step loop (tokens buffer in
  its queue) and a fast engine never waits for the network.
* **Backpressure** comes from scheduler admission: a POST is rejected
  with 503 (+ ``Retry-After``) when the target replica's queue depth
  reaches ``max_queue``, or — queue empty but the pool hopeless — when
  the scheduler's pure :meth:`~repro.serving.scheduler.Scheduler.
  would_admit` probe says the request could not be placed even at the
  head of the line. Trial-submitting and catching the rejection would
  skew admission stats and wedge head-of-line order; the probe mutates
  nothing.
* **Graceful drain**: :meth:`AsyncFrontend.shutdown` with ``drain=True``
  (the default) stops accepting new work (503), lets every in-flight
  stream run to completion, then stops the workers and closes the
  listener. ``drain=False`` abandons active requests (their streams get
  a final ``error`` event).

Streaming protocol (Server-Sent Events)
---------------------------------------
``POST /generate`` with a JSON body::

    {"prompt": [1, 2, 3], "max_new_tokens": 16, "temperature": 0.0,
     "top_k": 0, "top_p": 1.0, "seed": null, "priority": 0,
     "eos_id": null, "stream": true}

With ``stream`` true (default) the response is ``text/event-stream``:
one ``data:`` event per engine emission (a speculative verify step can
carry several tokens), then a final summary event, then ``[DONE]``::

    data: {"tokens": [17], "index": 0}
    data: {"tokens": [4, 9], "index": 1}
    data: {"done": true, "uid": 3, "replica": 0, "n": 3,
           "tokens": [17, 4, 9], "ttft_s": 0.01, "truncated": false}
    data: [DONE]

With ``stream`` false the same summary object comes back as one
``application/json`` response. ``GET /health`` reports liveness and load;
``GET /metrics`` the engine/router ``metrics_summary()`` plus frontend
stream metrics (tokens streamed, mean per-token latency = mean gap
between consecutive SSE emissions of a stream, rejects).

Multi-replica mode: construct with a :class:`~repro.serving.router.
Router` — the handler calls ``router.route(req)`` on the asyncio thread
(reads are racy-but-safe; see the router docstring) and submits to the
chosen replica's worker, feeding first-token latencies back into the
router's EWMA-TTFT load signal.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import queue as _queue
import threading
import time

from repro.serving.engine import Request, ServingEngine
from repro.serving.router import Router


class EngineWorker(threading.Thread):
    """Background thread driving one engine's step loop continuously.

    The only thread that touches the engine after start(). Submissions
    arrive through :meth:`submit` (thread-safe inbox, drained before each
    step); a submit the engine rejects (over-long prompt that can never
    fit the pool) sets ``req.error`` and fires the request's callback
    with ``done=True`` so the waiting stream fails loudly instead of
    hanging. ``idle_wait`` bounds the sleep while there is no work.
    """

    def __init__(self, engine: ServingEngine, *, idle_wait: float = 0.01,
                 name: str | None = None):
        super().__init__(name=name or "engine-worker", daemon=True)
        self.engine = engine
        self.idle_wait = float(idle_wait)
        self._inbox: _queue.Queue[Request] = _queue.Queue()
        self._wake = threading.Event()
        self._stopping = False
        self._drain = True
        self._closed = False          # refuse submits after stop()
        self.steps = 0

    def submit(self, req: Request) -> None:
        """Thread-safe: hand a request to the step loop."""
        if self._closed:
            raise RuntimeError("worker is shutting down")
        self._inbox.put(req)
        self._wake.set()

    def stop(self, *, drain: bool = True, timeout: float | None = 30.0
             ) -> None:
        """Stop the loop: ``drain=True`` finishes all queued/active work
        first; ``drain=False`` abandons it (active requests' callbacks
        fire once with ``req.error`` set)."""
        self._closed = True
        self._drain = drain
        self._stopping = True
        self._wake.set()
        self.join(timeout)

    def _drain_inbox(self) -> None:
        while True:
            try:
                req = self._inbox.get_nowait()
            except _queue.Empty:
                return
            try:
                self.engine.submit(req)
            except (ValueError, MemoryError) as e:
                req.error = str(e)          # type: ignore[attr-defined]
                if req.on_tokens is not None:
                    req.on_tokens(req, [], True)

    def run(self) -> None:   # pragma: no cover - exercised via frontend
        eng = self.engine
        while True:
            self._drain_inbox()
            if self._stopping and not self._drain:
                break
            if eng.has_work():
                eng.step()
                self.steps += 1
            elif self._stopping and self._inbox.empty():
                break
            else:
                self._wake.wait(self.idle_wait)
                self._wake.clear()
        if self._stopping and not self._drain:
            # abandoned requests: fail their streams, free their blocks
            for slot, req in enumerate(eng.scheduler.active):
                if req is None:
                    continue
                eng.scheduler.finish(slot)
                self._abort(req)
            for req in list(eng.scheduler.queue):
                self._abort(req)

    @staticmethod
    def _abort(req: Request) -> None:
        req.error = "aborted: frontend shut down without drain"  # type: ignore[attr-defined]
        if req.on_tokens is not None:
            req.on_tokens(req, [], True)


@dataclasses.dataclass
class FrontendStats:
    """Stream-level metrics the engine cannot see (it has no notion of a
    connection): acceptance/rejection counts and per-token SSE latency —
    the gap between consecutive emissions of one stream, aggregated over
    all streams. ``mean_inter_token_s`` is the serving-side analogue of
    decode tok/s as a *client* experiences it."""
    requests_accepted: int = 0
    requests_rejected: int = 0
    requests_completed: int = 0
    requests_failed: int = 0
    tokens_streamed: int = 0
    inter_token_sum_s: float = 0.0
    inter_token_n: int = 0

    @property
    def mean_inter_token_s(self) -> float:
        if self.inter_token_n == 0:
            return float("nan")
        return self.inter_token_sum_s / self.inter_token_n

    def as_dict(self) -> dict[str, float]:
        out = {
            "frontend_requests_accepted": float(self.requests_accepted),
            "frontend_requests_rejected": float(self.requests_rejected),
            "frontend_requests_completed": float(self.requests_completed),
            "frontend_requests_failed": float(self.requests_failed),
            "frontend_tokens_streamed": float(self.tokens_streamed),
        }
        if self.inter_token_n:
            out["frontend_mean_inter_token_s"] = self.mean_inter_token_s
        return out


class AsyncFrontend:
    """HTTP/SSE server over one engine or a multi-replica router.

    Lifecycle::

        fe = AsyncFrontend(engine_or_router, port=0)
        await fe.start()          # workers spin up, socket listens
        ...                       # fe.port is the bound port
        await fe.shutdown()       # drain in-flight streams, stop workers

    or from sync code, ``fe.run_forever()`` (Ctrl-C drains and exits).
    """

    def __init__(self, target: ServingEngine | Router, *,
                 host: str = "127.0.0.1", port: int = 0,
                 max_queue: int = 32, idle_wait: float = 0.01):
        if isinstance(target, Router):
            self.router: Router | None = target
            engines = target.engines
        else:
            self.router = None
            engines = [target]
        self.engines = engines
        self.workers = [
            EngineWorker(e, idle_wait=idle_wait, name=f"engine-worker-{i}")
            for i, e in enumerate(engines)
        ]
        self.host = host
        self.port = port              # 0 = ephemeral; real port after start
        self.max_queue = int(max_queue)
        self.stats = FrontendStats()
        self.accepting = False
        self._server: asyncio.AbstractServer | None = None
        self._loop: asyncio.AbstractEventLoop | None = None
        self._uid = 0
        self._inflight = 0
        self._idle = asyncio.Event()
        self._idle.set()

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        for w in self.workers:
            w.start()
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self.accepting = True

    async def shutdown(self, *, drain: bool = True,
                       timeout: float = 60.0) -> None:
        """Stop accepting (new POSTs get 503); with ``drain`` wait for
        every in-flight stream to finish before stopping the workers and
        closing the listener."""
        self.accepting = False
        if drain:
            try:
                await asyncio.wait_for(self._idle.wait(), timeout)
            except asyncio.TimeoutError:   # pragma: no cover - safety net
                pass
        for w in self.workers:
            # stop() joins the worker thread: run it off the event loop
            await asyncio.get_running_loop().run_in_executor(
                None, lambda w=w: w.stop(drain=drain))
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    def run_forever(self) -> None:   # pragma: no cover - CLI convenience
        async def _main():
            await self.start()
            print(f"serving on http://{self.host}:{self.port} "
                  f"({len(self.engines)} replica"
                  f"{'s' if len(self.engines) > 1 else ''})", flush=True)
            try:
                await asyncio.Event().wait()
            finally:
                await self.shutdown()
        try:
            asyncio.run(_main())
        except KeyboardInterrupt:
            pass

    # ------------------------------------------------------------------ #
    # request plumbing
    # ------------------------------------------------------------------ #
    def _total_depth(self) -> int:
        return sum(w._inbox.qsize() + e.scheduler.queue_depth
                   for w, e in zip(self.workers, self.engines))

    def _make_request(self, body: dict) -> Request:
        uid = self._uid
        self._uid += 1
        prompt = body.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) for t in prompt)):
            raise ValueError("'prompt' must be a non-empty list of ints")
        return Request(
            uid=uid, prompt=prompt,
            max_new_tokens=int(body.get("max_new_tokens", 16)),
            eos_id=body.get("eos_id"),
            priority=int(body.get("priority", 0)),
            temperature=float(body.get("temperature", 0.0)),
            top_k=int(body.get("top_k", 0)),
            top_p=float(body.get("top_p", 1.0)),
            seed=body.get("seed"))

    def _admission_check(self, req: Request, rid: int) -> str | None:
        """Returns a rejection reason, or None to admit. Queue depth is
        the primary backpressure signal; an *empty* queue with a pool
        that could never place the request (would_admit probe) rejects
        immediately rather than parking the request at the head of the
        line to starve everything behind it."""
        sched = self.engines[rid].scheduler
        depth = self.workers[rid]._inbox.qsize() + sched.queue_depth
        if depth >= self.max_queue:
            return (f"replica {rid} queue is full "
                    f"({depth}/{self.max_queue})")
        if depth == 0 and not sched.would_admit(req) \
                and not sched.has_work():
            # nothing running, nothing queued, still unplaceable: the
            # request can never fit (too many blocks) — reject now
            return (f"request needs more KV blocks than replica {rid}'s "
                    f"pool can ever free")
        return None

    # ------------------------------------------------------------------ #
    # HTTP layer
    # ------------------------------------------------------------------ #
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            await self._handle_one(reader, writer)
        except (asyncio.IncompleteReadError, ConnectionResetError,
                asyncio.TimeoutError):
            pass                       # client went away mid-request
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, OSError):  # pragma: no cover
                pass

    async def _handle_one(self, reader, writer) -> None:
        request_line = await asyncio.wait_for(reader.readline(), 30.0)
        if not request_line:
            return
        try:
            method, path, _ = request_line.decode("ascii").split()
        except ValueError:
            await self._respond(writer, 400, {"error": "bad request line"})
            return
        headers: dict[str, str] = {}
        while True:
            line = await asyncio.wait_for(reader.readline(), 30.0)
            if line in (b"\r\n", b"\n", b""):
                break
            key, _, val = line.decode("latin1").partition(":")
            headers[key.strip().lower()] = val.strip()
        body = b""
        clen = int(headers.get("content-length", "0") or 0)
        if clen:
            body = await asyncio.wait_for(reader.readexactly(clen), 30.0)

        if method == "GET" and path == "/health":
            await self._respond(writer, 200, self._health())
        elif method == "GET" and path == "/metrics":
            await self._respond(writer, 200, self._metrics())
        elif method == "POST" and path == "/generate":
            await self._handle_generate(writer, body)
        else:
            await self._respond(writer, 404,
                                {"error": f"no route {method} {path}"})

    def _health(self) -> dict:
        active = sum(sum(1 for r in e.scheduler.active if r is not None)
                     for e in self.engines)
        return {"status": "ok" if self.accepting else "draining",
                "replicas": len(self.engines),
                "queued": self._total_depth(), "active": active}

    def _metrics(self) -> dict:
        src = self.router if self.router is not None else self.engines[0]
        out = dict(src.metrics_summary())
        out.update(self.stats.as_dict())
        # JSON has no NaN: drop undefined aggregates rather than emitting
        # the non-standard token json.dumps would produce
        return {k: v for k, v in out.items()
                if not (isinstance(v, float) and v != v)}

    async def _respond(self, writer, status: int, obj: dict) -> None:
        reasons = {200: "OK", 400: "Bad Request", 404: "Not Found",
                   503: "Service Unavailable"}
        payload = json.dumps(obj).encode()
        head = (f"HTTP/1.1 {status} {reasons.get(status, 'OK')}\r\n"
                "Content-Type: application/json\r\n"
                f"Content-Length: {len(payload)}\r\n"
                + ("Retry-After: 1\r\n" if status == 503 else "")
                + "Connection: close\r\n\r\n").encode()
        writer.write(head + payload)
        await writer.drain()

    # ------------------------------------------------------------------ #
    # /generate
    # ------------------------------------------------------------------ #
    async def _handle_generate(self, writer, raw: bytes) -> None:
        if not self.accepting:
            self.stats.requests_rejected += 1
            await self._respond(writer, 503, {"error": "shutting down"})
            return
        try:
            body = json.loads(raw.decode() or "{}")
            req = self._make_request(body)
        except (ValueError, UnicodeDecodeError) as e:
            await self._respond(writer, 400, {"error": str(e)})
            return
        rid = self.router.route(req) if self.router is not None else 0
        reason = self._admission_check(req, rid)
        if reason is not None:
            self.stats.requests_rejected += 1
            await self._respond(writer, 503, {"error": reason})
            return

        loop = self._loop
        q: asyncio.Queue = asyncio.Queue()

        def on_tokens(r: Request, toks: list[int], done: bool) -> None:
            # runs on the worker thread, inside the step loop: the queue
            # put is marshalled onto the event loop — the only thread
            # crossing. time.monotonic here stamps true emission time so
            # per-token latency excludes event-loop scheduling delay.
            loop.call_soon_threadsafe(
                q.put_nowait, (list(toks), done, time.monotonic()))

        req.on_tokens = on_tokens
        stream = bool(body.get("stream", True))
        self.stats.requests_accepted += 1
        self._inflight += 1
        self._idle.clear()
        try:
            self.workers[rid].submit(req)
            if stream:
                await self._stream_sse(writer, req, rid, q)
            else:
                await self._collect_json(writer, req, rid, q)
        finally:
            self._inflight -= 1
            if self._inflight == 0:
                self._idle.set()

    def _summary_obj(self, req: Request, rid: int) -> dict:
        err = getattr(req, "error", None)
        out = {"done": True, "uid": req.uid, "replica": rid,
               "n": len(req.generated), "tokens": list(req.generated),
               "truncated": req.truncated}
        ttft = req.metrics.ttft
        if ttft == ttft:               # NaN-safe: omit when undefined
            out["ttft_s"] = round(ttft, 6)
        if err is not None:
            out["error"] = err
        return out

    async def _consume(self, req: Request, rid: int, q: asyncio.Queue,
                       per_event) -> None:
        """Drain the request's token queue to completion, maintaining
        stream metrics; ``per_event(toks, index)`` runs for every
        emission (the SSE writer, or a no-op for non-streaming)."""
        index = 0
        last_t: float | None = None
        first = True
        while True:
            toks, done, t = await q.get()
            if toks:
                if first and self.router is not None:
                    self.router.observe_ttft(
                        rid, t - req.metrics.submit_t)
                first = False
                self.stats.tokens_streamed += len(toks)
                if last_t is not None:
                    # one emission = one step: the gap amortizes over the
                    # tokens it carried (speculative steps emit several)
                    self.stats.inter_token_sum_s += t - last_t
                    self.stats.inter_token_n += len(toks)
                last_t = t
                await per_event(toks, index)
                index += 1
            if done:
                if getattr(req, "error", None) is None:
                    self.stats.requests_completed += 1
                else:
                    self.stats.requests_failed += 1
                return

    async def _stream_sse(self, writer, req, rid, q) -> None:
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-store\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()

        async def emit(toks: list[int], index: int) -> None:
            ev = json.dumps({"tokens": toks, "index": index})
            writer.write(f"data: {ev}\n\n".encode())
            await writer.drain()

        await self._consume(req, rid, q, emit)
        summary = json.dumps(self._summary_obj(req, rid))
        writer.write(f"data: {summary}\n\ndata: [DONE]\n\n".encode())
        await writer.drain()

    async def _collect_json(self, writer, req, rid, q) -> None:
        async def emit(toks: list[int], index: int) -> None:
            pass
        await self._consume(req, rid, q, emit)
        obj = self._summary_obj(req, rid)
        status = 200 if "error" not in obj else 400
        await self._respond(writer, status, obj)


# ---------------------------------------------------------------------- #
# minimal client (tests + benchmarks; avoids an HTTP-library dependency)
# ---------------------------------------------------------------------- #

async def client_generate(host: str, port: int, *, stream: bool = True,
                          timeout: float = 120.0, **payload) -> dict:
    """POST /generate and consume the response; returns the final summary
    object with ``events`` = the streamed SSE event list prepended. The
    token-level test client: asserts nothing, decodes everything."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = json.dumps(dict(payload, stream=stream)).encode()
        writer.write(
            (f"POST /generate HTTP/1.1\r\nHost: {host}\r\n"
             "Content-Type: application/json\r\n"
             f"Content-Length: {len(body)}\r\n"
             "Connection: close\r\n\r\n").encode() + body)
        await writer.drain()
        status_line = await asyncio.wait_for(reader.readline(), timeout)
        status = int(status_line.split()[1])
        while True:   # headers
            line = await asyncio.wait_for(reader.readline(), timeout)
            if line in (b"\r\n", b"\n", b""):
                break
        if not stream or status != 200:
            raw = await asyncio.wait_for(reader.read(), timeout)
            return dict(json.loads(raw.decode() or "{}"),
                        http_status=status, events=[])
        events: list[dict] = []
        summary: dict = {}
        buf = b""
        while True:
            chunk = await asyncio.wait_for(reader.readline(), timeout)
            if not chunk:
                break
            buf += chunk
            if not buf.endswith(b"\n\n") and chunk not in (b"\n", b"\r\n"):
                continue
            text = buf.decode().strip()
            buf = b""
            if not text.startswith("data:"):
                continue
            data = text[len("data:"):].strip()
            if data == "[DONE]":
                break
            obj = json.loads(data)
            if obj.get("done"):
                summary = obj
            else:
                events.append(obj)
        return dict(summary, http_status=status, events=events)
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, OSError):   # pragma: no cover
            pass


async def client_get(host: str, port: int, path: str,
                     timeout: float = 30.0) -> dict:
    """GET a JSON endpoint (/health, /metrics)."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write((f"GET {path} HTTP/1.1\r\nHost: {host}\r\n"
                      "Connection: close\r\n\r\n").encode())
        await writer.drain()
        status_line = await asyncio.wait_for(reader.readline(), timeout)
        status = int(status_line.split()[1])
        clen = 0
        while True:
            line = await asyncio.wait_for(reader.readline(), timeout)
            if line in (b"\r\n", b"\n", b""):
                break
            k, _, v = line.decode("latin1").partition(":")
            if k.strip().lower() == "content-length":
                clen = int(v)
        raw = await asyncio.wait_for(reader.readexactly(clen), timeout) \
            if clen else b"{}"
        return dict(json.loads(raw.decode()), http_status=status)
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, OSError):   # pragma: no cover
            pass
