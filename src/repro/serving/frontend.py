"""Async streaming frontend: an HTTP/SSE server over the serving engine.

Pure-stdlib asyncio (no framework dependency — the CI container installs
only jax + numpy): a hand-rolled HTTP/1.1 parser over
``asyncio.start_server``, Server-Sent Events for token streaming. The
design decouples *submission* from *computation* from *streaming*:

* **Worker threads** (:class:`EngineWorker`, one per replica) drive the
  blocking jitted step loop continuously — the asyncio event loop never
  blocks on device compute. Each worker is the ONLY thread that touches
  its engine: HTTP handlers hand requests over through a thread-safe
  inbox the worker drains before every step, so the engine and scheduler
  stay single-threaded with zero locks in the hot path.
* **Per-request asyncio queues** carry tokens out: the engine's
  ``Request.on_tokens`` callback fires inside the step loop and posts
  ``(tokens, done, t)`` onto the request's queue via
  ``loop.call_soon_threadsafe`` — the one safe thread boundary — and the
  HTTP handler awaits the queue and writes SSE events as they land.
  A slow client therefore never stalls the step loop (tokens buffer in
  its queue) and a fast engine never waits for the network.
* **Backpressure** comes from scheduler admission: a POST is rejected
  with 503 (+ ``Retry-After``) when the target replica's queue depth
  reaches ``max_queue``, or — queue empty but the pool hopeless — when
  the scheduler's pure :meth:`~repro.serving.scheduler.Scheduler.
  would_admit` probe says the request could not be placed even at the
  head of the line. Trial-submitting and catching the rejection would
  skew admission stats and wedge head-of-line order; the probe mutates
  nothing.
* **Graceful drain**: :meth:`AsyncFrontend.shutdown` with ``drain=True``
  (the default) stops accepting new work (503), lets every in-flight
  stream run to completion, then stops the workers and closes the
  listener. ``drain=False`` abandons active requests (their streams get
  a final ``error`` event).

Streaming protocol (Server-Sent Events)
---------------------------------------
``POST /generate`` with a JSON body::

    {"prompt": [1, 2, 3], "max_new_tokens": 16, "temperature": 0.0,
     "top_k": 0, "top_p": 1.0, "seed": null, "priority": 0,
     "eos_id": null, "stream": true}

With ``stream`` true (default) the response is ``text/event-stream``:
one ``data:`` event per engine emission (a speculative verify step can
carry several tokens), then a final summary event, then ``[DONE]``::

    data: {"tokens": [17], "index": 0}
    data: {"tokens": [4, 9], "index": 1}
    data: {"done": true, "uid": 3, "replica": 0, "n": 3,
           "tokens": [17, 4, 9], "ttft_s": 0.01, "truncated": false}
    data: [DONE]

With ``stream`` false the same summary object comes back as one
``application/json`` response. ``GET /health`` reports liveness and load;
``GET /metrics`` the engine/router ``metrics_summary()`` plus frontend
stream metrics (tokens streamed, mean per-token latency = mean gap
between consecutive SSE emissions of a stream, rejects).

Multi-replica mode: construct with a :class:`~repro.serving.router.
Router` — the handler calls ``router.route(req)`` on the asyncio thread
(reads are racy-but-safe; see the router docstring) and submits to the
chosen replica's worker, feeding first-token latencies back into the
router's EWMA-TTFT load signal.

Edge resilience (PR 8)
----------------------
* **Crash-safe workers**: an exception out of the step loop no longer
  kills the thread silently — the worker marks itself crashed, bumps the
  engine's ``worker_crashed`` counter, and either hands its work to the
  frontend's ``on_crash`` hook (which marks the replica DEAD in the
  router and *migrates* queued + in-flight requests to surviving
  workers through the bitwise requeue-as-prefill path — see
  :mod:`repro.serving.faults`) or, with no survivors, aborts every
  stream with an error event and frees its blocks. The inbox never
  hangs: a crashed worker refuses new submits.
* **Disconnect cancellation**: a client that drops mid-SSE-stream
  cancels its request — the worker's thread-safe cancel inbox reaches
  :meth:`~repro.serving.scheduler.Scheduler.cancel`, finishing the slot
  and decref'ing its blocks instead of generating into an abandoned
  queue.
* **Per-request deadlines**: ``deadline_s`` in the POST body (or the
  frontend-wide ``request_timeout``) bounds a stream's total wall time;
  expiry cancels the request and fails the stream with 504 semantics.
* **Graceful degradation**: when the surviving-replica fraction drops
  to ``shed_below`` or less, requests at priority <= ``shed_priority``
  are shed with 503 + Retry-After — low-priority traffic queues nowhere
  while a degraded pool digests the migrated backlog.
* **Stuck-step watchdog** (``step_deadline_s``): a worker stuck *inside*
  one step past the deadline is marked DEAD for routing immediately and
  quarantined — it hands its work back for migration the moment the
  stuck step returns (mid-step state cannot be moved safely; see the
  faults module on step-boundary recovery), while per-request deadlines
  bound the damage if it never does.
* **Client retry**: :func:`client_generate` retries transient 503s with
  exponential backoff + jitter (:func:`retry_delays`), seeded for
  deterministic tests.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import queue as _queue
import random
import threading
import time

from repro.serving.engine import Request, ServingEngine
from repro.serving.router import DEAD, Router


class WorkerQuarantined(RuntimeError):
    """Raised inside a worker's step loop when the stuck-step watchdog
    quarantined it: routes the worker through its own crash path so its
    requests migrate at the first safe (step-boundary) moment."""


class EngineWorker(threading.Thread):
    """Background thread driving one engine's step loop continuously.

    The only thread that touches the engine after start(). Submissions
    arrive through :meth:`submit` / :meth:`resubmit` (thread-safe inbox,
    drained before each step); a submit the engine rejects (over-long
    prompt that can never fit the pool) sets ``req.error`` and fires the
    request's callback with ``done=True`` so the waiting stream fails
    loudly instead of hanging. :meth:`cancel` rides a second inbox,
    drained after submissions so a cancel always wins over its own
    submit. ``idle_wait`` bounds the sleep while there is no work.

    Crash safety: an exception out of the step loop is caught — the
    worker closes its inbox, bumps ``engine.worker_crashed``, and either
    defers to ``on_crash(worker, exc)`` (the frontend's migration hook;
    return True when the requests were taken care of) or aborts every
    queued/active/pending stream itself with an error event, freeing all
    blocks. Either way the thread exits cleanly and nothing hangs.
    """

    def __init__(self, engine: ServingEngine, *, idle_wait: float = 0.01,
                 name: str | None = None, on_crash=None):
        super().__init__(name=name or "engine-worker", daemon=True)
        self.engine = engine
        self.idle_wait = float(idle_wait)
        self._inbox: _queue.Queue[tuple[Request, bool]] = _queue.Queue()
        self._cancels: _queue.Queue[int] = _queue.Queue()
        self._wake = threading.Event()
        self._stopping = False
        self._drain = True
        self._closed = False          # refuse submits after stop()
        self._quarantined = False
        self.on_crash = on_crash      # callable(worker, exc) -> bool
        self.crashed = False
        self.crash_error: str | None = None
        self.steps = 0
        # wall-clock start of the step currently executing (None between
        # steps): the frontend's stuck-step watchdog polls this
        self.step_started_t: float | None = None

    def submit(self, req: Request) -> None:
        """Thread-safe: hand a request to the step loop."""
        if self._closed:
            raise RuntimeError("worker is shutting down")
        self._inbox.put((req, False))
        self._wake.set()

    def resubmit(self, req: Request) -> None:
        """Thread-safe: hand over a request migrating from a dead
        replica — drained into :meth:`ServingEngine.resubmit`, the
        bitwise requeue-as-prefill resume."""
        if self._closed:
            raise RuntimeError("worker is shutting down")
        self._inbox.put((req, True))
        self._wake.set()

    def cancel(self, uid: int) -> None:
        """Thread-safe: drop ``uid`` wherever it is (queued, active, or
        still in the inbox) at the next step boundary. No ``_closed``
        check — cancelling during drain must still work."""
        self._cancels.put(uid)
        self._wake.set()

    def quarantine(self) -> None:
        """Thread-safe: ask the worker to stop and hand its work back at
        the next step boundary (the stuck-step watchdog calls this; the
        worker itself raises :class:`WorkerQuarantined` when it sees the
        flag, routing through the crash/migration path)."""
        self._quarantined = True
        self._closed = True
        self._wake.set()

    def stop(self, *, drain: bool = True, timeout: float | None = 30.0
             ) -> None:
        """Stop the loop: ``drain=True`` finishes all queued/active work
        first; ``drain=False`` abandons it (active requests' callbacks
        fire once with ``req.error`` set)."""
        self._closed = True
        self._drain = drain
        self._stopping = True
        self._wake.set()
        self.join(timeout)

    def _drain_inbox(self) -> None:
        cancelled: set[int] = set()
        while True:
            try:
                uid = self._cancels.get_nowait()
            except _queue.Empty:
                break
            if not self.engine.cancel(uid):
                # not in the engine yet: it may still sit in the submit
                # inbox below — swallow it there
                cancelled.add(uid)
        while True:
            try:
                req, resume = self._inbox.get_nowait()
            except _queue.Empty:
                return
            if req.uid in cancelled:
                continue
            try:
                if resume:
                    self.engine.resubmit(req)
                else:
                    self.engine.submit(req)
            except (ValueError, MemoryError) as e:
                self._abort(req, str(e))

    def drain_pending(self) -> list[Request]:
        """Pop not-yet-submitted requests out of the inbox. Crash-path
        only: the caller is the crashed thread itself (or holds the
        joined thread), so nothing races the engine."""
        out = []
        while True:
            try:
                req, _ = self._inbox.get_nowait()
            except _queue.Empty:
                return out
            out.append(req)

    def run(self) -> None:   # pragma: no cover - exercised via frontend
        try:
            self._run_loop()
        except Exception as e:
            # crash-safe: the step loop must never die silently — streams
            # would hang and stop(drain=True) would block to timeout
            self.crashed = True
            self.crash_error = repr(e)
            self._closed = True
            self.engine.worker_crashed += 1
            handled = False
            if self.on_crash is not None:
                try:
                    handled = bool(self.on_crash(self, e))
                except Exception:   # the hook must not re-kill the thread
                    handled = False
            if not handled:
                self._abort_all(f"replica worker crashed: {e!r}")

    def _run_loop(self) -> None:
        eng = self.engine
        while True:
            self._drain_inbox()
            if self._quarantined:
                raise WorkerQuarantined(
                    "quarantined by the stuck-step watchdog")
            if self._stopping and not self._drain:
                break
            if eng.has_work():
                self.step_started_t = time.monotonic()
                try:
                    eng.step()
                finally:
                    self.step_started_t = None
                self.steps += 1
            elif self._stopping and self._inbox.empty():
                break
            else:
                self._wake.wait(self.idle_wait)
                self._wake.clear()
        if self._stopping and not self._drain:
            # abandoned requests: fail their streams, free their blocks
            self._abort_all("aborted: frontend shut down without drain")

    def _abort_all(self, msg: str) -> None:
        """Fail every request this worker still owns — active slots
        (blocks freed), the scheduler queue, and the unsubmitted inbox —
        with one final error event each."""
        eng = self.engine
        for slot, req in enumerate(eng.scheduler.active):
            if req is None:
                continue
            eng.scheduler.finish(slot)
            self._abort(req, msg)
        for req in eng.scheduler.drain_queue():
            self._abort(req, msg)
        for req in self.drain_pending():
            self._abort(req, msg)

    @staticmethod
    def _abort(req: Request, msg: str) -> None:
        req.error = msg
        if req.on_tokens is not None:
            req.on_tokens(req, [], True)


@dataclasses.dataclass
class FrontendStats:
    """Stream-level metrics the engine cannot see (it has no notion of a
    connection): acceptance/rejection counts and per-token SSE latency —
    the gap between consecutive emissions of one stream, aggregated over
    all streams. ``mean_inter_token_s`` is the serving-side analogue of
    decode tok/s as a *client* experiences it."""
    requests_accepted: int = 0
    requests_rejected: int = 0
    requests_completed: int = 0
    requests_failed: int = 0
    tokens_streamed: int = 0
    inter_token_sum_s: float = 0.0
    inter_token_n: int = 0
    # resilience counters (all zero — and absent from as_dict — on the
    # healthy path)
    requests_cancelled: int = 0   # client disconnected mid-stream
    requests_timed_out: int = 0   # per-request deadline expired
    requests_shed: int = 0        # rejected by degraded-capacity shedding
    requests_migrated: int = 0    # moved off a crashed replica's worker
    workers_crashed: int = 0

    @property
    def mean_inter_token_s(self) -> float:
        if self.inter_token_n == 0:
            return float("nan")
        return self.inter_token_sum_s / self.inter_token_n

    def as_dict(self) -> dict[str, float]:
        out = {
            "frontend_requests_accepted": float(self.requests_accepted),
            "frontend_requests_rejected": float(self.requests_rejected),
            "frontend_requests_completed": float(self.requests_completed),
            "frontend_requests_failed": float(self.requests_failed),
            "frontend_tokens_streamed": float(self.tokens_streamed),
        }
        if self.inter_token_n:
            out["frontend_mean_inter_token_s"] = self.mean_inter_token_s
        for key in ("requests_cancelled", "requests_timed_out",
                    "requests_shed", "requests_migrated",
                    "workers_crashed"):
            v = getattr(self, key)
            if v:
                out[f"frontend_{key}"] = float(v)
        return out


class AsyncFrontend:
    """HTTP/SSE server over one engine or a multi-replica router.

    Lifecycle::

        fe = AsyncFrontend(engine_or_router, port=0)
        await fe.start()          # workers spin up, socket listens
        ...                       # fe.port is the bound port
        await fe.shutdown()       # drain in-flight streams, stop workers

    or from sync code, ``fe.run_forever()`` (Ctrl-C drains and exits).
    """

    def __init__(self, target: ServingEngine | Router, *,
                 host: str = "127.0.0.1", port: int = 0,
                 max_queue: int = 32, idle_wait: float = 0.01,
                 request_timeout: float | None = None,
                 step_deadline_s: float | None = None,
                 shed_below: float = 0.5, shed_priority: int = 0):
        if isinstance(target, Router):
            self.router: Router | None = target
            engines = target.engines
        else:
            self.router = None
            engines = [target]
        self.engines = engines
        self.workers = [
            EngineWorker(e, idle_wait=idle_wait, name=f"engine-worker-{i}",
                         on_crash=self._worker_crashed)
            for i, e in enumerate(engines)
        ]
        self.host = host
        self.port = port              # 0 = ephemeral; real port after start
        self.max_queue = int(max_queue)
        # default total-wall-time deadline per request (None = unbounded);
        # a request's own "deadline_s" body field overrides it
        self.request_timeout = request_timeout
        # stuck-step watchdog (None = off): needs a router to mark DEAD in
        self.step_deadline_s = step_deadline_s
        # degraded-capacity shedding: when alive/total <= shed_below (and
        # at least one replica is dead), priority <= shed_priority is 503'd
        self.shed_below = float(shed_below)
        self.shed_priority = int(shed_priority)
        self.stats = FrontendStats()
        self.accepting = False
        self._server: asyncio.AbstractServer | None = None
        self._loop: asyncio.AbstractEventLoop | None = None
        self._watchdog_task: asyncio.Task | None = None
        self._uid = 0
        self._inflight = 0
        self._idle = asyncio.Event()
        self._idle.set()

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        for w in self.workers:
            w.start()
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self.accepting = True
        if self.step_deadline_s and self.router is not None:
            self._watchdog_task = self._loop.create_task(self._watchdog())

    async def shutdown(self, *, drain: bool = True,
                       timeout: float = 60.0) -> None:
        """Stop accepting (new POSTs get 503); with ``drain`` wait for
        every in-flight stream to finish before stopping the workers and
        closing the listener."""
        self.accepting = False
        if self._watchdog_task is not None:
            self._watchdog_task.cancel()
            self._watchdog_task = None
        if drain:
            try:
                await asyncio.wait_for(self._idle.wait(), timeout)
            except asyncio.TimeoutError:   # pragma: no cover - safety net
                pass
        for w in self.workers:
            # stop() joins the worker thread: run it off the event loop
            await asyncio.get_running_loop().run_in_executor(
                None, lambda w=w: w.stop(drain=drain))
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    def run_forever(self) -> None:   # pragma: no cover - CLI convenience
        async def _main():
            await self.start()
            print(f"serving on http://{self.host}:{self.port} "
                  f"({len(self.engines)} replica"
                  f"{'s' if len(self.engines) > 1 else ''})", flush=True)
            try:
                await asyncio.Event().wait()
            finally:
                await self.shutdown()
        try:
            asyncio.run(_main())
        except KeyboardInterrupt:
            pass

    # ------------------------------------------------------------------ #
    # fault handling
    # ------------------------------------------------------------------ #
    def _worker_crashed(self, worker: EngineWorker,
                        exc: BaseException) -> bool:
        """Crash hook, called ON the dying worker's thread — its step
        loop has exited, so its engine is safe to touch from here. With a
        router and at least one survivor the crashed replica's work
        migrates: queued + in-flight requests are harvested (blocks
        freed) and resubmitted to surviving workers' thread-safe inboxes
        through the requeue-as-prefill path, so their streams continue
        bitwise (see :mod:`repro.serving.faults`). Returns False — "not
        handled, abort everything" — when there is no router or no
        survivor."""
        self.stats.workers_crashed += 1
        if self.router is None:
            return False
        rid = self.workers.index(worker)
        self.router.mark_dead(rid, repr(exc))
        if not self.router.alive():
            return False
        moved = self.router.harvest(rid) + worker.drain_pending()
        for req in moved:
            target = self.router.place_migrated(
                req, submit=lambda t, r: self.workers[t].resubmit(r))
            if target is not None:
                self.stats.requests_migrated += 1
        return True

    async def _watchdog(self) -> None:
        """Stuck-step watchdog: a worker inside ONE engine step for
        longer than ``step_deadline_s`` is marked DEAD (routing excludes
        it immediately) and quarantined — the worker raises out of its
        loop at the next step boundary and its work migrates via the
        crash hook. Mid-step state cannot be moved safely (the stalled
        thread owns the engine), so migration waits for the stall to
        break; per-request deadlines bound the damage if it never does."""
        assert self.router is not None and self.step_deadline_s
        interval = max(self.step_deadline_s / 4.0, 0.005)
        while True:
            await asyncio.sleep(interval)
            now = time.monotonic()
            for rid, w in enumerate(self.workers):
                if self.router.health[rid] == DEAD or w.crashed:
                    continue
                t0 = w.step_started_t
                if t0 is not None and now - t0 >= self.step_deadline_s:
                    self.router.mark_dead(
                        rid, f"stuck in one step > "
                             f"{self.step_deadline_s:.3f}s")
                    w.quarantine()

    # ------------------------------------------------------------------ #
    # request plumbing
    # ------------------------------------------------------------------ #
    def _total_depth(self) -> int:
        return sum(w._inbox.qsize() + e.scheduler.queue_depth
                   for w, e in zip(self.workers, self.engines))

    def _make_request(self, body: dict) -> Request:
        uid = self._uid
        self._uid += 1
        prompt = body.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) for t in prompt)):
            raise ValueError("'prompt' must be a non-empty list of ints")
        return Request(
            uid=uid, prompt=prompt,
            max_new_tokens=int(body.get("max_new_tokens", 16)),
            eos_id=body.get("eos_id"),
            priority=int(body.get("priority", 0)),
            temperature=float(body.get("temperature", 0.0)),
            top_k=int(body.get("top_k", 0)),
            top_p=float(body.get("top_p", 1.0)),
            seed=body.get("seed"))

    def _admission_check(self, req: Request, rid: int) -> str | None:
        """Returns a rejection reason, or None to admit. Queue depth is
        the primary backpressure signal; an *empty* queue with a pool
        that could never place the request (would_admit probe) rejects
        immediately rather than parking the request at the head of the
        line to starve everything behind it."""
        if self.router is not None:
            alive = self.router.alive()
            if (len(alive) < len(self.engines)
                    and len(alive) / len(self.engines) <= self.shed_below
                    and req.priority <= self.shed_priority):
                self.stats.requests_shed += 1
                return (f"degraded: {len(alive)}/{len(self.engines)} "
                        f"replicas alive, shedding priority <= "
                        f"{self.shed_priority}")
        sched = self.engines[rid].scheduler
        depth = self.workers[rid]._inbox.qsize() + sched.queue_depth
        if depth >= self.max_queue:
            return (f"replica {rid} queue is full "
                    f"({depth}/{self.max_queue})")
        if depth == 0 and not sched.would_admit(req) \
                and not sched.has_work():
            # nothing running, nothing queued, still unplaceable: the
            # request can never fit (too many blocks) — reject now
            return (f"request needs more KV blocks than replica {rid}'s "
                    f"pool can ever free")
        return None

    # ------------------------------------------------------------------ #
    # HTTP layer
    # ------------------------------------------------------------------ #
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            await self._handle_one(reader, writer)
        except (asyncio.IncompleteReadError, ConnectionResetError,
                BrokenPipeError, asyncio.TimeoutError):
            pass                       # client went away mid-request
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, OSError):  # pragma: no cover
                pass

    async def _handle_one(self, reader, writer) -> None:
        request_line = await asyncio.wait_for(reader.readline(), 30.0)
        if not request_line:
            return
        try:
            method, path, _ = request_line.decode("ascii").split()
        except ValueError:
            await self._respond(writer, 400, {"error": "bad request line"})
            return
        headers: dict[str, str] = {}
        while True:
            line = await asyncio.wait_for(reader.readline(), 30.0)
            if line in (b"\r\n", b"\n", b""):
                break
            key, _, val = line.decode("latin1").partition(":")
            headers[key.strip().lower()] = val.strip()
        body = b""
        clen = int(headers.get("content-length", "0") or 0)
        if clen:
            body = await asyncio.wait_for(reader.readexactly(clen), 30.0)

        if method == "GET" and path == "/health":
            await self._respond(writer, 200, self._health())
        elif method == "GET" and path == "/metrics":
            await self._respond(writer, 200, self._metrics())
        elif method == "POST" and path == "/generate":
            await self._handle_generate(writer, body)
        else:
            await self._respond(writer, 404,
                                {"error": f"no route {method} {path}"})

    def _health(self) -> dict:
        active = sum(sum(1 for r in e.scheduler.active if r is not None)
                     for e in self.engines)
        out = {"status": "ok" if self.accepting else "draining",
               "replicas": len(self.engines),
               "queued": self._total_depth(), "active": active}
        if self.router is not None and (
                any(h != "healthy" for h in self.router.health)
                or self.router.replica_deaths):
            out["replica_health"] = list(self.router.health)
        return out

    def _metrics(self) -> dict:
        src = self.router if self.router is not None else self.engines[0]
        out = dict(src.metrics_summary())
        out.update(self.stats.as_dict())
        # JSON has no NaN: drop undefined aggregates rather than emitting
        # the non-standard token json.dumps would produce
        return {k: v for k, v in out.items()
                if not (isinstance(v, float) and v != v)}

    async def _respond(self, writer, status: int, obj: dict) -> None:
        reasons = {200: "OK", 400: "Bad Request", 404: "Not Found",
                   503: "Service Unavailable", 504: "Gateway Timeout"}
        payload = json.dumps(obj).encode()
        head = (f"HTTP/1.1 {status} {reasons.get(status, 'OK')}\r\n"
                "Content-Type: application/json\r\n"
                f"Content-Length: {len(payload)}\r\n"
                + ("Retry-After: 1\r\n" if status == 503 else "")
                + "Connection: close\r\n\r\n").encode()
        writer.write(head + payload)
        await writer.drain()

    # ------------------------------------------------------------------ #
    # /generate
    # ------------------------------------------------------------------ #
    async def _handle_generate(self, writer, raw: bytes) -> None:
        if not self.accepting:
            self.stats.requests_rejected += 1
            await self._respond(writer, 503, {"error": "shutting down"})
            return
        try:
            body = json.loads(raw.decode() or "{}")
            req = self._make_request(body)
        except (ValueError, UnicodeDecodeError) as e:
            await self._respond(writer, 400, {"error": str(e)})
            return
        try:
            rid = self.router.route(req) if self.router is not None else 0
        except RuntimeError as e:      # every replica marked dead
            self.stats.requests_rejected += 1
            await self._respond(writer, 503, {"error": str(e)})
            return
        reason = self._admission_check(req, rid)
        if reason is not None:
            self.stats.requests_rejected += 1
            await self._respond(writer, 503, {"error": reason})
            return
        deadline = body.get("deadline_s", self.request_timeout)
        deadline = float(deadline) if deadline is not None else None

        loop = self._loop
        q: asyncio.Queue = asyncio.Queue()

        def on_tokens(r: Request, toks: list[int], done: bool) -> None:
            # runs on the worker thread, inside the step loop: the queue
            # put is marshalled onto the event loop — the only thread
            # crossing. time.monotonic here stamps true emission time so
            # per-token latency excludes event-loop scheduling delay.
            loop.call_soon_threadsafe(
                q.put_nowait, (list(toks), done, time.monotonic()))

        req.on_tokens = on_tokens
        stream = bool(body.get("stream", True))
        try:
            self.workers[rid].submit(req)
        except RuntimeError as e:   # worker crashed/quarantined just now
            self.stats.requests_rejected += 1
            await self._respond(writer, 503, {"error": str(e)})
            return
        self.stats.requests_accepted += 1
        self._inflight += 1
        self._idle.clear()
        try:
            if stream:
                await self._stream_sse(writer, req, rid, q,
                                       deadline=deadline)
            else:
                await self._collect_json(writer, req, rid, q,
                                         deadline=deadline)
        except (ConnectionResetError, BrokenPipeError):
            # client dropped mid-stream: cancel so the engine stops
            # generating into an abandoned queue and frees the blocks
            self.workers[rid].cancel(req.uid)
            self.stats.requests_cancelled += 1
            raise
        finally:
            self._inflight -= 1
            if self._inflight == 0:
                self._idle.set()

    def _summary_obj(self, req: Request, rid: int) -> dict:
        err = getattr(req, "error", None)
        out = {"done": True, "uid": req.uid, "replica": rid,
               "n": len(req.generated), "tokens": list(req.generated),
               "truncated": req.truncated}
        ttft = req.metrics.ttft
        if ttft == ttft:               # NaN-safe: omit when undefined
            out["ttft_s"] = round(ttft, 6)
        if err is not None:
            out["error"] = err
        return out

    async def _consume(self, req: Request, rid: int, q: asyncio.Queue,
                       per_event, deadline: float | None = None) -> None:
        """Drain the request's token queue to completion, maintaining
        stream metrics; ``per_event(toks, index)`` runs for every
        emission (the SSE writer, or a no-op for non-streaming). With a
        ``deadline`` (total wall seconds from now) an overrunning request
        is cancelled on its worker and the stream fails with a
        "deadline exceeded" error (504 for non-streaming)."""
        index = 0
        last_t: float | None = None
        first = True
        t_end = (time.monotonic() + deadline) if deadline is not None \
            else None
        while True:
            if t_end is None:
                toks, done, t = await q.get()
            else:
                try:
                    toks, done, t = await asyncio.wait_for(
                        q.get(), max(t_end - time.monotonic(), 0.0))
                except asyncio.TimeoutError:
                    self.workers[rid].cancel(req.uid)
                    req.error = (f"deadline exceeded: no completion "
                                 f"within {deadline:.3f}s")
                    self.stats.requests_timed_out += 1
                    self.stats.requests_failed += 1
                    return
            if toks:
                if first and self.router is not None:
                    self.router.observe_ttft(
                        rid, t - req.metrics.submit_t)
                first = False
                self.stats.tokens_streamed += len(toks)
                if last_t is not None:
                    # one emission = one step: the gap amortizes over the
                    # tokens it carried (speculative steps emit several)
                    self.stats.inter_token_sum_s += t - last_t
                    self.stats.inter_token_n += len(toks)
                last_t = t
                await per_event(toks, index)
                index += 1
            if done:
                if getattr(req, "error", None) is None:
                    self.stats.requests_completed += 1
                else:
                    self.stats.requests_failed += 1
                return

    async def _stream_sse(self, writer, req, rid, q, *,
                          deadline: float | None = None) -> None:
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-store\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()

        async def emit(toks: list[int], index: int) -> None:
            ev = json.dumps({"tokens": toks, "index": index})
            writer.write(f"data: {ev}\n\n".encode())
            await writer.drain()

        await self._consume(req, rid, q, emit, deadline)
        summary = json.dumps(self._summary_obj(req, rid))
        writer.write(f"data: {summary}\n\ndata: [DONE]\n\n".encode())
        await writer.drain()

    async def _collect_json(self, writer, req, rid, q, *,
                            deadline: float | None = None) -> None:
        async def emit(toks: list[int], index: int) -> None:
            pass
        await self._consume(req, rid, q, emit, deadline)
        obj = self._summary_obj(req, rid)
        if "error" not in obj:
            status = 200
        elif obj["error"].startswith("deadline exceeded"):
            status = 504
        else:
            status = 400
        await self._respond(writer, status, obj)


# ---------------------------------------------------------------------- #
# minimal client (tests + benchmarks; avoids an HTTP-library dependency)
# ---------------------------------------------------------------------- #

def retry_delays(retries: int, *, base_s: float = 0.05,
                 cap_s: float = 2.0, jitter: float = 0.1, rng=None):
    """Exponential backoff with multiplicative jitter: yields ``retries``
    delays ``min(cap_s, base_s * 2**i) * (1 + jitter * U[0,1))``. The
    jitter de-synchronizes a thundering herd of clients all told
    Retry-After by the same overloaded frontend; pass a seeded ``rng``
    for deterministic tests."""
    rng = rng if rng is not None else random
    for i in range(retries):
        yield min(cap_s, base_s * (2.0 ** i)) * (1.0 + jitter
                                                 * rng.random())


async def client_generate(host: str, port: int, *, stream: bool = True,
                          timeout: float = 120.0, retries: int = 0,
                          retry_base_s: float = 0.05,
                          retry_cap_s: float = 2.0,
                          retry_jitter: float = 0.1, retry_rng=None,
                          **payload) -> dict:
    """POST /generate and consume the response; returns the final summary
    object with ``events`` = the streamed SSE event list prepended, plus
    ``attempts``. Transient 503s (backpressure, degraded-capacity
    shedding) are retried up to ``retries`` times with exponential
    backoff + jitter; any other status returns immediately. The
    token-level test client: asserts nothing, decodes everything."""
    delays = retry_delays(retries, base_s=retry_base_s, cap_s=retry_cap_s,
                          jitter=retry_jitter, rng=retry_rng)
    attempt = 0
    while True:
        out = await _client_generate_once(host, port, stream=stream,
                                          timeout=timeout, **payload)
        attempt += 1
        if out.get("http_status") != 503 or attempt > retries:
            out["attempts"] = attempt
            return out
        await asyncio.sleep(next(delays))


async def _client_generate_once(host: str, port: int, *,
                                stream: bool = True,
                                timeout: float = 120.0,
                                **payload) -> dict:
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = json.dumps(dict(payload, stream=stream)).encode()
        writer.write(
            (f"POST /generate HTTP/1.1\r\nHost: {host}\r\n"
             "Content-Type: application/json\r\n"
             f"Content-Length: {len(body)}\r\n"
             "Connection: close\r\n\r\n").encode() + body)
        await writer.drain()
        status_line = await asyncio.wait_for(reader.readline(), timeout)
        status = int(status_line.split()[1])
        while True:   # headers
            line = await asyncio.wait_for(reader.readline(), timeout)
            if line in (b"\r\n", b"\n", b""):
                break
        if not stream or status != 200:
            raw = await asyncio.wait_for(reader.read(), timeout)
            return dict(json.loads(raw.decode() or "{}"),
                        http_status=status, events=[])
        events: list[dict] = []
        summary: dict = {}
        buf = b""
        while True:
            chunk = await asyncio.wait_for(reader.readline(), timeout)
            if not chunk:
                break
            buf += chunk
            if not buf.endswith(b"\n\n") and chunk not in (b"\n", b"\r\n"):
                continue
            text = buf.decode().strip()
            buf = b""
            if not text.startswith("data:"):
                continue
            data = text[len("data:"):].strip()
            if data == "[DONE]":
                break
            obj = json.loads(data)
            if obj.get("done"):
                summary = obj
            else:
                events.append(obj)
        return dict(summary, http_status=status, events=events)
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, OSError):   # pragma: no cover
            pass


async def client_get(host: str, port: int, path: str,
                     timeout: float = 30.0) -> dict:
    """GET a JSON endpoint (/health, /metrics)."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write((f"GET {path} HTTP/1.1\r\nHost: {host}\r\n"
                      "Connection: close\r\n\r\n").encode())
        await writer.drain()
        status_line = await asyncio.wait_for(reader.readline(), timeout)
        status = int(status_line.split()[1])
        clen = 0
        while True:
            line = await asyncio.wait_for(reader.readline(), timeout)
            if line in (b"\r\n", b"\n", b""):
                break
            k, _, v = line.decode("latin1").partition(":")
            if k.strip().lower() == "content-length":
                clen = int(v)
        raw = await asyncio.wait_for(reader.readexactly(clen), timeout) \
            if clen else b"{}"
        return dict(json.loads(raw.decode()), http_status=status)
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, OSError):   # pragma: no cover
            pass
