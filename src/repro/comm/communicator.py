"""Communicators — paper §2.3 Listing 3 adapted to the JAX collective model.

nnabla::

    comm = C.MultiProcessDataParalellCommunicator(ctx); comm.init()
    loss.backward(clear_buffer=True)
    comm.all_reduce([x.grad for x in nn.get_parameters().values()])

Here the communicator wraps ``jax.lax`` collectives for use *inside*
``shard_map`` (the explicit plane — NCCL-like) while pjit/GSPMD provides the
implicit plane. Beyond the paper: bucketed all-reduce (fewer, larger
collectives), bf16/int8 *compressed* gradient reduction with error feedback —
the standard distributed-optimization tricks for 1000+-node DP where the
gradient all-reduce is the wire bottleneck.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


@dataclasses.dataclass
class Communicator:
    """Explicit-collective plane over a named mesh axis."""

    mesh: Mesh
    axis: str = "data"

    @property
    def size(self) -> int:
        return self.mesh.shape[self.axis]

    # ---- inside-shard_map primitives (NCCL-alike) ----
    def all_reduce(self, tree: Any, mean: bool = False) -> Any:
        def red(x):
            y = lax.psum(x, self.axis)
            return y / self.size if mean else y
        return jax.tree.map(red, tree)

    def reduce_scatter(self, x: jax.Array, axis: int = 0) -> jax.Array:
        return lax.psum_scatter(x, self.axis, scatter_dimension=axis,
                                tiled=True)

    def all_gather(self, x: jax.Array, axis: int = 0) -> jax.Array:
        return lax.all_gather(x, self.axis, axis=axis, tiled=True)

    def all_to_all(self, x: jax.Array, split_axis: int,
                   concat_axis: int) -> jax.Array:
        return lax.all_to_all(x, self.axis, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)

    def permute(self, x: jax.Array, shift: int = 1) -> jax.Array:
        n = self.size
        perm = [(i, (i + shift) % n) for i in range(n)]
        return lax.ppermute(x, self.axis, perm)

    # ---- host-level convenience: compile an all-reduce over a grad dict ----
    def build_grad_all_reduce(self, grad_shapes: dict[str, Any],
                              mean: bool = True, compression: str | None = None,
                              bucket_bytes: int = 32 * 2**20):
        """Returns a jitted fn: sharded grads dict -> all-reduced dict.

        This is the paper's ``comm.all_reduce(params)`` as one compiled
        program: bucketed, optionally compressed.
        """
        spec = P(self.axis)

        def body(grads):
            if compression is None:
                return self.all_reduce(grads, mean=mean)
            return {k: compressed_all_reduce(v, self.axis, method=compression,
                                             mean=mean)
                    for k, v in grads.items()}

        shardings = {k: NamedSharding(self.mesh, P())
                     for k in grad_shapes}
        del bucket_bytes  # bucketing folded into XLA's combiner here
        f = shard_map(body, mesh=self.mesh,
                      in_specs=({k: P() for k in grad_shapes},),
                      out_specs={k: P() for k in grad_shapes},
                      check_rep=False)
        return jax.jit(f, in_shardings=(shardings,), out_shardings=shardings)


# --------------------------------------------------------------------------- #
# compressed collectives (beyond-paper distributed-optimization tricks)
# --------------------------------------------------------------------------- #

def compressed_all_reduce(x: jax.Array, axis: str, *, method: str = "bf16",
                          mean: bool = True) -> jax.Array:
    """All-reduce with on-the-wire compression.

    bf16: reduce-scatter + all-gather in bf16 (2x wire saving vs fp32).
    int8: per-tensor-scale quantization, all-gather int8 + local sum
          (4x wire saving on the gather leg; exact scale via pmax).
    """
    n = lax.psum(jnp.ones((), jnp.float32), axis)
    if method == "bf16":
        # genuinely bf16 on the wire; accumulation cost is the bf16 sum
        y = lax.psum(x.astype(jnp.bfloat16), axis).astype(jnp.float32)
        y = y / n if mean else y
        return y.astype(x.dtype)
    if method == "int8":
        scale = jnp.max(jnp.abs(x.astype(jnp.float32))) + 1e-12
        gscale = lax.pmax(scale, axis)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / gscale * 127.0),
                     -127, 127).astype(jnp.int8)
        allq = lax.all_gather(q, axis)              # int8 on the wire
        y = jnp.sum(allq.astype(jnp.float32), axis=0) * (gscale / 127.0)
        y = y / n if mean else y
        return y.astype(x.dtype)
    raise ValueError(f"unknown compression {method!r}")


def error_feedback_reduce(x: jax.Array, err: jax.Array, axis: str, *,
                          method: str = "int8", mean: bool = True
                          ) -> tuple[jax.Array, jax.Array]:
    """1-bit-Adam-style error feedback: compress (x + carried error),
    remember the quantization residual for the next step."""
    target = x.astype(jnp.float32) + err
    reduced = compressed_all_reduce(target, axis, method=method, mean=mean)
    # residual: what compression lost locally (approximate, pre-reduction)
    scale = jnp.max(jnp.abs(target)) + 1e-12
    gscale = lax.pmax(scale, axis)
    q = jnp.clip(jnp.round(target / gscale * 127.0), -127, 127)
    recon = q * (gscale / 127.0)
    new_err = target - recon
    return reduced.astype(x.dtype), new_err


def flatten_buckets(tree: dict[str, jax.Array],
                    bucket_bytes: int = 32 * 2**20
                    ) -> list[list[str]]:
    """Group parameter paths into ~bucket_bytes buckets (fewer collectives)."""
    buckets: list[list[str]] = [[]]
    acc = 0
    for k in sorted(tree):
        v = tree[k]
        nbytes = int(np.prod(v.shape)) * v.dtype.itemsize
        if acc + nbytes > bucket_bytes and buckets[-1]:
            buckets.append([])
            acc = 0
        buckets[-1].append(k)
        acc += nbytes
    return buckets
