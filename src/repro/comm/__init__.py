from repro.comm.communicator import (Communicator, compressed_all_reduce,
                                     error_feedback_reduce, flatten_buckets)

__all__ = ["Communicator", "compressed_all_reduce", "error_feedback_reduce",
           "flatten_buckets"]
