"""``nnp_inspect`` — what Neural Network Console displays, as a CLI.

Layer list, parameter counts, MAC estimates per function, output shapes —
the paper's §5.1 "footprint the computational workload of the networks
designed in NNL" story without the GUI.

  PYTHONPATH=src python -m repro.fileformat.inspect_cli model.nnp
  PYTHONPATH=src python -m repro.fileformat.inspect_cli --arch llama3.2-1b
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.fileformat.defs import ModelFile, NetworkDef
from repro.fileformat.nnp import load_nnp, query_unsupported

_MAC_OPS = {"matmul", "batch_matmul", "convolution", "einsum", "affine"}


def _macs(f, var_shapes: dict[str, list[int]]) -> int:
    if f.type not in _MAC_OPS or not f.outputs:
        return 0
    out = var_shapes.get(f.outputs[0])
    a = var_shapes.get(f.inputs[0]) if f.inputs else None
    if not out or not a:
        return 0
    k = a[-1] if a else 1
    return int(np.prod(out)) * k


def inspect_network(net: NetworkDef, params: dict) -> None:
    var_shapes = {v.name: v.shape for v in net.variables}
    n_params = sum(int(np.prod(v.shape)) for v in net.variables
                   if v.kind == "parameter")
    total_macs = 0
    print(f"network {net.name!r}: {len(net.functions)} functions, "
          f"{n_params:,} parameters")
    print(f"  inputs : {[(n, var_shapes.get(n)) for n in net.inputs]}")
    print(f"  outputs: {[(n, var_shapes.get(n)) for n in net.outputs]}")
    print(f"  {'function':<22s} {'type':<18s} {'output shape':<18s} MACs")
    for f in net.functions:
        macs = _macs(f, var_shapes)
        total_macs += macs
        out_shape = var_shapes.get(f.outputs[0], "?") if f.outputs else "?"
        print(f"  {f.name:<22s} {f.type:<18s} {str(out_shape):<18s} "
              f"{macs:,}")
    print(f"  total MACs/forward: {total_macs:,}")
    unsup = query_unsupported(net)
    print(f"  unsupported for executor reload: {unsup or 'none'}")


def inspect_arch(name: str) -> None:
    from repro.configs import get_arch
    from repro.configs.base import SHAPES
    cfg = get_arch(name)
    print(f"arch {cfg.name}: family={cfg.family} {cfg.n_layers}L "
          f"d={cfg.d_model} H={cfg.n_heads}/{cfg.n_kv_heads} "
          f"ff={cfg.d_ff} V={cfg.vocab_size}")
    print(f"  params        : {cfg.param_count():,} "
          f"({cfg.param_count() / 1e9:.2f}B)")
    print(f"  active params : {cfg.active_param_count():,}")
    for s in SHAPES.values():
        if s.kind == "train":
            toks = s.global_batch * s.seq_len
            print(f"  {s.name}: 6*N*D = "
                  f"{6 * cfg.active_param_count() * toks / 1e15:.1f} PFLOP/step")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?", help=".nnp archive to inspect")
    ap.add_argument("--arch", help="inspect an assigned architecture config")
    args = ap.parse_args(argv)
    if args.arch:
        inspect_arch(args.arch)
        return 0
    if not args.path:
        ap.error("give an .nnp path or --arch")
    model, params = load_nnp(args.path)
    print(f"{args.path}: {len(model.networks)} network(s), "
          f"{len(model.executors)} executor(s)")
    for net in model.networks:
        inspect_network(net, params)
    return 0


if __name__ == "__main__":
    sys.exit(main())
