from repro.fileformat.defs import (DatasetDef, ExecutorDef, FunctionDef,
                                   GlobalConfig, ModelFile, MonitorDef,
                                   NetworkDef, OptimizerDef, TrainingConfig,
                                   VariableDef)
from repro.fileformat.nnp import (NnpExecutor, export_model, load_nnp,
                                  op_registry, query_unsupported, save_nnp,
                                  trace_network)
from repro.fileformat import onnx_mini

__all__ = ["DatasetDef", "ExecutorDef", "FunctionDef", "GlobalConfig",
           "ModelFile", "MonitorDef", "NetworkDef", "OptimizerDef",
           "TrainingConfig", "VariableDef", "NnpExecutor", "export_model",
           "load_nnp", "op_registry", "query_unsupported", "save_nnp",
           "trace_network", "onnx_mini"]
