"""Mini-ONNX interchange (paper §3: "ONNX to NNP and vice versa").

A faithful subset of the ONNX graph data model (opset-13-ish op names,
node/initializer/input/output structure) as JSON — enough to round-trip our
NetworkDefs and to *demonstrate* the compatibility machinery: op-name
translation tables both ways, plus the unsupported-op query the paper calls
out for conversion safety.
"""

from __future__ import annotations

from typing import Any

import numpy as np

from repro.fileformat.defs import FunctionDef, NetworkDef, VariableDef

# repro op type -> ONNX op_type
TO_ONNX = {
    "add": "Add", "sub": "Sub", "mul": "Mul", "div": "Div", "neg": "Neg",
    "pow": "Pow", "exp": "Exp", "log": "Log", "sqrt": "Sqrt", "abs": "Abs",
    "tanh": "Tanh", "sigmoid": "Sigmoid", "relu": "Relu",
    "leaky_relu": "LeakyRelu", "softplus": "Softplus", "silu": "Silu",
    "gelu": "Gelu", "softmax": "Softmax", "log_softmax": "LogSoftmax",
    "matmul": "MatMul", "batch_matmul": "MatMul", "reshape": "Reshape",
    "transpose": "Transpose", "concatenate": "Concat", "pad": "Pad",
    "squeeze": "Squeeze", "expand_dims": "Unsqueeze",
    "sum": "ReduceSum", "mean": "ReduceMean", "max": "ReduceMax",
    "min": "ReduceMin", "convolution": "Conv", "max_pooling": "MaxPool",
    "average_pooling": "AveragePool",
    "global_average_pooling": "GlobalAveragePool",
    "embed": "Gather", "gather": "Gather", "one_hot": "OneHot",
    "where": "Where", "cast": "Cast",
    "layer_normalization": "LayerNormalization",
    "batch_normalization": "BatchNormalization",
    "softmax_cross_entropy": "SoftmaxCrossEntropyLoss",
    "argmax": "ArgMax", "cumsum": "CumSum", "top_k": "TopK",
    "clip_by_value": "Clip", "stop_gradient": "Identity",
}
FROM_ONNX = {v: k for k, v in TO_ONNX.items()}
# where several repro ops share one ONNX type, pick the canonical inverse
FROM_ONNX["MatMul"] = "matmul"
FROM_ONNX["Identity"] = "stop_gradient"


def unsupported_for_export(net: NetworkDef) -> list[str]:
    return sorted({f.type for f in net.functions if f.type not in TO_ONNX})


def unsupported_for_import(onnx_graph: dict) -> list[str]:
    return sorted({n["op_type"] for n in onnx_graph["node"]
                   if n["op_type"] not in FROM_ONNX})


def export_onnx(net: NetworkDef, params: dict[str, np.ndarray],
                strict: bool = True) -> dict[str, Any]:
    """NetworkDef -> ONNX-shaped JSON dict (ModelProto-lite)."""
    missing = unsupported_for_export(net)
    if missing and strict:
        raise ValueError(
            f"functions not supported by the ONNX exporter: {missing} "
            "(run unsupported_for_export() first — paper §3)")
    nodes = []
    for f in net.functions:
        if f.type not in TO_ONNX:
            continue
        nodes.append({
            "name": f.name, "op_type": TO_ONNX[f.type],
            "input": list(f.inputs), "output": list(f.outputs),
            "attribute": dict(f.args),
        })
    initializer = [{
        "name": k, "dims": list(v.shape), "data_type": str(v.dtype),
        "raw_data_b64_len": int(v.nbytes),
    } for k, v in params.items()]
    vinfo = {v.name: v for v in net.variables}
    graph = {
        "name": net.name,
        "node": nodes,
        "initializer": initializer,
        "input": [{"name": n, "shape": vinfo[n].shape,
                   "dtype": vinfo[n].dtype} for n in net.inputs],
        "output": [{"name": n, "shape": vinfo[n].shape,
                    "dtype": vinfo[n].dtype} for n in net.outputs],
    }
    return {"ir_version": 8, "opset_import": [{"version": 13}],
            "producer_name": "repro-nnl", "graph": graph}


def import_onnx(model: dict[str, Any]) -> NetworkDef:
    """ONNX-shaped JSON dict -> NetworkDef (op names translated back)."""
    g = model["graph"]
    missing = unsupported_for_import(g)
    if missing:
        raise ValueError(f"ONNX ops unsupported by importer: {missing}")
    functions = [FunctionDef(
        name=n["name"], type=FROM_ONNX[n["op_type"]],
        inputs=list(n["input"]), outputs=list(n["output"]),
        args=dict(n.get("attribute", {}))) for n in g["node"]]
    variables = []
    seen = set()
    for io_list, kind in ((g["input"], "input"), (g["output"], "output")):
        for x in io_list:
            if x["name"] not in seen:
                seen.add(x["name"])
                variables.append(VariableDef(
                    name=x["name"], shape=list(x["shape"]),
                    dtype=x["dtype"], kind=kind))
    for init in g["initializer"]:
        if init["name"] not in seen:
            seen.add(init["name"])
            variables.append(VariableDef(
                name=init["name"], shape=list(init["dims"]),
                dtype=init["data_type"], kind="parameter"))
    for n in g["node"]:
        for out in n["output"]:
            if out not in seen:
                seen.add(out)
                variables.append(VariableDef(
                    name=out, shape=[], dtype="float32",
                    kind="intermediate"))
    return NetworkDef(name=g["name"], variables=variables,
                      functions=functions,
                      inputs=[x["name"] for x in g["input"]],
                      outputs=[x["name"] for x in g["output"]])
