"""NNP message taxonomy (paper §3.1), as serializable dataclasses.

Mirrors NNablaProtoBuf: GlobalConfig, TrainingConfig, Network, Parameter,
Dataset, Optimizer, Monitor, Executor. The root ``ModelFile`` is what a
``.nnp`` archive stores (graph as JSON — the protobuf role — plus parameters
in an .npz — the HDF5 role).
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass
class VariableDef:
    name: str
    shape: list[int]
    dtype: str
    kind: str = "intermediate"   # input | parameter | intermediate | output


@dataclasses.dataclass
class FunctionDef:
    name: str                    # unique instance name
    type: str                    # op type (F registry key)
    inputs: list[str]
    outputs: list[str]
    args: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class NetworkDef:
    name: str
    variables: list[VariableDef] = dataclasses.field(default_factory=list)
    functions: list[FunctionDef] = dataclasses.field(default_factory=list)
    inputs: list[str] = dataclasses.field(default_factory=list)
    outputs: list[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class GlobalConfig:
    default_context: str = "cpu|float"


@dataclasses.dataclass
class TrainingConfig:
    max_epoch: int = 0
    iter_per_epoch: int = 0
    save_best: bool = True


@dataclasses.dataclass
class DatasetDef:
    name: str = "synthetic"
    uri: str = ""
    batch_size: int = 0
    shuffle: bool = False


@dataclasses.dataclass
class OptimizerDef:
    name: str = "adam"
    network: str = ""
    solver: str = "adam"
    hyper: dict[str, float] = dataclasses.field(default_factory=dict)
    dataset: str = ""


@dataclasses.dataclass
class MonitorDef:
    name: str = "loss"
    network: str = ""
    variable: str = ""


@dataclasses.dataclass
class ExecutorDef:
    name: str = "runtime"
    network: str = ""
    inputs: list[str] = dataclasses.field(default_factory=list)
    outputs: list[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ModelFile:
    global_config: GlobalConfig = dataclasses.field(default_factory=GlobalConfig)
    training_config: TrainingConfig = \
        dataclasses.field(default_factory=TrainingConfig)
    networks: list[NetworkDef] = dataclasses.field(default_factory=list)
    datasets: list[DatasetDef] = dataclasses.field(default_factory=list)
    optimizers: list[OptimizerDef] = dataclasses.field(default_factory=list)
    monitors: list[MonitorDef] = dataclasses.field(default_factory=list)
    executors: list[ExecutorDef] = dataclasses.field(default_factory=list)

    def network(self, name: str) -> NetworkDef:
        for n in self.networks:
            if n.name == name:
                return n
        raise KeyError(name)


def to_dict(obj: Any) -> Any:
    if dataclasses.is_dataclass(obj):
        return {f.name: to_dict(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, (list, tuple)):
        return [to_dict(v) for v in obj]
    if isinstance(obj, dict):
        return {k: to_dict(v) for k, v in obj.items()}
    return obj


_NESTED = {
    ModelFile: {"global_config": GlobalConfig,
                "training_config": TrainingConfig},
}
_LISTS = {
    ModelFile: {"networks": None, "datasets": DatasetDef,
                "optimizers": OptimizerDef, "monitors": MonitorDef,
                "executors": ExecutorDef},
    # NetworkDef handled explicitly below
}


def network_from_dict(d: dict) -> NetworkDef:
    return NetworkDef(
        name=d["name"],
        variables=[VariableDef(**v) for v in d["variables"]],
        functions=[FunctionDef(**f) for f in d["functions"]],
        inputs=list(d["inputs"]),
        outputs=list(d["outputs"]))


def model_from_dict(d: dict) -> ModelFile:
    return ModelFile(
        global_config=GlobalConfig(**d.get("global_config", {})),
        training_config=TrainingConfig(**d.get("training_config", {})),
        networks=[network_from_dict(n) for n in d.get("networks", [])],
        datasets=[DatasetDef(**x) for x in d.get("datasets", [])],
        optimizers=[OptimizerDef(**x) for x in d.get("optimizers", [])],
        monitors=[MonitorDef(**x) for x in d.get("monitors", [])],
        executors=[ExecutorDef(**x) for x in d.get("executors", [])])
