"""``.nnp`` archives: trace, save, load, execute, query (paper §3, §3.1).

* ``trace_network`` — run model code on deferred Variables and serialize the
  resulting graph into a :class:`NetworkDef` (the protobuf role).
* ``save_nnp`` / ``load_nnp`` — zip of ``model.json`` + ``parameters.npz``
  (the HDF5 role). Portable: a fresh process reloads and executes without
  the model's Python code.
* ``NnpExecutor`` — rebuilds a pure jax callable from the NetworkDef; the
  round-trip test (identical outputs) is the paper's portability claim.
* ``query_unsupported`` — the paper's "querying commands ... to check
  whether it contains unsupported function", both for import and export.
"""

from __future__ import annotations

import io
import json
import zipfile
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as nn
from repro.core import functions as F
from repro.core import graph as _graph
from repro.core.parameter import Parameter
from repro.core.variable import Variable
from repro.fileformat.defs import (ExecutorDef, FunctionDef, ModelFile,
                                   NetworkDef, VariableDef, model_from_dict,
                                   to_dict)


def op_registry() -> dict[str, Callable]:
    """All F ops by type name (wrapper exposes its pure fn)."""
    reg = {}
    for name in dir(F):
        fn = getattr(F, name)
        if callable(fn) and hasattr(fn, "pure"):
            reg[name] = fn.pure
    return reg


_JSONABLE = (int, float, str, bool, type(None))


def _ser_arg(v: Any) -> Any:
    if isinstance(v, _JSONABLE):
        return v
    if isinstance(v, (tuple, list)):
        return [_ser_arg(x) for x in v]
    if isinstance(v, (np.dtype,)):
        return str(v)
    if hasattr(v, "dtype") and np.ndim(v) == 0:
        return float(v)
    if v in (jnp.float32, jnp.float16, jnp.bfloat16, jnp.int32, jnp.int64):
        return str(np.dtype(v))
    return str(v)


def trace_network(name: str, fn: Callable, example_inputs: dict[str, Any],
                  ) -> tuple[NetworkDef, dict[str, np.ndarray]]:
    """Build a NetworkDef by running ``fn`` on deferred Variables.

    ``example_inputs``: name -> array. Parameters come from the global
    registry (eager plane), captured with their registered names.
    Returns (network, parameters).
    """
    in_vars = {k: Variable(data=jnp.asarray(v), need_grad=False, name=k)
               for k, v in example_inputs.items()}
    out = fn(**in_vars)
    outputs = out if isinstance(out, (tuple, list)) else [out]
    out_list = [o for o in outputs if isinstance(o, Variable)]
    if not out_list:
        raise ValueError("traced function returned no Variables")

    # Collect the graph in topological order from all outputs.
    nodes: list[_graph.FunctionNode] = []
    seen = set()
    for o in out_list:
        for node in _graph._topo_nodes(o):
            if node.uid not in seen:
                seen.add(node.uid)
                nodes.append(node)
    nodes.sort(key=lambda n: n.uid)

    names: dict[int, str] = {}
    variables: list[VariableDef] = []
    params: dict[str, np.ndarray] = {}

    def name_of(v: Variable, kind_hint: str = "intermediate") -> str:
        if id(v) in names:
            return names[id(v)]
        if isinstance(v, Parameter):
            nm, kind = v.name, "parameter"
            params[nm] = np.asarray(v.data)
        elif v.name:
            nm, kind = v.name, "input"
        else:
            nm, kind = f"h{len(names)}", kind_hint
        names[id(v)] = nm
        variables.append(VariableDef(
            name=nm, shape=[int(s) for s in v.shape],
            dtype=str(np.dtype(v.dtype)), kind=kind))
        return nm

    functions: list[FunctionDef] = []
    for i, node in enumerate(nodes):
        ins = [name_of(v) for v in node.inputs]
        outs = [name_of(v) for v in node.outputs]
        functions.append(FunctionDef(
            name=f"{node.name}_{i}", type=node.name, inputs=ins,
            outputs=outs,
            args={k: _ser_arg(v) for k, v in node.kwargs.items()}))

    out_names = [names[id(o)] for o in out_list]
    for vd in variables:
        if vd.name in out_names and vd.kind == "intermediate":
            vd.kind = "output"
    net = NetworkDef(name=name, variables=variables, functions=functions,
                     inputs=list(example_inputs), outputs=out_names)
    return net, params


def save_nnp(path: str, model: ModelFile,
             parameters: dict[str, np.ndarray]) -> None:
    with zipfile.ZipFile(path, "w", zipfile.ZIP_DEFLATED) as z:
        z.writestr("model.json", json.dumps(to_dict(model), indent=1))
        buf = io.BytesIO()
        np.savez(buf, **{k.replace("/", "|"): v
                         for k, v in parameters.items()})
        z.writestr("parameters.npz", buf.getvalue())


def load_nnp(path: str) -> tuple[ModelFile, dict[str, np.ndarray]]:
    with zipfile.ZipFile(path) as z:
        model = model_from_dict(json.loads(z.read("model.json")))
        with np.load(io.BytesIO(z.read("parameters.npz"))) as npz:
            params = {k.replace("|", "/"): npz[k] for k in npz.files}
    return model, params


def query_unsupported(net: NetworkDef,
                      registry: dict[str, Callable] | None = None
                      ) -> list[str]:
    reg = registry if registry is not None else op_registry()
    return sorted({f.type for f in net.functions if f.type not in reg})


class NnpExecutor:
    """Rebuild a jax callable from a NetworkDef (paper's Executor message)."""

    def __init__(self, net: NetworkDef, parameters: dict[str, np.ndarray],
                 jit: bool = True):
        missing = query_unsupported(net)
        if missing:
            raise ValueError(f"unsupported functions in network: {missing}")
        self.net = net
        self.reg = op_registry()
        self.params = {k: jnp.asarray(v) for k, v in parameters.items()
                       if any(vd.name == k and vd.kind == "parameter"
                              for vd in net.variables)}
        self._fn = jax.jit(self._run) if jit else self._run

    def _run(self, inputs: dict[str, jax.Array],
             params: dict[str, jax.Array]) -> list[jax.Array]:
        env: dict[str, Any] = dict(params)
        env.update(inputs)
        for f in self.net.functions:
            args = [env[i] for i in f.inputs]
            kwargs = {k: (tuple(v) if isinstance(v, list) else v)
                      for k, v in f.args.items()}
            out = self.reg[f.type](*args, **kwargs)
            outs = out if isinstance(out, tuple) else (out,)
            for nm, val in zip(f.outputs, outs):
                env[nm] = val
        return [env[o] for o in self.net.outputs]

    def __call__(self, **inputs) -> list[jax.Array]:
        arr = {k: jnp.asarray(v) for k, v in inputs.items()}
        return self._fn(arr, self.params)


def export_model(name: str, fn: Callable, example_inputs: dict[str, Any],
                 path: str, *, executor_name: str = "runtime") -> ModelFile:
    """One-call export: trace + wrap in ModelFile + save."""
    net, params = trace_network(name, fn, example_inputs)
    model = ModelFile(networks=[net], executors=[
        ExecutorDef(name=executor_name, network=name,
                    inputs=net.inputs, outputs=net.outputs)])
    save_nnp(path, model, params)
    return model
