"""Solvers (nnabla's name for optimizers) — dual-plane like everything else.

Eager plane (paper Listing 3/6 parity)::

    solver = S.Adam(alpha=1e-3)
    solver.set_parameters(nn.get_parameters())
    loss.backward(loss_scale)
    solver.scale_grad(1.0 / loss_scale)
    if solver.check_inf_or_nan_grad(): ...   # skip + rescale
    solver.update()

Functional plane (used by the distributed train step)::

    state = solver.init_state(params)
    params, state = solver.step(params, grads, state)

Mixed precision: when parameters are stored in fp16/bf16, the solver keeps an
fp32 **master copy** in its state and updates that, casting back to storage
dtype — the paper's "weights are managed in both FP-16 and 32" (§3.3).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.parameter import Parameter

Params = dict[str, Any]


def clip_by_global_norm(grads: Params, max_norm: float) -> tuple[Params, jax.Array]:
    """Global-norm gradient clipping (fp32 accumulation)."""
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(sq)
    factor = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree.map(
        lambda g: (g.astype(jnp.float32) * factor).astype(g.dtype), grads), gnorm


class Solver:
    name = "solver"

    def __init__(self, lr: float = 1e-3):
        self.lr = lr
        # eager plane
        self._params: dict[str, Parameter] = {}
        self._eager_state: dict[str, Any] = {}
        self._eager_step = 0

    # ------------------------------------------------------------------ #
    # per-leaf math, implemented by subclasses (always fp32)
    # ------------------------------------------------------------------ #
    def _init_slots(self, p32: jax.Array) -> dict[str, jax.Array]:
        raise NotImplementedError

    def _update(self, p32: jax.Array, g32: jax.Array,
                slots: dict[str, jax.Array], step: jax.Array,
                lr: jax.Array) -> tuple[jax.Array, dict[str, jax.Array]]:
        raise NotImplementedError

    # ------------------------------------------------------------------ #
    # functional plane
    # ------------------------------------------------------------------ #
    def init_state(self, params: Params) -> dict[str, Any]:
        def master(p):
            return p.astype(jnp.float32) if p.dtype != jnp.float32 else None
        masters = {k: master(v) for k, v in params.items()}
        masters = {k: v for k, v in masters.items() if v is not None}
        slots = {k: self._init_slots(v.astype(jnp.float32))
                 for k, v in params.items()}
        return {"step": jnp.zeros((), jnp.int32),
                "master": masters, "slots": slots}

    def init_state_shapes(self, params: Params) -> dict[str, Any]:
        return jax.eval_shape(self.init_state, params)

    def step(self, params: Params, grads: Params, state: dict[str, Any],
             lr: float | jax.Array | None = None) -> tuple[Params, dict[str, Any]]:
        lr = jnp.asarray(self.lr if lr is None else lr, jnp.float32)
        step_no = state["step"] + 1
        new_params: Params = {}
        new_masters: Params = {}
        new_slots: dict[str, Any] = {}
        for k, p in params.items():
            g32 = grads[k].astype(jnp.float32)
            p32 = state["master"].get(k, p).astype(jnp.float32)
            np32, nslots = self._update(p32, g32, state["slots"][k],
                                        step_no, lr)
            new_slots[k] = nslots
            if p.dtype != jnp.float32:
                new_masters[k] = np32
                new_params[k] = np32.astype(p.dtype)
            else:
                new_params[k] = np32
        return new_params, {"step": step_no, "master": new_masters,
                            "slots": new_slots}

    # ------------------------------------------------------------------ #
    # eager plane (paper API)
    # ------------------------------------------------------------------ #
    def set_parameters(self, params: dict[str, Parameter],
                       reset: bool = True) -> None:
        if reset:
            self._params.clear()
            self._eager_state.clear()
            self._eager_step = 0
        for k, p in params.items():
            if not p.need_grad:
                continue
            self._params[k] = p
            self._eager_state[k] = {
                "master": (p.data.astype(jnp.float32)
                           if p.dtype != jnp.float32 else None),
                "slots": self._init_slots(p.data.astype(jnp.float32)),
            }

    def set_learning_rate(self, lr: float) -> None:
        self.lr = lr

    def zero_grad(self) -> None:
        for p in self._params.values():
            p.grad = None

    def scale_grad(self, factor: float) -> None:
        """Paper Listing 6: ``solver.scale_grad(1. / loss_scale)``."""
        for p in self._params.values():
            if p.grad is not None:
                p.grad = (p.grad.astype(jnp.float32) * factor).astype(p.grad.dtype)

    def check_inf_or_nan_grad(self) -> bool:
        for p in self._params.values():
            if p.grad is not None and not bool(jnp.isfinite(p.grad).all()):
                return True
        return False

    def clip_grad_by_norm(self, clip_norm: float) -> None:
        grads = {k: p.grad for k, p in self._params.items()
                 if p.grad is not None}
        clipped, _ = clip_by_global_norm(grads, clip_norm)
        for k, g in clipped.items():
            self._params[k].grad = g

    def weight_decay(self, decay_rate: float) -> None:
        """nnabla semantics: fold L2 decay into the gradients."""
        for p in self._params.values():
            if p.grad is not None:
                p.grad = p.grad + decay_rate * p.data.astype(p.grad.dtype)

    def update(self) -> None:
        self._eager_step += 1
        step = jnp.asarray(self._eager_step, jnp.int32)
        lr = jnp.asarray(self.lr, jnp.float32)
        for k, p in self._params.items():
            if p.grad is None:
                continue
            st = self._eager_state[k]
            p32 = st["master"] if st["master"] is not None \
                else p.data.astype(jnp.float32)
            np32, nslots = self._update(p32, p.grad.astype(jnp.float32),
                                        st["slots"], step, lr)
            st["slots"] = nslots
            if st["master"] is not None:
                st["master"] = np32
                p.data = np32.astype(p.dtype)
            else:
                p.data = np32

    def __repr__(self) -> str:  # pragma: no cover
        return f"{type(self).__name__}(lr={self.lr})"
