"""Concrete solvers: Sgd, Momentum, Adam, AdamW, Adafactor-lite.

All math in fp32 on master weights (see base.py). Adafactor is the
beyond-paper memory saver for billion-parameter optimizer state (factored
second moment: O(n+m) instead of O(nm) per matrix).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.solvers.base import Solver


class Sgd(Solver):
    name = "sgd"

    def _init_slots(self, p32):
        return {}

    def _update(self, p32, g32, slots, step, lr):
        return p32 - lr * g32, slots


class Momentum(Solver):
    name = "momentum"

    def __init__(self, lr: float = 1e-3, momentum: float = 0.9):
        super().__init__(lr)
        self.momentum = momentum

    def _init_slots(self, p32):
        return {"v": jnp.zeros_like(p32)}

    def _update(self, p32, g32, slots, step, lr):
        v = self.momentum * slots["v"] + g32
        return p32 - lr * v, {"v": v}


class Adam(Solver):
    name = "adam"

    def __init__(self, alpha: float = 1e-3, beta1: float = 0.9,
                 beta2: float = 0.999, eps: float = 1e-8):
        super().__init__(alpha)
        self.beta1, self.beta2, self.eps = beta1, beta2, eps

    def _init_slots(self, p32):
        return {"m": jnp.zeros_like(p32), "v": jnp.zeros_like(p32)}

    def _bias_corrected_lr(self, step, lr):
        t = step.astype(jnp.float32)
        return lr * jnp.sqrt(1.0 - self.beta2 ** t) / (1.0 - self.beta1 ** t)

    def _update(self, p32, g32, slots, step, lr):
        m = self.beta1 * slots["m"] + (1 - self.beta1) * g32
        v = self.beta2 * slots["v"] + (1 - self.beta2) * jnp.square(g32)
        alpha_t = self._bias_corrected_lr(step, lr)
        new_p = p32 - alpha_t * m / (jnp.sqrt(v) + self.eps)
        return new_p, {"m": m, "v": v}


class AdamW(Adam):
    name = "adamw"

    def __init__(self, alpha: float = 1e-3, beta1: float = 0.9,
                 beta2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.01):
        super().__init__(alpha, beta1, beta2, eps)
        self.wd = weight_decay

    def _update(self, p32, g32, slots, step, lr):
        new_p, nslots = super()._update(p32, g32, slots, step, lr)
        return new_p - lr * self.wd * p32, nslots


class Adafactor(Solver):
    """Factored second moment (Shazeer & Stern 2018), beta1=0 variant.

    Optimizer state for a (n, m) matrix is n+m floats instead of 2nm —
    the difference between fitting and not fitting a 72B model's optimizer
    on 256 chips without ZeRO over more axes.
    """

    name = "adafactor"

    def __init__(self, lr: float = 1e-2, eps: float = 1e-30,
                 clip_threshold: float = 1.0, decay_rate: float = 0.8):
        super().__init__(lr)
        self.eps = eps
        self.clip_threshold = clip_threshold
        self.decay_rate = decay_rate

    def _init_slots(self, p32):
        if p32.ndim >= 2:
            return {"vr": jnp.zeros(p32.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p32.shape[:-2] + p32.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros_like(p32)}

    def _update(self, p32, g32, slots, step, lr):
        t = step.astype(jnp.float32)
        beta2t = 1.0 - jnp.power(t, -self.decay_rate)
        g2 = jnp.square(g32) + self.eps
        if p32.ndim >= 2:
            vr = beta2t * slots["vr"] + (1 - beta2t) * jnp.mean(g2, axis=-1)
            vc = beta2t * slots["vc"] + (1 - beta2t) * jnp.mean(g2, axis=-2)
            denom_r = vr / jnp.mean(vr, axis=-1, keepdims=True)
            u = g32 / (jnp.sqrt(denom_r)[..., None] * jnp.sqrt(vc)[..., None, :])
            nslots = {"vr": vr, "vc": vc}
        else:
            v = beta2t * slots["v"] + (1 - beta2t) * g2
            u = g32 / jnp.sqrt(v)
            nslots = {"v": v}
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
        return p32 - lr * u, nslots


SOLVERS = {cls.name: cls for cls in
           (Sgd, Momentum, Adam, AdamW, Adafactor)}


def make_solver(name: str, **kwargs) -> Solver:
    try:
        cls = SOLVERS[name]
    except KeyError as e:
        raise ValueError(f"unknown solver {name!r}; one of {sorted(SOLVERS)}") from e
    return cls(**kwargs)
