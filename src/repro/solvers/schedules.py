
"""Learning-rate schedules (pure functions of the step, jit-safe).

Composable with any solver: ``solver.step(params, grads, state,
lr=schedule(step))`` on the functional plane, or
``solver.set_learning_rate(float(schedule(i)))`` on the eager plane.
"""

from __future__ import annotations

import math

import jax.numpy as jnp


def constant(lr: float):
    def f(step):
        return jnp.full((), lr, jnp.float32)
    return f


def cosine(peak_lr: float, total_steps: int, warmup_steps: int = 0,
           final_fraction: float = 0.1):
    """Linear warmup -> cosine decay to final_fraction * peak (LLM default)."""
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * s / max(1, warmup_steps)
        prog = jnp.clip((s - warmup_steps)
                        / max(1, total_steps - warmup_steps), 0.0, 1.0)
        floor = peak_lr * final_fraction
        cos = floor + (peak_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, cos).astype(jnp.float32)
    return f


def inverse_sqrt(peak_lr: float, warmup_steps: int = 1000):
    def f(step):
        s = jnp.maximum(jnp.asarray(step, jnp.float32), 1.0)
        warm = peak_lr * s / max(1, warmup_steps)
        decay = peak_lr * jnp.sqrt(warmup_steps / s)
        return jnp.where(s < warmup_steps, warm, decay).astype(jnp.float32)
    return f


def step_decay(lr: float, gamma: float = 0.1, every: int = 30):
    """The paper's ImageNet-era staircase (x0.1 every 30 epochs)."""
    def f(step):
        k = jnp.floor(jnp.asarray(step, jnp.float32) / every)
        return (lr * gamma ** k).astype(jnp.float32)
    return f


SCHEDULES = {"constant": constant, "cosine": cosine,
             "inverse_sqrt": inverse_sqrt, "step_decay": step_decay}
