from repro.solvers.base import Solver, clip_by_global_norm
from repro.solvers.solvers import (SOLVERS, Adafactor, Adam, AdamW, Momentum,
                                   Sgd, make_solver)

__all__ = ["Solver", "clip_by_global_norm", "SOLVERS", "Adafactor", "Adam",
           "AdamW", "Momentum", "Sgd", "make_solver"]
