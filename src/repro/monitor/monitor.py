
"""Monitors — nnabla's ``nnabla.monitor`` (and the NNP ``Monitor`` message).

Training-status tracking the way the paper's ecosystem does it: per-series
scalar logs with interval-averaged flushes, wall-time monitors, and CSV
persistence that Neural Network Console-style tooling (our ``nnp_inspect``
sibling) can read back.
"""

from __future__ import annotations

import csv
import os
import pathlib
import time
from typing import Any


class Monitor:
    """A directory of monitored series (one file per series)."""

    def __init__(self, path: str | os.PathLike):
        self.path = pathlib.Path(path)
        self.path.mkdir(parents=True, exist_ok=True)


class MonitorSeries:
    """Interval-averaged scalar series, printed and persisted.

    nnabla parity: ``MonitorSeries("loss", monitor, interval=10).add(i, v)``.
    """

    def __init__(self, name: str, monitor: Monitor | None = None,
                 interval: int = 10, verbose: bool = True):
        self.name = name
        self.interval = max(1, interval)
        self.verbose = verbose
        self._buf: list[float] = []
        self._file = None
        if monitor is not None:
            self._file = open(monitor.path / f"{name.replace(' ', '_')}.txt",
                              "a", buffering=1)

    def add(self, index: int, value: Any) -> None:
        self._buf.append(float(value))
        if (index + 1) % self.interval == 0:
            mean = sum(self._buf) / len(self._buf)
            self._buf.clear()
            line = f"{index} {mean:.6f}"
            if self.verbose:
                print(f"[{self.name}] {line}", flush=True)
            if self._file is not None:
                self._file.write(line + "\n")

    def close(self) -> None:
        if self._file is not None:
            self._file.close()


class MonitorTimeElapsed:
    """Wall-time per interval (nnabla parity)."""

    def __init__(self, name: str, monitor: Monitor | None = None,
                 interval: int = 10, verbose: bool = True):
        self.series = MonitorSeries(name, monitor, interval=1,
                                    verbose=verbose)
        self.interval = max(1, interval)
        self._t0 = time.time()

    def add(self, index: int) -> None:
        if (index + 1) % self.interval == 0:
            now = time.time()
            self.series.add(index, now - self._t0)
            self._t0 = now


class MonitorCSV:
    """Multi-column CSV log (step + named metrics), flushed per row —
    restart-safe, resumable by appending."""

    def __init__(self, path: str | os.PathLike, fields: list[str]):
        self.path = pathlib.Path(path)
        self.fields = ["step"] + fields
        new = not self.path.exists()
        self._fh = open(self.path, "a", newline="", buffering=1)
        self._w = csv.writer(self._fh)
        if new:
            self._w.writerow(self.fields)

    def add(self, step: int, **metrics: Any) -> None:
        self._w.writerow([step] + [float(metrics.get(f, float("nan")))
                                   for f in self.fields[1:]])

    @staticmethod
    def read(path: str | os.PathLike) -> list[dict[str, float]]:
        with open(path, newline="") as fh:
            return [{k: float(v) for k, v in row.items()}
                    for row in csv.DictReader(fh)]

    def close(self) -> None:
        self._fh.close()
