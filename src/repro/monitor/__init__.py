from repro.monitor.monitor import (Monitor, MonitorSeries,
                                   MonitorTimeElapsed, MonitorCSV)

__all__ = ["Monitor", "MonitorSeries", "MonitorTimeElapsed", "MonitorCSV"]
