"""Kernel dispatch layer.

Models call these; the active :class:`repro.core.context.Context` decides
whether the Pallas TPU kernel, its interpret-mode build (CPU validation), or
the plain-XLA reference executes. The dry-run container always takes the XLA
path (TPU Pallas cannot lower on CPU backends); real-TPU deployments flip
``Context.kernels`` to ``"pallas"``.

Op x mode matrix (which implementation runs, and — for the paged ops —
where quantized pools (int8/fp8, :mod:`repro.kernels.quant`) convert):

=========================  ==============  ==============  ===================
op                         xla             xla_chunked     pallas[_interpret]
=========================  ==============  ==============  ===================
attention                  mha_reference   mha_chunked     flash_attention
attention_decode           decode ref      decode ref      flash_decode
attention_prefill          prefill ref     prefill ref     paged walk [#f1]_
attention_decode_paged     gather+dense    gather+dense    paged_decode
  quant (k/v_scale)        dequant in the  dequant in the  dequant in VMEM
                           gather [#f3]_   gather [#f3]_   post-DMA [#f4]_
attention_prefill_paged    gather+dense    gather+dense    paged_prefill
  quant (k/v_scale)        dequant in the  dequant in the  dequant in VMEM
                           gather [#f3]_   gather [#f3]_   post-DMA [#f4]_
paged_cache_write          jnp scatter     jnp scatter     fused paged_write
  quant (pool_scale)       jnp quantize +  jnp quantize +  absmax quant in
                           2-array scatter 2-array scatter the scatter body
ssd                        ssd_chunked     ssd_chunked     ssd kernel [#f2]_
ssd_decode_step            jnp             jnp             jnp (elementwise)
=========================  ==============  ==============  ===================

.. [#f1] dense prefill is the paged walk over an identity page table (a
   contiguous cache reshapes to a block pool for free).
.. [#f2] stateful continuation (``h0``) always takes the chunked-jnp path.
.. [#f3] ``gather_pages`` on the quantized pool + scale array, then one
   broadcast multiply — the dense copy is f32, so the same dense oracle
   applies and XLA-vs-Pallas parity holds at quantized dtypes too.
.. [#f4] *why VMEM*: dequantizing right after the double-buffered DMA
   wait means the HBM traffic is the **quantized** bytes (the whole point
   of the scheme — the walk is bandwidth-bound), the dequant multiply
   hides in the next block's DMA shadow, and the MXU sees exactly the
   high-precision operands of the unquantized walk, leaving the online
   softmax carry and chunk-causal mask untouched. A dequantized pool
   never exists in HBM in any mode.

Quantization scheme (shared by all modes): symmetric absmax, one f32
scale per written (token slot, kv head) — scale arrays (NB, bs, Hkv)
alongside each (NB, bs, Hkv, D) pool. Per-*slot* (not per-block) scales
keep the fused write a pure scatter (no read-modify-write of sibling
slots) and keep speculative decode bitwise: a stored token's bytes never
depend on rejected draft tokens sharing its block. The XLA quantize and
the Pallas write-kernel quantize are op-for-op identical, so pools are
bit-identical across modes and spill/fetch round-trips are exact.

Speculative verify steps (PR 6) add **no rows**: a ``(B, 1 + k)`` draft
window is just another chunk width through ``attention_prefill_paged``
and ``paged_cache_write``. Two properties of the existing rows make this
sound in every mode:

* chunk-causal masking is by *position* (``kpos <= qpos``), so K/V
  written at positions ``>= pos + length`` — pad columns then, rejected
  drafts now — are invisible to every real query of this and of any
  later step until the positions are legitimately rewritten;
* the scatter path is a plain last-writer-wins overwrite, so re-writing
  a rejected draft's slot position next step needs no clearing pass.

Tensor parallelism (serving mesh with a ``model`` axis active in the
ambient :class:`repro.distributed.sharding.ShardingEnv` at trace time):

=========================  =====================  =========================
op                         xla / xla_chunked      pallas[_interpret]
=========================  =====================  =========================
attention_{prefill,decode} GSPMD partitions the   shard_map over kv heads
 [+ _paged variants]       jnp reference (rule    (``Hkv % tp == 0``) or
                           table + ``constrain``  over grouped query heads
                           hints keep kv-head     (GQA ``Hkv < tp``: KV
                           dims sharded)          replicates); else runs
                                                  fully replicated
paged_cache_write          GSPMD scatter          shard_map over kv heads;
                                                  per-shard kernel keeps
                                                  ``input_output_aliases``
                                                  pool donation
=========================  =====================  =========================

The shard_map body is the *unchanged* single-device kernel: with the pool
sharded on kv heads, every shard walks the full page table over its local
``Hkv/tp`` head slice of every block — block ids stay global, the VMEM
double-buffered DMA walk and fused-scatter donation work per shard exactly
as they do on one device.
"""

from __future__ import annotations

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import context as _ctx
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.ssd import ref as ssd_ref


# --------------------------------------------------------------------------- #
# tensor-parallel wrapping of the Pallas kernels
#
# The XLA reference paths are plain jnp: under a serving mesh GSPMD
# partitions them from the rule-table constraints alone. pallas_call has no
# partitioning rule, so the Pallas builds must be wrapped in shard_map with
# an explicit layout — chosen here, at the dispatch layer, so neither the
# kernels nor the models know the mesh exists.
# --------------------------------------------------------------------------- #

def _tp_mesh():
    """The serving mesh, iff a >1-wide ``model`` axis is active at trace
    time (the engine scopes its ShardingEnv around step tracing)."""
    from repro.distributed.sharding import get_env
    mesh = get_env().mesh
    if mesh is None or mesh.empty or "model" not in mesh.shape:
        return None
    return mesh if mesh.shape["model"] > 1 else None


def _repl(*arrays):
    return tuple(P() for _ in arrays)


def _head_spec(a, ax):
    """PartitionSpec sharding array ``a``'s axis ``ax`` on the model axis."""
    ax = ax % a.ndim
    return P(*(None,) * ax, "model", *(None,) * (a.ndim - ax - 1))


def _tp_heads_call(fn, q, kv_args, rep_args, kv_axes=None):
    """Run ``fn(q, *kv_args, *rep_args) -> (B, C, Hq, D)`` under shard_map.

    ``kv_args`` carry the kv-head axis at the per-arg position in
    ``kv_axes`` (default -2 for every arg: block pools ``(NB, bs, Hkv, D)``
    and dense caches ``(B, S, Hkv, D)`` both do; quantized scale arrays
    ``(NB, bs, Hkv)`` pass -1); ``rep_args`` (page tables, positions,
    lengths) replicate. Layouts, in preference order: shard kv heads (each
    shard walks only its local pool slice); GQA ``Hkv < tp``: replicate
    KV, shard the per-group query heads; indivisible probe geometries:
    run fully replicated.
    """
    mesh = _tp_mesh()
    if mesh is None:
        return fn(q, *kv_args, *rep_args)
    if kv_axes is None:
        kv_axes = (-2,) * len(kv_args)
    tp = mesh.shape["model"]
    B, C, Hq, D = q.shape
    Hkv = kv_args[0].shape[-2]
    rep_specs = _repl(*rep_args)
    if Hkv % tp == 0:
        # q heads are grouped contiguously by kv head (head h serves kv
        # head h // rep), so sharding the q-head axis into tp contiguous
        # chunks lands each chunk on the shard holding its kv heads.
        kv_specs = tuple(
            _head_spec(a, ax) for a, ax in zip(kv_args, kv_axes))
        sharded = shard_map(
            fn, mesh=mesh,
            in_specs=(P(None, None, "model", None),) + kv_specs + rep_specs,
            out_specs=P(None, None, "model", None), check_rep=False)
        return sharded(q, *kv_args, *rep_args)
    group = Hq // Hkv
    if group % tp == 0:
        # replicate KV, split each kv head's query group across shards;
        # regrouping happens inside the shard so GQA ratios stay intact
        qg = q.reshape(B, C, Hkv, group, D)

        def _grouped(qg_loc, *args):
            out = fn(qg_loc.reshape(B, C, -1, D), *args)
            return out.reshape(qg_loc.shape)

        sharded = shard_map(
            _grouped, mesh=mesh,
            in_specs=(P(None, None, None, "model", None),)
            + _repl(*kv_args) + rep_specs,
            out_specs=P(None, None, None, "model", None), check_rep=False)
        return sharded(qg, *kv_args, *rep_args).reshape(B, C, Hq, D)
    sharded = shard_map(fn, mesh=mesh,
                        in_specs=_repl(q, *kv_args) + rep_specs,
                        out_specs=P(), check_rep=False)
    return sharded(q, *kv_args, *rep_args)


def _tp_write_call(fn, pool, new, pages, pos, pool_scale=None):
    """Fused paged scatter under shard_map: pool and chunk both shard on
    the kv-head axis (position -2; a quantized scale array shards the same
    head axis at -1), page table and positions replicate. The per-shard
    kernel still donates its pool (+ scale) slice in place via
    ``input_output_aliases``."""
    mesh = _tp_mesh()
    if mesh is None:
        return fn(pool, new, pages, pos) if pool_scale is None \
            else fn(pool, new, pages, pos, pool_scale)
    tp = mesh.shape["model"]
    split = pool.shape[-2] % tp == 0
    kv = _head_spec(pool, -2) if split else P()
    if pool_scale is None:
        sharded = shard_map(fn, mesh=mesh, in_specs=(kv, kv, P(), P()),
                            out_specs=kv, check_rep=False)
        return sharded(pool, new, pages, pos)
    sc = _head_spec(pool_scale, -1) if split else P()
    sharded = shard_map(fn, mesh=mesh, in_specs=(kv, kv, P(), P(), sc),
                        out_specs=(kv, sc), check_rep=False)
    return sharded(pool, new, pages, pos, pool_scale)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None,
              unroll: int | bool = 1, block: int = 1024) -> jax.Array:
    mode = _ctx.get_default_context().kernels
    if mode == "xla":
        return fa_ref.mha_reference(q, k, v, causal=causal, window=window,
                                    scale=scale)
    if mode == "xla_chunked":
        # flash algorithm in plain XLA (online softmax over KV blocks).
        # Cost probes fully unroll the block scans (while-body undercount);
        # they use a larger block so the unrolled HLO stays compilable —
        # total FLOPs/bytes are block-size invariant.
        if unroll is True:
            block = max(block, 4096)
        return fa_ref.mha_chunked(q, k, v, causal=causal, window=window,
                                  scale=scale, block_q=block, block_k=block,
                                  unroll=unroll)
    from repro.kernels.flash_attention import flash_attention as fa
    return fa.flash_attention(q, k, v, causal=causal, window=window,
                              scale=scale,
                              interpret=(mode == "pallas_interpret"))


def attention_decode(q, k_cache, v_cache, lengths, *, scale=None) -> jax.Array:
    mode = _ctx.get_default_context().kernels
    if mode in ("xla", "xla_chunked"):
        return fa_ref.decode_reference(q, k_cache, v_cache, lengths,
                                       scale=scale)
    from repro.kernels.flash_attention import flash_attention as fa

    def _call(q_, k_, v_, len_):
        return fa.flash_decode(q_, k_, v_, len_, scale=scale,
                               interpret=(mode == "pallas_interpret"))

    return _tp_heads_call(_call, q, (k_cache, v_cache), (lengths,))


def attention_prefill(q, k_cache, v_cache, pos, *, scale=None) -> jax.Array:
    """Chunk-causal attention for chunked prefill: q (B, C, Hq, D) against a
    (B, Smax, Hkv, D) cache; query i of row b sees cache[: pos[b] + i + 1].
    """
    mode = _ctx.get_default_context().kernels
    if mode in ("xla", "xla_chunked"):
        # no chunked-XLA variant: the chunk is short and the cache read is
        # one bandwidth pass, so blockwise XLA would buy nothing here
        return fa_ref.prefill_reference(q, k_cache, v_cache, pos, scale=scale)
    from repro.kernels.flash_attention import paged_attention as pa

    def _call(q_, k_, v_, pos_):
        return pa.prefill_dense(q_, k_, v_, pos_, scale=scale,
                                interpret=(mode == "pallas_interpret"))

    return _tp_heads_call(_call, q, (k_cache, v_cache), (pos,))


def attention_decode_paged(q, k_pool, v_pool, pages, lengths, *,
                           scale=None, k_scale=None,
                           v_scale=None) -> jax.Array:
    """Single-token decode against a block-paged cache: q (B, 1, Hq, D),
    pools (num_blocks, block_size, Hkv, D), ``pages`` (B, max_blocks) int32
    block ids per row, ``lengths`` (B,) valid token counts.

    XLA modes lower to the gather-then-dense reference — one extra full
    HBM pass plus a transient dense copy sized by the worst-case table
    width. Pallas modes walk the page table in VMEM (double-buffered block
    DMAs, no materialized gather): :mod:`.flash_attention.paged_attention`.

    Quantized pools (int8/fp8) pass their (NB, bs, Hkv) scale arrays via
    ``k_scale``/``v_scale``; see the matrix above for where each mode
    dequantizes.
    """
    mode = _ctx.get_default_context().kernels
    if mode in ("xla", "xla_chunked"):
        return fa_ref.paged_decode_reference(q, k_pool, v_pool, pages,
                                             lengths, scale=scale,
                                             k_scale=k_scale,
                                             v_scale=v_scale)
    from repro.kernels.flash_attention import paged_attention as pa

    if k_scale is not None:
        def _call_q(q_, k_, v_, ks_, vs_, pages_, len_):
            return pa.paged_decode(q_, k_, v_, pages_, len_, scale=scale,
                                   k_scale=ks_, v_scale=vs_,
                                   interpret=(mode == "pallas_interpret"))

        return _tp_heads_call(_call_q, q, (k_pool, v_pool, k_scale, v_scale),
                              (pages, lengths), kv_axes=(-2, -2, -1, -1))

    def _call(q_, k_, v_, pages_, len_):
        return pa.paged_decode(q_, k_, v_, pages_, len_, scale=scale,
                               interpret=(mode == "pallas_interpret"))

    return _tp_heads_call(_call, q, (k_pool, v_pool), (pages, lengths))


def attention_prefill_paged(q, k_pool, v_pool, pages, pos, *,
                            scale=None, k_scale=None,
                            v_scale=None) -> jax.Array:
    """Chunk-causal prefill against a block-paged cache: q (B, C, Hq, D)
    with query i of row b seeing positions ``<= pos[b] + i`` gathered
    through the row's page table (see :func:`attention_decode_paged` for
    the layout, mode dispatch and quantized-pool handling).
    """
    mode = _ctx.get_default_context().kernels
    if mode in ("xla", "xla_chunked"):
        return fa_ref.paged_prefill_reference(q, k_pool, v_pool, pages, pos,
                                              scale=scale, k_scale=k_scale,
                                              v_scale=v_scale)
    from repro.kernels.flash_attention import paged_attention as pa

    if k_scale is not None:
        def _call_q(q_, k_, v_, ks_, vs_, pages_, pos_):
            return pa.paged_prefill(q_, k_, v_, pages_, pos_, scale=scale,
                                    k_scale=ks_, v_scale=vs_,
                                    interpret=(mode == "pallas_interpret"))

        return _tp_heads_call(_call_q, q, (k_pool, v_pool, k_scale, v_scale),
                              (pages, pos), kv_axes=(-2, -2, -1, -1))

    def _call(q_, k_, v_, pages_, pos_):
        return pa.paged_prefill(q_, k_, v_, pages_, pos_, scale=scale,
                                interpret=(mode == "pallas_interpret"))

    return _tp_heads_call(_call, q, (k_pool, v_pool), (pages, pos))


def paged_cache_write(pool, new, pages, pos, *, pool_scale=None):
    """Scatter a (B, C, Hkv, D) K/V chunk into a (NB, bs, Hkv, D) pool.

    Token i of row b lands at flat slot ``pages[b, p // bs] * bs + p % bs``
    with ``p = pos[b] + i``. Rows whose page-table entry is 0 (idle slots,
    pad columns past a row's allocation) scatter into the garbage block,
    which no valid mask ever reads — so the write needs no predication.
    Tokens whose position falls past the table's last column likewise go to
    the garbage block: clipping the column instead would silently overwrite
    whatever live block sits in the last entry.

    Pallas modes fuse the scatter into a kernel whose output index map
    computes each token's (block, slot) destination directly (pool donated
    in place); XLA modes use the flat jnp scatter below.

    With ``pool_scale`` (quantized pool's (NB, bs, Hkv) scale array), the
    chunk is absmax-quantized to the pool dtype on the way in — inside the
    Pallas scatter body, or as a jnp quantize feeding a two-array scatter
    in the XLA modes (bit-identical results) — and ``(pool, pool_scale)``
    is returned.
    """
    mode = _ctx.get_default_context().kernels
    if mode not in ("xla", "xla_chunked"):
        from repro.kernels.flash_attention import paged_attention as pa

        if pool_scale is not None:
            def _call_q(pool_, new_, pages_, pos_, scale_):
                return pa.paged_write(pool_, new_, pages_, pos_,
                                      pool_scale=scale_,
                                      interpret=(mode == "pallas_interpret"))

            return _tp_write_call(_call_q, pool, new, pages, pos, pool_scale)

        def _call(pool_, new_, pages_, pos_):
            return pa.paged_write(pool_, new_, pages_, pos_,
                                  interpret=(mode == "pallas_interpret"))

        return _tp_write_call(_call, pool, new, pages, pos)
    nb, bs = pool.shape[0], pool.shape[1]
    B, C = new.shape[0], new.shape[1]
    MB = pages.shape[1]
    p = pos[:, None] + jax.numpy.arange(C, dtype=pos.dtype)[None, :]
    col = p // bs
    blk = jax.numpy.take_along_axis(
        pages, jax.numpy.clip(col, 0, MB - 1), axis=1)
    blk = jax.numpy.where(col < MB, blk, 0)    # overrun -> garbage block
    flat = (blk * bs + p % bs).reshape(-1)
    if pool_scale is not None:
        from repro.kernels import quant
        new_q, s_new = quant.quantize(new, pool.dtype)
        pool_flat = pool.reshape((nb * bs,) + pool.shape[2:])
        pool_flat = pool_flat.at[flat].set(
            new_q.reshape((B * C,) + new_q.shape[2:]))
        scale_flat = pool_scale.reshape((nb * bs,) + pool_scale.shape[2:])
        scale_flat = scale_flat.at[flat].set(
            s_new.astype(pool_scale.dtype).reshape((B * C,) + s_new.shape[2:]))
        return (pool_flat.reshape(pool.shape),
                scale_flat.reshape(pool_scale.shape))
    pool_flat = pool.reshape((nb * bs,) + pool.shape[2:])
    pool_flat = pool_flat.at[flat].set(
        new.astype(pool.dtype).reshape((B * C,) + new.shape[2:]))
    return pool_flat.reshape(pool.shape)


def ssd(x, dt, A, Bm, Cm, D=None, *, chunk: int = 64, h0=None,
        return_state: bool = False, unroll: int | bool = 1):
    mode = _ctx.get_default_context().kernels
    # The Pallas kernel always starts from h=0; stateful continuation
    # (chunked prefill) goes through the chunked-jnp path in every mode.
    if mode in ("xla", "xla_chunked") or h0 is not None:
        return ssd_ref.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk, h0=h0,
                                   return_state=return_state, unroll=unroll)
    from repro.kernels.ssd import ssd_kernel
    return ssd_kernel.ssd(x, dt, A, Bm, Cm, D, chunk=chunk, h0=h0,
                          return_state=return_state,
                          interpret=(mode == "pallas_interpret"))


def ssd_decode_step(h, x_t, dt_t, A, B_t, C_t, D=None):
    # Single-token state update is elementwise-dominated; XLA fuses it well.
    return ssd_ref.ssd_decode_step(h, x_t, dt_t, A, B_t, C_t, D)
