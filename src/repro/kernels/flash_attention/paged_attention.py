"""Paged attention as Pallas TPU kernels: walk the page table in VMEM.

The XLA references (:func:`ref.paged_decode_reference` /
:func:`ref.paged_prefill_reference`) gather every row's blocks into a dense
``(B, max_blocks * block_size, Hkv, D)`` copy of the cache before attending —
one full extra HBM round-trip per step plus a transient allocation that
scales with the *worst-case* table width, not the request's actual length.

The kernels here never build that view. The K/V pools stay in HBM
(``memory_space=ANY``); the page table and per-row lengths ride in as
scalar-prefetch operands (:class:`pltpu.PrefetchScalarGridSpec`) so block
ids are known ahead of the grid step, and each step issues
:func:`pltpu.make_async_copy` DMAs that pull exactly one ``(block_size, D)``
K and V tile into double-buffered VMEM scratch — the next block's copy is
in flight while the current block is on the MXU. An online softmax
(m, l, acc) carried in VMEM scratch across the sequential kv-block grid
axis reproduces the flash-attention recurrence, and ``pl.when`` skips every
block at or past the row's valid length — idle rows and short requests cost
no DMA and no FLOPs, instead of attending to a worst-case-wide gather.

Both entry points share one kernel:

* :func:`paged_decode` — q ``(B, 1, Hq, D)`` vs ``lengths`` (B,): query
  sees positions ``< lengths[b]``. This is the C = 1 / ``pos = lengths - 1``
  special case of the chunk-causal walk.
* :func:`paged_prefill` — q ``(B, C, Hq, D)`` vs ``pos`` (B,): query i of
  row b sees gathered positions ``<= pos[b] + i`` (the chunk-causal mask of
  ``ref.prefill_reference``).

GQA is handled exactly as in :mod:`flash_attention`: the grid runs over KV
heads and the q BlockSpec index map keeps that head's ``rep`` query heads
resident, flattened to a ``(C * rep, D)`` MXU operand.

:func:`paged_write` is the fused scatter companion: the *output* BlockSpec
index map computes each token's ``(block, slot)`` destination from the
scalar-prefetched table, so the chunk lands directly in the pool
(``input_output_aliases`` donates it — no read-modify-write of the flat
pool, no materialized scatter indices). Tokens past the row's table width
are redirected into the garbage block 0, which no valid mask ever reads.

Quantized pools (int8/fp8, see :mod:`repro.kernels.quant`) fuse both
directions into the same kernels: the walk DMAs each block's
per-(slot, head) scale vector alongside its K/V tile and dequantizes in
VMEM right after the waits (one VPU broadcast multiply — no dequantized
pool copy ever exists in HBM, and the DMA'd bytes are *halved*), while
``paged_write`` computes the absmax quant inside the scatter body and
donates pool + scale array through the same index maps.

Validated against the gather-then-dense references in interpret mode (CPU
container, block sizes 4/8/16, GQA, ragged lengths); ``interpret=False``
targets real TPUs. Lengths/pos semantics assume ``lengths >= 1`` for any
row whose output is consumed (the engine always decodes at ``pos + 1``);
a length-0 row yields zeros, not the reference's garbage-uniform average.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import quant

NEG_INF = -1e30


def _paged_attn_kernel(pages_ref, pos_ref, q_ref, k_hbm, v_hbm, *rest,
                       bs: int, C: int, rep: int, scale: float,
                       quantized: bool):
    """One (batch row, kv head, kv block) grid step of the paged walk.

    Scratch persists across the innermost (sequential) grid axis: m/l/acc
    carry the online softmax, k_vmem/v_vmem are the two DMA landing slots.
    ``pos_ref[b] + C`` is the row's visible-token count — for decode
    (C = 1, pos = lengths - 1) that is exactly ``lengths[b]``.

    Quantized pools add per-(slot, head) scale vectors that ride the same
    double-buffer rhythm: each block's ``(bs,)`` scale slice is DMA'd
    alongside its K/V tile (own landing slots + semaphore) and the dequant
    is a single VPU multiply right after the waits — the MXU sees the
    same high-precision operands as the unquantized walk, so the online
    softmax carry and the chunk-causal mask are untouched.
    """
    if quantized:
        (ks_hbm, vs_hbm, o_ref, m_scr, l_scr, acc_scr,
         k_vmem, v_vmem, ks_vmem, vs_vmem, sem_s, sem) = rest
    else:
        o_ref, m_scr, l_scr, acc_scr, k_vmem, v_vmem, sem = rest
    b = pl.program_id(0)
    h = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    visible = pos_ref[b] + C          # tokens any query of this row can see

    def block_dma(slot, col, hbm, vmem):
        # The page-table lookup: scalar-prefetched block id -> HBM tile.
        blk = pages_ref[b, col]
        return pltpu.make_async_copy(
            hbm.at[blk, :, h, :], vmem.at[slot], sem.at[slot])

    def scale_dma(slot, col, hbm, vmem):
        blk = pages_ref[b, col]
        return pltpu.make_async_copy(
            hbm.at[blk, :, h], vmem.at[slot], sem_s.at[slot])

    def start_block(slot, col):
        block_dma(slot, col, k_hbm, k_vmem).start()
        block_dma(slot, col, v_hbm, v_vmem).start()
        if quantized:
            scale_dma(slot, col, ks_hbm, ks_vmem).start()
            scale_dma(slot, col, vs_hbm, vs_vmem).start()

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

        @pl.when(visible > 0)
        def _warm():
            start_block(0, 0)

    @pl.when(ki * bs < visible)
    def _body():
        # Double buffering: kick off block ki+1 into the other slot before
        # touching this block's data, so its DMA overlaps our MXU work.
        # Every started copy is awaited by its own grid step (the prefetch
        # guard only fires for steps that will run), so no semaphore leaks
        # across (b, h) rows.
        @pl.when((ki + 1) * bs < visible)
        def _prefetch():
            start_block((ki + 1) % 2, ki + 1)

        slot = ki % 2
        # wait() only consumes the semaphore + dst shape; src is a dummy.
        pltpu.make_async_copy(k_hbm.at[0, :, h, :], k_vmem.at[slot],
                              sem.at[slot]).wait()
        pltpu.make_async_copy(v_hbm.at[0, :, h, :], v_vmem.at[slot],
                              sem.at[slot]).wait()
        if quantized:
            pltpu.make_async_copy(ks_hbm.at[0, :, h], ks_vmem.at[slot],
                                  sem_s.at[slot]).wait()
            pltpu.make_async_copy(vs_hbm.at[0, :, h], vs_vmem.at[slot],
                                  sem_s.at[slot]).wait()

        q = q_ref[0, :, 0, :, :].astype(jnp.float32).reshape(C * rep, -1)
        k = k_vmem[slot].astype(jnp.float32)              # (bs, D)
        v = v_vmem[slot].astype(jnp.float32)
        if quantized:
            # dequant in VMEM: one broadcast multiply per tile, fused into
            # the DMA shadow — never a dequantized pool copy in HBM
            k = k * ks_vmem[slot][:, None]
            v = v * vs_vmem[slot][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        # chunk-causal: query i (s-row i * rep + r) sees kpos <= pos + i
        qpos = pos_ref[b] + jax.lax.broadcasted_iota(
            jnp.int32, (C * rep, bs), 0) // rep
        kpos = ki * bs + jax.lax.broadcasted_iota(
            jnp.int32, (C * rep, bs), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] \
            + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _fini():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :, :] = (acc_scr[...] / denom[:, None]) \
            .astype(o_ref.dtype).reshape(C, rep, -1)


def _paged_walk(q, k_pool, v_pool, pages, pos, *, scale, interpret,
                k_scale=None, v_scale=None):
    """Shared pallas_call builder: q (B, C, Hq, D) through the page table
    with the chunk-causal mask anchored at per-row ``pos``. Quantized
    pools (int8/fp8) pass their (NB, bs, Hkv) scale arrays as extra
    HBM-resident operands."""
    B, C, Hq, D = q.shape
    _, bs, Hkv, _ = k_pool.shape
    MB = pages.shape[1]
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    quantized = k_scale is not None
    if quantized and v_scale is None:
        raise ValueError("k_scale given without v_scale")
    rep = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qh = q.reshape(B, C, Hkv, rep, D)

    in_specs = [
        pl.BlockSpec((1, C, 1, rep, D),
                     lambda b, h, ki, *_: (b, 0, h, 0, 0)),
        pl.BlockSpec(memory_space=pltpu.ANY),   # K pool stays in HBM
        pl.BlockSpec(memory_space=pltpu.ANY),   # V pool stays in HBM
    ]
    scratch = [
        pltpu.VMEM((C * rep,), jnp.float32),          # m
        pltpu.VMEM((C * rep,), jnp.float32),          # l
        pltpu.VMEM((C * rep, D), jnp.float32),        # acc
        pltpu.VMEM((2, bs, D), k_pool.dtype),         # K landing slots
        pltpu.VMEM((2, bs, D), v_pool.dtype),         # V landing slots
    ]
    operands = [pages, jnp.asarray(pos, jnp.int32), qh, k_pool, v_pool]
    if quantized:
        in_specs += [
            pl.BlockSpec(memory_space=pltpu.ANY),     # K scales (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),     # V scales (HBM)
        ]
        scratch += [
            pltpu.VMEM((2, bs), k_scale.dtype),       # K scale slots
            pltpu.VMEM((2, bs), v_scale.dtype),       # V scale slots
            pltpu.SemaphoreType.DMA((2,)),            # scale DMA sem
        ]
        operands += [k_scale, v_scale]
    scratch += [pltpu.SemaphoreType.DMA((2,))]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # pages, pos
        grid=(B, Hkv, MB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, C, 1, rep, D),
                               lambda b, h, ki, *_: (b, 0, h, 0, 0)),
        scratch_shapes=scratch,
    )
    out = pl.pallas_call(
        functools.partial(_paged_attn_kernel, bs=bs, C=C, rep=rep,
                          scale=scale, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, Hkv, rep, D), q.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(B, C, Hq, D)


def paged_decode(q, k_pool, v_pool, pages, lengths, *, scale=None,
                 k_scale=None, v_scale=None,
                 interpret: bool = False) -> jax.Array:
    """Single-token decode through the page table. q (B, 1, Hq, D); pools
    (num_blocks, block_size, Hkv, D); pages (B, max_blocks) int32;
    lengths (B,) valid token counts (the query sees kpos < lengths[b]).
    Quantized pools pass (NB, bs, Hkv) scales via k_scale/v_scale."""
    B, one, _, _ = q.shape
    assert one == 1, "decode takes a single query token per row"
    return _paged_walk(q, k_pool, v_pool, pages,
                       jnp.asarray(lengths, jnp.int32) - 1,
                       scale=scale, interpret=interpret,
                       k_scale=k_scale, v_scale=v_scale)


def paged_prefill(q, k_pool, v_pool, pages, pos, *, scale=None,
                  k_scale=None, v_scale=None,
                  interpret: bool = False) -> jax.Array:
    """Chunk-causal prefill through the page table. q (B, C, Hq, D);
    query i of row b sees gathered positions ``<= pos[b] + i``."""
    return _paged_walk(q, k_pool, v_pool, pages, pos,
                       scale=scale, interpret=interpret,
                       k_scale=k_scale, v_scale=v_scale)


def prefill_dense(q, k_cache, v_cache, pos, *, scale=None,
                  interpret: bool = False) -> jax.Array:
    """Chunk-causal prefill against a *dense* (B, Smax, Hkv, D) cache,
    run through the paged kernel: a contiguous cache is just a block pool
    with the identity page table (row b's blocks are b*MB .. b*MB+MB-1),
    so the reshape is free and no dedicated dense kernel is needed."""
    B, C, Hq, D = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    # largest power-of-two tile <= 128 dividing Smax (gcd with 2^7); odd
    # Smax degrades to bs=1 — correct, but size caches in block multiples
    bs = math.gcd(Smax, 128)
    MB = Smax // bs
    k_pool = k_cache.reshape(B * MB, bs, Hkv, D)
    v_pool = v_cache.reshape(B * MB, bs, Hkv, D)
    pages = (jnp.arange(B, dtype=jnp.int32)[:, None] * MB
             + jnp.arange(MB, dtype=jnp.int32)[None, :])
    return _paged_walk(q, k_pool, v_pool, pages, pos,
                       scale=scale, interpret=interpret)


def _paged_write_kernel(pages_ref, pos_ref, new_ref, pool_ref, out_ref):
    # The scatter is entirely in the output index map; the body just lands
    # the token's (Hkv, D) tile in its block slot.
    del pages_ref, pos_ref, pool_ref
    out_ref[...] = new_ref[...].astype(out_ref.dtype)


def _paged_write_quant_kernel(pages_ref, pos_ref, new_ref, pool_ref,
                              scale_pool_ref, out_ref, scale_out_ref, *,
                              qmax: float, integer: bool):
    # Quant fused into the scatter: per-(token, head) absmax over D on the
    # VPU, then the same output-index-map landing — op-for-op identical to
    # quant.quantize so the XLA path writes bit-identical pools.
    del pages_ref, pos_ref, pool_ref, scale_pool_ref
    x = new_ref[...].astype(jnp.float32)                  # (1, 1, Hkv, D)
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), quant._EPS)
    # reciprocal multiply, matching quant.quantize (see the note there)
    s = (amax * (1.0 / qmax)).astype(scale_out_ref.dtype)  # (1, 1, Hkv)
    q = x / s.astype(jnp.float32)[..., None]
    if integer:
        q = jnp.clip(jnp.round(q), -qmax, qmax)
    out_ref[...] = q.astype(out_ref.dtype)
    scale_out_ref[...] = s


def paged_write(pool, new, pages, pos, *, pool_scale=None,
                interpret: bool = False):
    """Fused scatter of a (B, C, Hkv, D) chunk into a (NB, bs, Hkv, D)
    pool: token i of row b lands at block ``pages[b, (pos[b]+i) // bs]``,
    slot ``(pos[b]+i) % bs``. Tokens past the table width go to the
    garbage block 0 (never read). The pool is donated in place
    (``input_output_aliases``): no flat-index materialization, no
    read-modify-write of untouched blocks.

    With ``pool_scale`` (quantized (NB, bs, Hkv) scale array), the chunk
    is absmax-quantized to the pool dtype *inside* the scatter — both the
    pool and the scale array are donated outputs and the per-token scale
    lands through the same index map. Returns ``(pool, pool_scale)``."""
    NB, bs, Hkv, D = pool.shape
    B, C = new.shape[:2]
    MB = pages.shape[1]

    def out_map(b, i, pages_ref, pos_ref):
        p = pos_ref[b] + i
        col = p // bs
        blk = jnp.where(col < MB, pages_ref[b, jnp.minimum(col, MB - 1)], 0)
        return blk, p % bs, 0, 0

    if pool_scale is None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,            # pages, pos
            grid=(B, C),
            in_specs=[
                pl.BlockSpec((1, 1, Hkv, D), lambda b, i, *_: (b, i, 0, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),  # donated pool (unread)
            ],
            out_specs=pl.BlockSpec((1, 1, Hkv, D), out_map),
        )
        return pl.pallas_call(
            _paged_write_kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
            # operand 3 counting the two scalar-prefetch args: (pages, pos,
            # new, pool) -> pool aliases the single output
            input_output_aliases={3: 0},
            interpret=interpret,
        )(pages, jnp.asarray(pos, jnp.int32), new, pool)

    def scale_map(b, i, pages_ref, pos_ref):
        p = pos_ref[b] + i
        col = p // bs
        blk = jnp.where(col < MB, pages_ref[b, jnp.minimum(col, MB - 1)], 0)
        return blk, p % bs, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                # pages, pos
        grid=(B, C),
        in_specs=[
            pl.BlockSpec((1, 1, Hkv, D), lambda b, i, *_: (b, i, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # donated pool (unread)
            pl.BlockSpec(memory_space=pltpu.ANY),   # donated scales (unread)
        ],
        out_specs=[pl.BlockSpec((1, 1, Hkv, D), out_map),
                   pl.BlockSpec((1, 1, Hkv), scale_map)],
    )
    qd = jnp.dtype(pool.dtype)
    return pl.pallas_call(
        functools.partial(_paged_write_quant_kernel, qmax=quant.qmax(qd),
                          integer=bool(jnp.issubdtype(qd, jnp.integer))),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(pool.shape, pool.dtype),
                   jax.ShapeDtypeStruct(pool_scale.shape, pool_scale.dtype)],
        # operands counting the two scalar-prefetch args: (pages, pos, new,
        # pool, scales) -> pool and scales alias the two outputs
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(pages, jnp.asarray(pos, jnp.int32), new, pool, pool_scale)
