"""Pure-jnp oracle for the flash-attention kernel.

Also the XLA fallback path used whenever ``Context.kernels == "xla"`` (e.g.
the CPU dry-run container, where TPU Pallas cannot lower).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None) -> jax.Array:
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D); GQA by head broadcast.

    fp32 logits + softmax; output cast back to q.dtype.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    rep = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    qh = q.reshape(B, Sq, Hkv, rep, D)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qh, k,
                        preferred_element_type=jnp.float32) * scale
    if causal or window is not None:
        qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kpos = jnp.arange(Sk)[None, :]
        mask = jnp.ones((Sq, Sk), bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def _block_update(carry, q_i, k_i, v_i, mask, scale):
    """One online-softmax accumulation step (fp32)."""
    m, l, acc = carry
    s = jnp.einsum("...qhrd,...khd->...hrqk", q_i, k_i,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, -1e30)
    m_new = jnp.maximum(m, s.max(-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l * alpha + p.sum(-1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "...hrqk,...khd->...hrqd", p, v_i,
        preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def mha_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                causal: bool = True, window: int | None = None,
                scale: float | None = None, block_q: int = 1024,
                block_k: int = 1024, unroll: int | bool = 1) -> jax.Array:
    """Blockwise online-softmax attention in pure XLA ops.

    The flash-attention *algorithm* without the Pallas kernel: stream KV
    blocks against resident Q blocks carrying (m, l, acc); the (Sq, Sk)
    logits matrix never materializes, so peak memory is O(block_q·block_k)
    instead of O(Sq·Sk).

    Causal mode uses the **folded schedule**: q-block rows i and nq-1-i are
    paired; row i needs i+1 KV blocks and its partner needs nq-i, so every
    pair needs exactly nq+1 — a static loop bound that skips the upper
    triangle's compute entirely (2x fewer FLOPs than mask-only blocking,
    visible in HLO, not just at runtime).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    use_fold = (causal and window is None and Sq == Sk and bq == bk
                and Sq % bq == 0 and (Sq // bq) % 2 == 0)
    if (Sq % bq or Sk % bk) or (causal and not use_fold and Sq != Sk):
        # ragged / offset shapes (tests, speculative decode): plain reference
        return mha_reference(q, k, v, causal=causal, window=window,
                             scale=scale)
    nq, nk = Sq // bq, Sk // bk

    qb = q.reshape(B, nq, bq, Hkv, rep, D)
    kb = k.reshape(B, nk, bk, Hkv, D)
    vb = v.reshape(B, nk, bk, Hkv, D)
    tri_q = jnp.arange(bq)[:, None]
    tri_k = jnp.arange(bk)[None, :]

    if use_fold:
        npair = nq // 2
        pair_i = jnp.arange(npair)                   # rows 0..npair-1
        pair_j = nq - 1 - pair_i                     # rows nq-1..npair
        q_lo = qb[:, :npair]                         # (B,P,bq,Hkv,rep,D)
        q_hi = qb[:, npair:][:, ::-1]
        # move pair dim first for scan-friendly batching
        q_lo = jnp.moveaxis(q_lo, 1, 0)              # (P,B,bq,...)
        q_hi = jnp.moveaxis(q_hi, 1, 0)

        def kv_step(carry, s):
            lo, hi = carry
            # row i consumes kv s while s <= i; afterwards row j consumes
            # kv (s - i - 1); per pair, exactly one block of work per step.
            on_lo = s <= pair_i                                   # (P,)
            ki = jnp.where(on_lo, jnp.minimum(s, nk - 1),
                           jnp.clip(s - pair_i - 1, 0, nk - 1))   # (P,)
            k_i = jnp.moveaxis(kb[:, ki], 1, 0)       # (P,B,bk,Hkv,D)
            v_i = jnp.moveaxis(vb[:, ki], 1, 0)
            selm = on_lo[:, None, None, None, None]   # m/l (P,B,Hkv,rep,bq)
            sela = on_lo[:, None, None, None, None, None]  # acc (+D)
            selq = on_lo[:, None, None, None, None, None]  # q (P,B,bq,h,r,D)
            q_sel = jnp.where(selq, q_lo, q_hi)
            cur = (jnp.where(selm, lo[0], hi[0]),
                   jnp.where(selm, lo[1], hi[1]),
                   jnp.where(sela, lo[2], hi[2]))
            row = jnp.where(on_lo, pair_i, pair_j)                # (P,)
            # mask: diagonal block needs the triangle; off-diagonal is full
            diag = row == ki
            qpos = row[:, None, None] * bq + tri_q[None]
            kpos = ki[:, None, None] * bk + tri_k[None]
            mask = jnp.where(diag[:, None, None], qpos >= kpos, True)
            mask = mask[:, None, None, None, :, :]    # (P,1,1,1,bq,bk)
            new = _block_update(cur, q_sel, k_i, v_i, mask, scale)
            sels = (selm, selm, sela)
            lo = tuple(jnp.where(sl, nw, old)
                       for sl, nw, old in zip(sels, new, lo))
            hi = tuple(jnp.where(sl, old, nw)
                       for sl, nw, old in zip(sels, new, hi))
            return (lo, hi), None

        def init():
            m0 = jnp.full((npair, B, Hkv, rep, bq), -1e30, jnp.float32)
            l0 = jnp.zeros((npair, B, Hkv, rep, bq), jnp.float32)
            a0 = jnp.zeros((npair, B, Hkv, rep, bq, D), jnp.float32)
            return (m0, l0, a0)

        (lo, hi), _ = jax.lax.scan(kv_step, (init(), init()),
                                   jnp.arange(nq + 1), unroll=unroll)

        def finalize(t):
            m, l, acc = t
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            return jnp.moveaxis(out, 4, 2)            # (P,B,bq,Hkv,rep,D)

        o_lo = finalize(lo)
        o_hi = finalize(hi)[::-1]
        out = jnp.concatenate([o_lo, o_hi], axis=0)   # (nq,B,bq,...)
        out = jnp.moveaxis(out, 0, 1)                 # (B,nq,bq,...)
        return out.reshape(B, Sq, Hq, D).astype(q.dtype)

    # non-causal / windowed: plain blockwise sweep with masking
    off = Sk - Sq

    def q_block(carry, qi):
        q_i = qb[:, qi]

        def kv_step(c, ki):
            k_i = kb[:, ki]
            v_i = vb[:, ki]
            qpos = qi * bq + tri_q + off
            kpos = ki * bk + tri_k
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask = mask & (qpos >= kpos)
            if window is not None:
                mask = mask & ((qpos - kpos) < window)
            return _block_update(c, q_i, k_i, v_i,
                                 mask[None, None, None], scale), None

        m0 = jnp.full((B, Hkv, rep, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, rep, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, rep, bq, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nk), unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, jnp.moveaxis(out, 3, 1)         # (B,bq,Hkv,rep,D)

    _, outs = jax.lax.scan(q_block, 0, jnp.arange(nq), unroll=unroll)
    out = jnp.moveaxis(outs, 0, 1)                    # (B,nq,bq,...)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def prefill_reference(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                      pos: jax.Array, *, scale: float | None = None
                      ) -> jax.Array:
    """Chunked-prefill attention: a (B, C, Hq, D) query chunk against a
    (B, Smax, Hkv, D) cache whose rows were just written at per-row offsets
    ``pos`` (B,).

    Chunk-causal: query i of row b attends to cache entries j <= pos[b] + i.
    Cache entries beyond the chunk (stale slots from an earlier occupant of
    the row) are never visible because pos[b] + C - 1 bounds the window.
    """
    B, C, Hq, D = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    rep = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qh = q.reshape(B, C, Hkv, rep, D)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qh, k_cache,
                        preferred_element_type=jnp.float32) * scale
    qpos = pos[:, None] + jnp.arange(C)[None, :]               # (B, C)
    valid = jnp.arange(Smax)[None, None, :] <= qpos[..., None]  # (B, C, Smax)
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, C, Hq, D).astype(q.dtype)


def gather_pages(pool: jax.Array, pages: jax.Array) -> jax.Array:
    """Materialize per-row dense caches from a block pool.

    ``pool`` (num_blocks, block_size, Hkv, D) — or a (num_blocks,
    block_size, Hkv) scale array; any trailing shape rides along.
    ``pages`` (B, max_blocks) int32 block ids (0 = the garbage block —
    rows past a request's length, masked out downstream). Returns
    (B, max_blocks * block_size, ...), the exact dense cache the row
    would have held, so the dense references below apply unchanged and
    paged-vs-dense logits agree bitwise.
    """
    bs = pool.shape[1]
    B, MB = pages.shape
    g = jnp.take(pool, pages, axis=0)            # (B, MB, bs, ...)
    return g.reshape((B, MB * bs) + pool.shape[2:])


def _gather_dequant(pool, scale_arr, pages):
    """Gather a quantized pool + its scales into the dense f32 cache the
    unquantized row would have held — the jnp mirror of the kernel's
    in-VMEM dequant, so the same dense oracles apply to quantized pools."""
    dense = gather_pages(pool, pages).astype(jnp.float32)
    s = gather_pages(scale_arr, pages).astype(jnp.float32)
    return dense * s[..., None]


def paged_prefill_reference(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, pages: jax.Array,
                            pos: jax.Array, *, scale: float | None = None,
                            k_scale: jax.Array | None = None,
                            v_scale: jax.Array | None = None) -> jax.Array:
    """Chunk-causal prefill attention through a page table: gather each
    row's blocks into its dense-equivalent cache, then delegate to
    :func:`prefill_reference` (the oracle for paged-vs-dense equivalence).
    Quantized pools pass their (NB, bs, Hkv) scales via k_scale/v_scale
    and are dequantized in the gather."""
    if k_scale is not None:
        return prefill_reference(q, _gather_dequant(k_pool, k_scale, pages),
                                 _gather_dequant(v_pool, v_scale, pages),
                                 pos, scale=scale)
    return prefill_reference(q, gather_pages(k_pool, pages),
                             gather_pages(v_pool, pages), pos, scale=scale)


def paged_decode_reference(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, pages: jax.Array,
                           lengths: jax.Array, *, scale: float | None = None,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None) -> jax.Array:
    """Single-token decode attention through a page table (see
    :func:`paged_prefill_reference`)."""
    if k_scale is not None:
        return decode_reference(q, _gather_dequant(k_pool, k_scale, pages),
                                _gather_dequant(v_pool, v_scale, pages),
                                lengths, scale=scale)
    return decode_reference(q, gather_pages(k_pool, pages),
                            gather_pages(v_pool, pages), lengths, scale=scale)


def decode_reference(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, scale: float | None = None
                     ) -> jax.Array:
    """Single-token decode: q (B, 1, Hq, D) against a (B, Smax, Hkv, D) cache.

    ``lengths`` (B,) — number of valid cache entries per sequence.
    """
    B, _, Hq, D = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    rep = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qh = q.reshape(B, Hkv, rep, D)
    logits = jnp.einsum("bhrd,bkhd->bhrk", qh, k_cache,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(Smax)[None, :] < lengths[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhrk,bkhd->bhrd", probs, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)
