"""Flash attention as a Pallas TPU kernel.

TPU-native adaptation of the (GPU-origin) flash-attention insight: stream KV
blocks through VMEM against a resident Q block with an online softmax, so the
(Sq, Sk) logits matrix never exists in HBM. Tiling is MXU-aligned
(block_q x block_k >= 128x128, head_dim lanes = 128) and the accumulator
lives in VMEM scratch that persists across the innermost (KV) grid axis —
the TPU grid is sequential, which replaces the GPU kernel's thread-block
reduction with a legal cross-step carry.

GQA is handled in the index maps (query head h reads KV head h // group).
Causal masking skips fully-masked KV blocks via ``pl.when``.

Validated against ``ref.mha_reference`` in interpret mode (CPU container);
``interpret=False`` targets real TPUs.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int | None,
               block_q: int, block_k: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # Whole-block skip: in causal mode a KV block strictly above the
    # diagonal (and, with a window, one entirely below it) contributes
    # nothing -> don't even load it.
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(
            run, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = kpos < seq_k
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] \
            + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _fini():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q (B, Sq, Hq, D); k/v (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    # pad seq lengths to block multiples
    Sq_p = -(-Sq // bq) * bq
    Sk_p = -(-Sk // bk) * bk
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    if Sk_p != Sk:
        k = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))

    grid = (B, Hq, Sq_p // bq, Sk_p // bk)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq_p, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]


def _fd_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, block_k: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0, 0, :, :].astype(jnp.float32)        # (rep, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] \
            + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _fini():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, 0, :, :] = (acc_scr[...] / denom[:, None]) \
            .astype(o_ref.dtype)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 lengths: jax.Array, *, scale: float | None = None,
                 block_k: int = 512, interpret: bool = False) -> jax.Array:
    """Single-token decode. q (B, 1, Hq, D); caches (B, Smax, Hkv, D);
    lengths (B,). Grid streams the cache; one (batch, kv-head) per step with
    the query's ``rep`` grouped heads resident."""
    B, one, Hq, D = q.shape
    assert one == 1
    _, Smax, Hkv, _ = k_cache.shape
    rep = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bk = min(block_k, Smax)
    Sk_p = -(-Smax // bk) * bk
    if Sk_p != Smax:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, Sk_p - Smax), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, Sk_p - Smax), (0, 0), (0, 0)))

    qh = q.reshape(B, 1, Hkv, rep, D)
    grid = (B, Hkv, Sk_p // bk)

    out = pl.pallas_call(
        functools.partial(_fd_kernel, scale=scale, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, rep, D), lambda b, h, ki: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1,), lambda b, h, ki: (b,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, rep, D),
                               lambda b, h, ki: (b, 0, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, Hkv, rep, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, D), jnp.float32),
        ],
        interpret=interpret,
    )(qh, k_cache, v_cache, lengths)
    return out.reshape(B, 1, Hq, D)
