"""Quantized KV block pools: dtypes, scales, and the jnp quant/dequant.

The paged KV pools can be stored in int8 (or fp8 where the platform
dtype exists) instead of the compute dtype, halving (vs bf16) the HBM
bytes every block costs — the block budget every other subsystem
(tiering, spec decode, tp sharding) spends doubles for free. This module
owns the *scheme*; the kernels (:mod:`.flash_attention.paged_attention`)
and the XLA references (:mod:`.flash_attention.ref`) own the fusion.

Scheme: symmetric absmax, one scale per written **token slot per
kv-head** — scale arrays shaped ``(num_blocks, block_size, Hkv)``
(float32) ride alongside each ``(num_blocks, block_size, Hkv, D)`` pool.
Why per-(slot, head) rather than the coarser per-(block, head):

* **Pure scatter.** Decode writes one token into a partially-filled
  block. A block-granular scale would need the block's other slots
  re-scaled on every write (read-modify-write, breaking the donated
  fused scatter); a per-slot scale is computed from the written token
  alone and lands through the same output index map.
* **Speculative decode stays bitwise.** The engine guarantees the
  spec-k stream equals the spec-0 stream. A block-wide absmax would
  make accepted tokens' quantized values depend on *rejected* draft
  tokens sharing the block; per-slot scales keep each token's stored
  bytes a pure function of that token.

The byte cost: scales add 4 bytes per token per head next to ``D``
payload bytes, so int8 + scales is ``(D + 4) / (2 * D)`` of bf16 —
0.53x at D = 64. Per-channel (per-D-lane) scales are a recorded
follow-on (ROADMAP), as is an int4 packed layout.
"""

from __future__ import annotations

from typing import Any

import jax.numpy as jnp

SCALE_DTYPE = jnp.float32

# fp8 support depends on the jax build; gate rather than require
FP8_DTYPE = getattr(jnp, "float8_e4m3fn", None)

_QMAX = {jnp.dtype(jnp.int8): 127.0}
if FP8_DTYPE is not None:
    _QMAX[jnp.dtype(FP8_DTYPE)] = 448.0   # e4m3fn max finite

# floor on the absmax so a silent/zero token quantizes to zeros with a
# harmless scale instead of dividing by zero
_EPS = 1e-12


def is_quantized(dtype: Any) -> bool:
    """True when ``dtype`` is a quantized KV storage dtype (needs scales)."""
    return jnp.dtype(dtype) in _QMAX


def qmax(dtype: Any) -> float:
    """Largest representable magnitude used as the absmax target."""
    return _QMAX[jnp.dtype(dtype)]


def resolve_kv_dtype(name: str | None, compute_dtype: Any):
    """Map a ``--kv-dtype`` string to a concrete storage dtype.

    ``None``/"native" keep the compute dtype (unquantized). "fp8" falls
    back to int8 with a warning when the jax build has no float8.
    """
    if name is None or name in ("", "native"):
        return jnp.dtype(compute_dtype)
    table = {
        "int8": jnp.int8,
        "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
        "fp16": jnp.float16, "float16": jnp.float16, "half": jnp.float16,
        "fp32": jnp.float32, "float32": jnp.float32,
    }
    if name == "fp8":
        if FP8_DTYPE is None:
            import warnings
            warnings.warn("this jax build has no float8_e4m3fn; "
                          "kv_dtype=fp8 falls back to int8", RuntimeWarning)
            return jnp.dtype(jnp.int8)
        return jnp.dtype(FP8_DTYPE)
    if name not in table:
        raise ValueError(f"unknown kv dtype {name!r} (expected int8, fp8, "
                         f"bf16, fp16, fp32 or native)")
    return jnp.dtype(table[name])


def kv_dtype_name(dtype: Any) -> str:
    """Canonical short name for reporting (metrics line, CacheSpec)."""
    d = jnp.dtype(dtype)
    if d == jnp.dtype(jnp.int8):
        return "int8"
    if FP8_DTYPE is not None and d == jnp.dtype(FP8_DTYPE):
        return "fp8"
    return {"bfloat16": "bf16", "float16": "fp16",
            "float32": "fp32"}.get(d.name, d.name)


def quantize(x, qdtype):
    """Quantize ``(..., D)`` to ``qdtype`` with per-``(...)`` absmax scales.

    Returns ``(q, scale)`` where ``q`` has ``x``'s shape in ``qdtype``
    and ``scale`` is float32 shaped like ``x`` minus the last axis, such
    that ``q * scale[..., None] ~= x``. Matches the Pallas fused-write
    kernel op-for-op (f32 absmax, round-to-nearest for ints) so the XLA
    and kernel paths produce bit-identical pools.
    """
    qd = jnp.dtype(qdtype)
    xf = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), _EPS)
    # explicit reciprocal multiply: XLA rewrites division-by-constant to
    # this anyway, but inconsistently across lowering contexts — writing
    # the multiply keeps the fused Pallas write bit-identical to this path
    scale = (amax * (1.0 / qmax(qd))).astype(SCALE_DTYPE)
    q = xf / scale.astype(jnp.float32)[..., None]
    if jnp.issubdtype(qd, jnp.integer):
        q = jnp.clip(jnp.round(q), -qmax(qd), qmax(qd))
    return q.astype(qd), scale


def dequantize(q, scale, out_dtype=jnp.float32):
    """Inverse of :func:`quantize`: ``q (..., D)`` times ``scale (...)``."""
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(out_dtype)
