"""Device-tuned kernels behind the function-API dispatch (paper: "speedy
computation" without changing user code).

Layout: each hot-spot package ships ``<name>.py`` (the Pallas TPU kernel),
``ref.py`` (the pure-jnp oracle / XLA fallback) and is routed through
:mod:`repro.kernels.ops`, where the active ``Context.kernels`` mode picks
the implementation. See the op x mode matrix in the :mod:`.ops` docstring:

* ``xla`` — plain references (CPU containers, dry runs, oracles).
* ``xla_chunked`` — blockwise-XLA flash algorithm where one exists.
* ``pallas`` — compiled Pallas TPU kernels (real-TPU deployments).
* ``pallas_interpret`` — the same kernels on the Pallas interpreter
  (bit-accurate CPU validation of kernel logic, used by CI).

Packages: ``flash_attention`` (dense flash + decode, and the paged-
attention page-table walk in ``flash_attention/paged_attention.py``),
``ssd`` (Mamba-2 state-space duality scan).
"""
