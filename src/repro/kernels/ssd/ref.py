"""Pure-jnp oracles for the Mamba-2 SSD (state-space duality) scan.

``ssd_naive``   — token-by-token linear recurrence (the ground truth).
``ssd_chunked`` — the SSD block-decomposition (intra-chunk quadratic +
inter-chunk state recurrence) in plain jnp; this is both the oracle for the
Pallas kernel's chunking logic and the XLA fallback the full models lower on
the dry-run.

Shapes (following the Mamba-2 paper):
  x  (B, S, H, P)   per-head inputs        H heads, P head_dim
  dt (B, S, H)      softplus-positive step sizes
  A  (H,)           negative decay rates (scalar per head, SSD restriction)
  Bm (B, S, G, N)   input->state projection   G state groups, N state dim
  Cm (B, S, G, N)   state->output projection
  D  (H,)           skip connection
Returns y (B, S, H, P); final state (B, H, P, N) if requested.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _expand_groups(m: jax.Array, H: int) -> jax.Array:
    """(B, S, G, N) -> (B, S, H, N) by repeating each group H//G times."""
    B, S, G, N = m.shape
    rep = H // G
    return jnp.repeat(m, rep, axis=2) if rep > 1 else m


def ssd_naive(x, dt, A, Bm, Cm, D=None, *, h0=None, return_state=False):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = _expand_groups(Bm.astype(jnp.float32), H)
    Cf = _expand_groups(Cm.astype(jnp.float32), H)
    dA = jnp.exp(dtf * A.astype(jnp.float32))          # (B,S,H)

    def step(h, inp):
        xt, dat, dtt, bt, ct = inp
        # h: (B,H,P,N)
        h = h * dat[..., None, None] \
            + (dtt[..., None] * xt)[..., None] * bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0
    inps = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dA, 1, 0),
            jnp.moveaxis(dtf, 1, 0), jnp.moveaxis(Bf, 1, 0),
            jnp.moveaxis(Cf, 1, 0))
    hT, ys = lax.scan(step, h0, inps)
    y = jnp.moveaxis(ys, 0, 1)                          # (B,S,H,P)
    if D is not None:
        y = y + xf * D.astype(jnp.float32)[:, None]
    y = y.astype(x.dtype)
    return (y, hT) if return_state else y


def _segsum(logd: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} logd[..., k].

    Returns -inf above the diagonal (strictly causal decay matrix in log
    space). logd: (..., Q) -> (..., Q, Q).
    """
    Q = logd.shape[-1]
    csum = jnp.cumsum(logd, axis=-1)
    diff = csum[..., :, None] - csum[..., None, :]      # sum_{j<k<=i}
    i = jnp.arange(Q)[:, None]
    j = jnp.arange(Q)[None, :]
    return jnp.where(i >= j, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, D=None, *, chunk: int = 64, h0=None,
                return_state=False, unroll: int | bool = 1):
    """Mamba-2 §6 block decomposition. S must be a multiple of ``chunk``."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    if S % chunk:
        raise ValueError(f"S={S} not a multiple of chunk={chunk}")
    nc, Q = S // chunk, chunk

    # matmul INPUTS stay in the storage dtype (bf16 on the MXU), accumulation
    # in f32 via preferred_element_type — mirrors the Pallas kernel's numerics
    # and halves the big-tensor HBM traffic vs an all-f32 reference.
    mm = x.dtype
    xf = x.reshape(B, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, Q, H)
    Bf = _expand_groups(Bm, H).reshape(B, nc, Q, H, N)
    Cf = _expand_groups(Cm, H).reshape(B, nc, Q, H, N)
    logd = dtf * A.astype(jnp.float32)                  # (B,nc,Q,H) log decay
    xbar = (xf.astype(jnp.float32) * dtf[..., None]).astype(mm)

    # ---- intra-chunk (quadratic, "attention-like") ----
    Lmat = jnp.exp(_segsum(jnp.moveaxis(logd, -1, -2)))      # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cf, Bf,
                        preferred_element_type=jnp.float32)  # (B,nc,H,Q,Q)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp",
                         (scores * Lmat).astype(mm), xbar,
                         preferred_element_type=jnp.float32)

    # ---- chunk summary states ----
    csum = jnp.cumsum(logd, axis=2)                          # (B,nc,Q,H)
    decay_to_end = jnp.exp(csum[:, :, -1:, :] - csum)        # (B,nc,Q,H)
    states = jnp.einsum(
        "bcqhn,bcqhp->bchnp",
        (Bf.astype(jnp.float32) * decay_to_end[..., None]).astype(mm), xbar,
        preferred_element_type=jnp.float32)                  # (B,nc,H,N,P)

    # ---- inter-chunk recurrence over chunk states ----
    chunk_decay = jnp.exp(csum[:, :, -1, :])                 # (B,nc,H)

    def scan_fn(h, inp):
        s_c, d_c = inp                                       # (B,H,N,P),(B,H)
        h_new = h * d_c[..., None, None] + s_c
        return h_new, h                                      # emit state *before* chunk

    h_init = jnp.zeros((B, H, N, P), jnp.float32) if h0 is None \
        else jnp.moveaxis(h0, 2, 3)                          # accept (B,H,P,N)
    hT, h_prev = lax.scan(
        scan_fn, h_init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        unroll=unroll)
    h_prev = jnp.moveaxis(h_prev, 0, 1)                      # (B,nc,H,N,P)

    # ---- inter-chunk contribution ----
    decay_from_start = jnp.exp(csum)                         # (B,nc,Q,H)
    y_inter = jnp.einsum(
        "bcqhn,bchnp->bcqhp",
        (Cf.astype(jnp.float32) * decay_from_start[..., None]).astype(mm),
        h_prev.astype(mm), preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    if D is not None:
        y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[:, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, jnp.moveaxis(hT, 2, 3)                     # (B,H,P,N)
    return y


def ssd_decode_step(h, x_t, dt_t, A, B_t, C_t, D=None):
    """One-token state update. h (B,H,P,N); x_t (B,H,P); dt_t (B,H);
    B_t/C_t (B,G,N). Returns (y_t (B,H,P), h')."""
    B_, H, P, N = h.shape
    Bf = _expand_groups(B_t[:, None].astype(jnp.float32), H)[:, 0]
    Cf = _expand_groups(C_t[:, None].astype(jnp.float32), H)[:, 0]
    dtf = dt_t.astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32))                # (B,H)
    xf = x_t.astype(jnp.float32)
    h_new = h * dA[..., None, None] \
        + (dtf[..., None] * xf)[..., None] * Bf[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Cf)
    if D is not None:
        y = y + xf * D.astype(jnp.float32)[:, None]
    return y.astype(x_t.dtype), h_new
