"""Mamba-2 SSD scan as a Pallas TPU kernel.

TPU adaptation of the SSD block decomposition: one (batch, head) per outer
grid cell, chunks streamed along the innermost (sequential) grid axis with
the running (N, P) state held in VMEM scratch — the recurrence carries across
grid steps instead of across GPU thread blocks. Per chunk the kernel does the
three MXU matmuls of the duality form (C·Bᵀ masked by the decay matrix,
state read-out, state update) in fp32.

Chunk tiles: Q x N and Q x P with Q, N, P multiples of the 128-lane /
8-sublane layout where the config allows (Q=128+ recommended).

Validated against ``ref.ssd_chunked``/``ref.ssd_naive`` in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
                h_scr, *, chunk: int, has_d: bool):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)        # (Q,)
    A = a_ref[0]                                       # scalar (f32, SMEM)
    Bm = b_ref[0, 0, :, 0, :].astype(jnp.float32)      # (Q, N)
    Cm = c_ref[0, 0, :, 0, :].astype(jnp.float32)      # (Q, N)

    logd = dt * A                                      # (Q,)
    csum = jnp.cumsum(logd)                            # (Q,)
    xbar = x * dt[:, None]                             # (Q, P)

    # intra-chunk: masked (C Bᵀ) with pairwise decay
    i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ldiff = csum[:, None] - csum[None, :]              # log decay i<-j
    L = jnp.where(i >= j, jnp.exp(ldiff), 0.0)         # (Q, Q)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * L, xbar, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)

    # inter-chunk: read out the carried state
    decay_from_start = jnp.exp(csum)[:, None]          # (Q, 1)
    h_prev = h_scr[...]                                # (N, P)
    y += jax.lax.dot_general(Cm * decay_from_start, h_prev,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    if has_d:
        y += x * d_ref[0]

    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: h = exp(sum logd) h + (B * decay_to_end)ᵀ xbar
    total = csum[chunk - 1]
    decay_to_end = jnp.exp(total - csum)[:, None]      # (Q, 1)
    h_new = jnp.exp(total) * h_prev \
        + jax.lax.dot_general(Bm * decay_to_end, xbar,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    h_scr[...] = h_new

    @pl.when(ci == nc - 1)
    def _emit():
        hout_ref[0, 0, :, :] = h_new


def ssd(x, dt, A, Bm, Cm, D=None, *, chunk: int = 64, h0=None,
        return_state: bool = False, interpret: bool = False):
    """Pallas SSD. Shapes as in :mod:`repro.kernels.ssd.ref`.

    ``h0`` is not supported in-kernel (prefill starts cold); callers that
    split sequences across calls combine states at the ref layer.
    """
    if h0 is not None:
        raise NotImplementedError("kernel path starts from h=0; "
                                  "use the ref for stateful continuation")
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    if S % chunk:
        raise ValueError(f"S={S} % chunk={chunk} != 0")
    nc = S // chunk
    group = H // G

    grid = (B, H, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, has_d=D is not None)
    d_arr = (D if D is not None else jnp.zeros((H,))).astype(jnp.float32)

    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, 1, P),
                         lambda b, h, ci: (b, ci, 0, h, 0)),
            pl.BlockSpec((1, 1, chunk, 1),
                         lambda b, h, ci: (b, ci, 0, h)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, chunk, 1, N),
                         lambda b, h, ci, g=group: (b, ci, 0, h // g, 0)),
            pl.BlockSpec((1, 1, chunk, 1, N),
                         lambda b, h, ci, g=group: (b, ci, 0, h // g, 0)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, 1, P),
                         lambda b, h, ci: (b, ci, 0, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, chunk, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x.reshape(B, nc, chunk, H, P),
      dt.reshape(B, nc, chunk, H),
      A.astype(jnp.float32),
      Bm.reshape(B, nc, chunk, G, N),
      Cm.reshape(B, nc, chunk, G, N),
      d_arr)

    y = y.reshape(B, S, H, P)
    if return_state:
        return y, jnp.moveaxis(hT, 2, 3)  # (B, H, P, N)
    return y
