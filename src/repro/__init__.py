"""repro — Neural Network Libraries (nnabla) rebuilt as a JAX/TPU framework.

    import repro.core as nn
    import repro.core.functions as F
    import repro.core.parametric as PF

See README.md / DESIGN.md / EXPERIMENTS.md at the repo root.
"""

__version__ = "1.0.0"
