"""granite-34b — llama-arch code model, MQA [arXiv:2405.04324; hf].

88L d_model=6144 48H (kv=1, multi-query) d_ff=24576 vocab=49152.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    rope_theta=10000.0,
    norm="layernorm",
    act="gelu",
)
