"""whisper-medium — enc-dec, conv frontend stubbed [arXiv:2212.04356].

24L (x2: 24 encoder + 24 decoder) d_model=1024 16H (kv=16 MHA) d_ff=4096
vocab=51865. LayerNorm + GELU, learned decoder positions, sinusoidal encoder
positions; frontend provides (B, 1500, d_model) frame embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    encoder_decoder=True,
    n_encoder_layers=24,
    n_audio_frames=1500,
    max_position=1 << 16,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
)
