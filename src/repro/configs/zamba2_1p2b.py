"""zamba2-1.2b — Mamba2 backbone + shared attention [arXiv:2411.15242; hf].

38L d_model=2048 32H (kv=32, MHA shared block) d_ff=8192 vocab=32000,
ssm_state=64. Shared attention applied every 6 layers (one physical copy).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_chunk=256,
    attn_every=6,
    norm="rmsnorm",
    act="gelu",
)
