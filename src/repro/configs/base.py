"""Model/run configuration schema.

One ``ModelConfig`` per architecture (exact public-literature numbers live in
``repro/configs/<id>.py``); ``smoke()`` derives the reduced same-family
variant used by CPU smoke tests. ``ShapeConfig`` is one input-shape cell of
the assignment.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm", "cnn"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 2048        # tokens per dispatch group
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    # --- hybrid (zamba2) ---
    attn_every: int = 0               # shared attn block period; 0 = never
    # --- positions ---
    rope_theta: float = 10000.0
    max_position: int = 1 << 20
    mrope: bool = False               # qwen2-vl 3-section M-RoPE
    # --- enc-dec (whisper) ---
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500
    # --- misc ---
    tie_embeddings: bool = False
    norm: str = "rmsnorm"             # "rmsnorm" | "layernorm"
    act: str = "silu"                 # "silu" | "gelu"
    qkv_bias: bool = False
    remat: str = "full"               # layer_stack remat policy for training
    scan_layers: bool = True
    scan_unroll: int | bool = 1       # True (cost probes) = fully unrolled
    loss_chunk: int = 0               # >0: chunked CE (no (B,S,V) buffer)
    ssm_split_proj: bool = False      # split z/x/B/C projections (TP-clean)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    # ------------------------------------------------------------------ #
    # parameter / FLOP accounting (used by roofline + nnp_inspect)
    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        d, dff, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        n = emb
        d_inner = self.ssm_expand * d

        def attn_params() -> int:
            return d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
                + hd * self.n_heads * d

        def mlp_params() -> int:
            mult = 3 if self.act == "silu" else 2  # gated vs plain
            return mult * d * dff

        def ssm_params() -> int:
            nh = d_inner // self.ssm_head_dim
            in_proj = d * (2 * d_inner + 2 * self.ssm_ngroups * self.ssm_state
                           + nh)
            conv = (d_inner + 2 * self.ssm_ngroups * self.ssm_state) * self.ssm_conv
            out = d_inner * d
            return in_proj + conv + out + 2 * nh + d_inner  # A, D, norm

        if self.family in ("dense", "vlm"):
            n += L * (attn_params() + mlp_params() + 2 * d) + d
        elif self.family == "moe":
            n += L * (attn_params() + self.n_experts * mlp_params()
                      + d * self.n_experts + 2 * d) + d
        elif self.family == "ssm":
            n += L * (ssm_params() + d) + d
        elif self.family == "hybrid":
            n += L * (ssm_params() + d) + d
            if self.attn_every:
                n += attn_params() + mlp_params() + 2 * d  # one shared block
        elif self.family == "audio":
            n += self.n_encoder_layers * (attn_params() + mlp_params() + 2 * d)
            n += L * (2 * attn_params() + mlp_params() + 3 * d) + 2 * d
        return n

    def active_param_count(self) -> int:
        """Activated-per-token params (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, dff, L = self.d_model, self.d_ff, self.n_layers
        mult = 3 if self.act == "silu" else 2
        inactive = L * (self.n_experts - self.top_k) * mult * d * dff
        return self.param_count() - inactive

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 if not self.attn_every
                         else max(2, self.attn_every)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_group_size=64,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=16,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            n_audio_frames=16,
            max_position=4096,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k needs a sub-quadratic-prefill story; only SSM/hybrid run it.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return False, ("pure full-attention arch; 500k-token context has no "
                       "sub-quadratic prefill path (skip per assignment)")
    return True, ""
