"""Architecture registry: ``--arch <id>`` resolves here."""

from repro.configs.base import (LONG_CONTEXT_FAMILIES, SHAPES, ModelConfig,
                                ShapeConfig, cell_applicable)
from repro.configs.phi35_moe import CONFIG as phi35_moe
from repro.configs.granite_moe import CONFIG as granite_moe
from repro.configs.mamba2_370m import CONFIG as mamba2_370m
from repro.configs.zamba2_1p2b import CONFIG as zamba2_1p2b
from repro.configs.deepseek_coder_33b import CONFIG as deepseek_coder_33b
from repro.configs.llama32_1b import CONFIG as llama32_1b
from repro.configs.mistral_nemo_12b import CONFIG as mistral_nemo_12b
from repro.configs.granite_34b import CONFIG as granite_34b
from repro.configs.whisper_medium import CONFIG as whisper_medium
from repro.configs.qwen2_vl_72b import CONFIG as qwen2_vl_72b

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        phi35_moe, granite_moe, mamba2_370m, zamba2_1p2b,
        deepseek_coder_33b, llama32_1b, mistral_nemo_12b, granite_34b,
        whisper_medium, qwen2_vl_72b,
    ]
}


def get_arch(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError as e:
        raise ValueError(
            f"unknown arch {name!r}; one of {sorted(ARCHS)}") from e


__all__ = ["ARCHS", "SHAPES", "LONG_CONTEXT_FAMILIES", "ModelConfig",
           "ShapeConfig", "cell_applicable", "get_arch"]
