"""mamba2-370m — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024 attn-free, vocab=50280, ssm_state=128.
d_inner = 2*d = 2048, head_dim 64 -> 32 SSD heads, 1 state group.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,          # SSD heads = d_inner / ssm_head_dim
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_chunk=256,
    tie_embeddings=True,
    norm="rmsnorm",
)
