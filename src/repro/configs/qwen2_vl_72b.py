"""qwen2-vl-72b — M-RoPE, dynamic-resolution VLM backbone [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. Vision tower is a
stub per assignment; positions arrive as (B, S, 3) t/h/w M-RoPE indices.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    mrope=True,
    rope_theta=1000000.0,
    norm="rmsnorm",
    act="silu",
    qkv_bias=True,
)
