"""``F`` — the Function namespace (paper §2.1 building block #2).

Convention (enforced by the dispatcher): positional arguments are tensors
(arrays or :class:`Variable`), keyword arguments are static configuration.
Called on plain arrays, every op is a pure jnp function (the functional plane
used by pjit); called on Variables, the op is recorded on the graph
(static/deferred) or executed immediately (dynamic), per §2.2.

Numerics policy: softmax / norms / losses accumulate in fp32 regardless of the
compute dtype — the TPU analogue of the paper's "batch normalization is in
FP-32" rule for mixed-precision training (§3.3).
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import graph as _graph


def _op(pure_fn=None, *, name: str | None = None, n_outputs: int = 1):
    def deco(fn):
        opname = name or fn.__name__

        @functools.wraps(fn)
        def wrapper(*inputs, **kwargs):
            return _graph.apply_function(opname, fn, inputs, kwargs,
                                         n_outputs=n_outputs)
        wrapper.pure = fn
        return wrapper
    if pure_fn is not None:
        return deco(pure_fn)
    return deco


# ---------------------------------------------------------------------------
# elementwise arithmetic
# ---------------------------------------------------------------------------

@_op
def add(a, b):
    return jnp.add(a, b)


@_op
def sub(a, b):
    return jnp.subtract(a, b)


@_op
def mul(a, b):
    return jnp.multiply(a, b)


@_op
def div(a, b):
    return jnp.divide(a, b)


@_op
def neg(a):
    return jnp.negative(a)


@_op
def pow(a, b):  # noqa: A001 - nnabla parity
    return jnp.power(a, b)


@_op
def exp(a):
    return jnp.exp(a)


@_op
def log(a):
    return jnp.log(a)


@_op
def sqrt(a):
    return jnp.sqrt(a)


@_op
def rsqrt(a):
    return lax.rsqrt(a)


@_op
def abs(a):  # noqa: A001
    return jnp.abs(a)


@_op
def maximum2(a, b):
    return jnp.maximum(a, b)


@_op
def minimum2(a, b):
    return jnp.minimum(a, b)


@_op
def clip_by_value(a, *, min=None, max=None):  # noqa: A002
    return jnp.clip(a, min, max)


@_op
def where(cond, a, b):
    return jnp.where(cond, a, b)


@_op
def stop_gradient(a):
    return lax.stop_gradient(a)


@_op
def cast(a, *, dtype):
    return a.astype(dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

@_op
def relu(a, *, inplace: bool = False):
    del inplace  # nnabla API parity; XLA owns buffers here
    return jnp.maximum(a, 0)


@_op
def leaky_relu(a, *, alpha: float = 0.1):
    return jnp.where(a >= 0, a, alpha * a)


@_op
def sigmoid(a):
    return jax.nn.sigmoid(a)


@_op
def tanh(a):
    return jnp.tanh(a)


@_op
def gelu(a):
    # tanh approximation — MXU-friendly, matches common LM checkpoints.
    c = math.sqrt(2.0 / math.pi)
    af = a.astype(jnp.float32)
    out = 0.5 * af * (1.0 + jnp.tanh(c * (af + 0.044715 * af**3)))
    return out.astype(a.dtype)


@_op
def silu(a):
    return a * jax.nn.sigmoid(a)


swish = silu


@_op
def softplus(a):
    return jax.nn.softplus(a)


@_op
def softmax(a, *, axis: int = -1):
    af = a.astype(jnp.float32)
    return jax.nn.softmax(af, axis=axis).astype(a.dtype)


@_op
def log_softmax(a, *, axis: int = -1):
    af = a.astype(jnp.float32)
    return jax.nn.log_softmax(af, axis=axis).astype(a.dtype)


# ---------------------------------------------------------------------------
# reductions / shape
# ---------------------------------------------------------------------------

@_op
def sum(a, *, axis=None, keepdims: bool = False):  # noqa: A001
    return jnp.sum(a, axis=axis, keepdims=keepdims)


@_op
def mean(a, *, axis=None, keepdims: bool = False):
    return jnp.mean(a, axis=axis, keepdims=keepdims)


@_op
def max(a, *, axis=None, keepdims: bool = False):  # noqa: A001
    return jnp.max(a, axis=axis, keepdims=keepdims)


@_op
def min(a, *, axis=None, keepdims: bool = False):  # noqa: A001
    return jnp.min(a, axis=axis, keepdims=keepdims)


@_op
def cumsum(a, *, axis: int = -1):
    return jnp.cumsum(a, axis=axis)


@_op
def logsumexp(a, *, axis: int = -1, keepdims: bool = False):
    return jax.scipy.special.logsumexp(
        a.astype(jnp.float32), axis=axis, keepdims=keepdims).astype(a.dtype)


@_op
def reshape(a, *, shape):
    return jnp.reshape(a, shape)


@_op
def transpose(a, *, axes=None):
    return jnp.transpose(a, axes)


@_op
def broadcast_to(a, *, shape):
    return jnp.broadcast_to(a, shape)


@_op
def concatenate(*xs, axis: int = 0):
    return jnp.concatenate(xs, axis=axis)


@_op
def slice(a, *, start, stop, step=None):  # noqa: A001
    import builtins
    idx = tuple(builtins.slice(s, e, st) for s, e, st in
                zip(start, stop, step or [1] * len(start)))
    return a[idx]


@_op
def pad(a, *, pad_width, value: float = 0.0):
    return jnp.pad(a, pad_width, constant_values=value)


@_op
def squeeze(a, *, axis=None):
    return jnp.squeeze(a, axis=axis)


@_op
def expand_dims(a, *, axis: int):
    return jnp.expand_dims(a, axis)


@_op
def one_hot(a, *, num_classes: int, dtype=jnp.float32):
    return jax.nn.one_hot(a, num_classes, dtype=dtype)


@_op
def gather(table, idx, *, axis: int = 0):
    return jnp.take(table, idx, axis=axis)


@_op(n_outputs=2)
def top_k(a, *, k: int):
    return lax.top_k(a, k)


@_op
def argmax(a, *, axis: int = -1):
    return jnp.argmax(a, axis=axis)


# ---------------------------------------------------------------------------
# linear algebra
# ---------------------------------------------------------------------------

@_op
def matmul(a, b):
    return jnp.matmul(a, b)


@_op
def batch_matmul(a, b, *, transpose_a: bool = False, transpose_b: bool = False):
    if transpose_a:
        a = jnp.swapaxes(a, -1, -2)
    if transpose_b:
        b = jnp.swapaxes(b, -1, -2)
    return jnp.matmul(a, b)


@_op
def einsum(*operands, equation: str, precision=None):
    return jnp.einsum(equation, *operands, precision=precision)


def dot(a, b, preferred_element_type=None):
    """Pure helper (not taped): MXU matmul with explicit accumulation dtype."""
    return jnp.matmul(a, b, preferred_element_type=preferred_element_type)


# ---------------------------------------------------------------------------
# normalization (fp32 accumulation, paper §3.3 rule)
# ---------------------------------------------------------------------------

@_op
def layer_normalization(x, gamma, beta, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    y = y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return y.astype(x.dtype)


@_op
def rms_normalization(x, gamma, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(ms + eps) * gamma.astype(jnp.float32)
    return y.astype(x.dtype)


@_op
def batch_normalization(x, gamma, beta, mean_stat, var_stat, *,
                        eps: float = 1e-5, batch_stat: bool = True):
    """NCHW batch norm; fp32 statistics (paper: BN stays FP-32 under 'half')."""
    xf = x.astype(jnp.float32)
    axes = tuple(i for i in range(x.ndim) if i != 1)
    if batch_stat:
        mu = jnp.mean(xf, axis=axes, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=axes, keepdims=True)
    else:
        bshape = (1, x.shape[1]) + (1,) * (x.ndim - 2)
        mu = mean_stat.astype(jnp.float32).reshape(bshape)
        var = var_stat.astype(jnp.float32).reshape(bshape)
    bshape = (1, x.shape[1]) + (1,) * (x.ndim - 2)
    y = (xf - mu) * lax.rsqrt(var + eps)
    y = y * gamma.astype(jnp.float32).reshape(bshape) \
        + beta.astype(jnp.float32).reshape(bshape)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# convolution / pooling (NCHW, nnabla layout)
# ---------------------------------------------------------------------------

@_op
def convolution(x, w, b=None, *, pad=(0, 0), stride=(1, 1), dilation=(1, 1),
                group: int = 1):
    dims = ("NCHW", "OIHW", "NCHW")
    y = lax.conv_general_dilated(
        x, w, window_strides=tuple(stride),
        padding=[(p, p) for p in pad],
        rhs_dilation=tuple(dilation),
        dimension_numbers=dims,
        feature_group_count=group)
    if b is not None:
        y = y + b.astype(y.dtype).reshape((1, -1) + (1,) * (y.ndim - 2))
    return y.astype(x.dtype)


@_op
def convolution_1d(x, w, b=None, *, pad: int = 0, stride: int = 1,
                   group: int = 1):
    """(B, C, L) conv — mamba's depthwise causal conv uses group=C."""
    y = lax.conv_general_dilated(
        x, w, window_strides=(stride,), padding=[(pad, pad)],
        dimension_numbers=("NCH", "OIH", "NCH"),
        feature_group_count=group)
    if b is not None:
        y = y + b.astype(y.dtype).reshape(1, -1, 1)
    return y.astype(x.dtype)


@_op
def max_pooling(x, *, kernel=(2, 2), stride=None, pad=(0, 0)):
    stride = stride or kernel
    return lax.reduce_window(
        x, -jnp.inf, lax.max,
        window_dimensions=(1, 1) + tuple(kernel),
        window_strides=(1, 1) + tuple(stride),
        padding=((0, 0), (0, 0)) + tuple((p, p) for p in pad))


@_op
def average_pooling(x, *, kernel=(2, 2), stride=None, pad=(0, 0)):
    stride = stride or kernel
    ones = lax.reduce_window(
        jnp.ones_like(x), 0.0, lax.add,
        window_dimensions=(1, 1) + tuple(kernel),
        window_strides=(1, 1) + tuple(stride),
        padding=((0, 0), (0, 0)) + tuple((p, p) for p in pad))
    summed = lax.reduce_window(
        x, 0.0, lax.add,
        window_dimensions=(1, 1) + tuple(kernel),
        window_strides=(1, 1) + tuple(stride),
        padding=((0, 0), (0, 0)) + tuple((p, p) for p in pad))
    return summed / ones


@_op
def global_average_pooling(x):
    return jnp.mean(x, axis=tuple(range(2, x.ndim)))


# ---------------------------------------------------------------------------
# embeddings / rotary
# ---------------------------------------------------------------------------

@_op
def embed(ids, table):
    return jnp.take(table, ids, axis=0)


def rope_frequencies(head_dim: int, max_pos: int, theta: float = 10000.0,
                     dtype=jnp.float32):
    """(max_pos, head_dim//2) cos/sin tables."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    t = jnp.arange(max_pos, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)
    return jnp.cos(freqs).astype(dtype), jnp.sin(freqs).astype(dtype)


@_op
def apply_rope(x, cos, sin):
    """x: (..., seq, heads, head_dim); cos/sin: (seq, head_dim//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., :, None, :].astype(jnp.float32)
    s = sin[..., :, None, :].astype(jnp.float32)
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# dropout / noise
# ---------------------------------------------------------------------------

@_op
def dropout(x, *, p: float = 0.5, seed: int = 0):
    if p <= 0.0:
        return x
    key = jax.random.fold_in(jax.random.key(seed), 0)
    keep = jax.random.bernoulli(key, 1.0 - p, x.shape)
    return jnp.where(keep, x / (1.0 - p), jnp.zeros_like(x))


@_op
def rand(*, shape, low: float = 0.0, high: float = 1.0, seed: int = 0):
    key = jax.random.key(seed)
    return jax.random.uniform(key, shape, jnp.float32, low, high)


# ---------------------------------------------------------------------------
# losses (fp32)
# ---------------------------------------------------------------------------

@_op
def softmax_cross_entropy(logits, labels, *, axis: int = -1):
    """Integer labels; returns per-example loss (fp32)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=axis)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=axis)[..., 0]
    return -ll


@_op
def sigmoid_cross_entropy(logits, targets):
    lf = logits.astype(jnp.float32)
    tf = targets.astype(jnp.float32)
    return jnp.maximum(lf, 0) - lf * tf + jnp.log1p(jnp.exp(-jnp.abs(lf)))


@_op
def mean_squared_error(pred, target):
    d = pred.astype(jnp.float32) - target.astype(jnp.float32)
    return jnp.square(d)


# ---------------------------------------------------------------------------
# attention (XLA reference path; kernels/ provides the Pallas hot path)
# ---------------------------------------------------------------------------

@_op
def scaled_dot_product_attention(q, k, v, *, causal: bool = True,
                                 scale: float | None = None,
                                 window: int | None = None):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D). GQA via head broadcasting.

    fp32 logits+softmax (the loss-scaling-free numerics TPU bf16 affords).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    rep = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qh = q.reshape(B, Sq, Hkv, rep, D)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qh, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        # Offset so the causal frontier aligns when Sq != Sk (decode step).
        qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kpos = jnp.arange(Sk)[None, :]
        mask = qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)
