"""repro.core — the "Neural Network Libraries" programming model on JAX.

Import convention mirrors the paper::

    import repro.core as nn
    import repro.core.functions as F
    import repro.core.parametric as PF
"""

from repro.core.context import (Context, Policy, POLICIES, auto_forward,
                                context_scope, get_auto_forward,
                                get_default_context, get_extension_context,
                                set_auto_forward, set_default_context)
from repro.core.graph import CompiledGraph, FunctionNode, compile_graph
from repro.core.module import (apply, apply_shared, capture, init,
                               init_shapes, layer_stack,
                               layer_stack_with_output)
from repro.core.parameter import (Parameter, clear_parameters,
                                  filter_parameters, get_parameter,
                                  get_parameter_or_create, get_parameters,
                                  parameter_count, parameter_scope,
                                  parameter_state, read_state, create_state,
                                  seed_parameters, set_parameter)
from repro.core.variable import Variable, as_variable

__all__ = [
    "Context", "Policy", "POLICIES", "auto_forward", "context_scope",
    "get_auto_forward", "get_default_context", "get_extension_context",
    "set_auto_forward", "set_default_context",
    "CompiledGraph", "FunctionNode", "compile_graph",
    "apply", "apply_shared", "capture", "init", "init_shapes", "layer_stack",
    "layer_stack_with_output",
    "Parameter", "clear_parameters", "filter_parameters", "get_parameter",
    "get_parameter_or_create", "get_parameters", "parameter_count",
    "parameter_scope", "parameter_state", "read_state", "create_state",
    "seed_parameters", "set_parameter",
    "Variable", "as_variable",
]
