"""Graph engine: static (deferred) and dynamic (auto-forward) execution.

Paper §2.2 / Figure 1. One code path builds the graph; the execution mode is a
context flag:

* dynamic (``with nn.auto_forward():``) — every ``F.*`` call executes
  immediately, op by op, capturing a per-node VJP. Intermediates are
  inspectable the moment they are created.
* static (default) — ``F.*`` only records nodes; ``y.forward()`` runs the
  whole subgraph. The first ``forward(...)`` of a given graph JIT-compiles a
  single fused XLA program for it (and a paired VJP program for
  ``backward()``), which is where the paper's "static is fast" property comes
  from on TPU.
"""

from __future__ import annotations

import itertools
from collections.abc import Callable, Sequence
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import context as _ctx
from repro.core.variable import Variable, as_variable

_node_counter = itertools.count()


class FunctionNode:
    """One applied Function (paper's ``Function`` building block)."""

    __slots__ = ("uid", "name", "pure_fn", "kwargs", "inputs", "outputs",
                 "vjp_fn", "executed", "n_outputs")

    def __init__(self, name: str, pure_fn: Callable, kwargs: dict,
                 inputs: list[Variable], n_outputs: int):
        self.uid = next(_node_counter)
        self.name = name
        self.pure_fn = pure_fn
        self.kwargs = kwargs
        self.inputs = inputs
        self.outputs: list[Variable] = []
        self.vjp_fn = None
        self.executed = False
        self.n_outputs = n_outputs

    def call_pure(self, *arrays):
        out = self.pure_fn(*arrays, **self.kwargs)
        return tuple(out) if isinstance(out, (tuple, list)) else (out,)

    def execute(self, capture_vjp: bool = True) -> None:
        arrays = []
        for v in self.inputs:
            if v.data is None:
                raise RuntimeError(
                    f"input of {self.name} has no data; call forward() from the "
                    "output variable or set .d on the graph inputs first")
            arrays.append(v.data)
        if capture_vjp and any(v.need_grad for v in self.inputs):
            outs, self.vjp_fn = jax.vjp(
                lambda *a: self.call_pure(*a), *arrays)
        else:
            outs, self.vjp_fn = self.call_pure(*arrays), None
        for var, val in zip(self.outputs, outs):
            var.data = val
        self.executed = True

    def __repr__(self) -> str:  # pragma: no cover
        return f"FunctionNode<{self.name}#{self.uid}>"


def apply_function(name: str, pure_fn: Callable, inputs: Sequence[Any],
                   kwargs: dict, n_outputs: int = 1):
    """Dispatch an F op: pure-array fast path, or record a graph node."""
    if not any(isinstance(x, Variable) for x in inputs):
        out = pure_fn(*inputs, **kwargs)
        return out

    in_vars = [as_variable(x) for x in inputs]
    node = FunctionNode(name, pure_fn, kwargs, in_vars, n_outputs)
    need_grad = any(v.need_grad for v in in_vars)
    out_vars = [Variable(need_grad=need_grad) for _ in range(n_outputs)]
    for ov in out_vars:
        ov.parent = node
    node.outputs = out_vars

    if _ctx.get_auto_forward():
        node.execute(capture_vjp=need_grad)
    else:
        # deferred mode: static shape inference at definition time (nnabla
        # infers shapes when the graph is built, before any forward())
        avals = jax.eval_shape(
            lambda *a: node.call_pure(*a),
            *[jax.ShapeDtypeStruct(v.shape, v.dtype) for v in in_vars])
        for ov, av in zip(out_vars, avals):
            ov._shape = tuple(av.shape)
            ov._dtype = av.dtype

    return out_vars[0] if n_outputs == 1 else tuple(out_vars)


# --------------------------------------------------------------------------- #
# Traversal
# --------------------------------------------------------------------------- #

def _topo_nodes(root: Variable) -> list[FunctionNode]:
    """Ancestor FunctionNodes of ``root`` in topological (execution) order."""
    order: list[FunctionNode] = []
    seen: set[int] = set()
    stack: list[tuple[FunctionNode, bool]] = []
    if root.parent is not None:
        stack.append((root.parent, False))
    while stack:
        node, expanded = stack.pop()
        if expanded:
            order.append(node)
            continue
        if node.uid in seen:
            continue
        seen.add(node.uid)
        stack.append((node, True))
        for v in node.inputs:
            if v.parent is not None and v.parent.uid not in seen:
                stack.append((v.parent, False))
    return order


def _graph_leaves(nodes: list[FunctionNode]) -> list[Variable]:
    produced = {id(ov) for n in nodes for ov in n.outputs}
    leaves: list[Variable] = []
    seen: set[int] = set()
    for n in nodes:
        for v in n.inputs:
            if id(v) not in produced and id(v) not in seen:
                seen.add(id(v))
                leaves.append(v)
    return leaves


# --------------------------------------------------------------------------- #
# Static-plane compile cache
# --------------------------------------------------------------------------- #

class CompiledGraph:
    """Whole-graph XLA program + its VJP, built once per graph structure."""

    def __init__(self, root: Variable):
        self.nodes = _topo_nodes(root)
        self.leaves = _graph_leaves(self.nodes)
        self.root = root
        node_index = {n.uid: n for n in self.nodes}
        leaf_pos = {id(v): i for i, v in enumerate(self.leaves)}

        def pure(leaf_vals):
            env: dict[int, Any] = {
                id(v): leaf_vals[i] for v, i in
                zip(self.leaves, range(len(self.leaves)))}
            for n in self.nodes:
                args = [env[id(v)] for v in n.inputs]
                outs = n.call_pure(*args)
                for ov, val in zip(n.outputs, outs):
                    env[id(ov)] = val
            return env[id(root)]

        self._pure = pure
        self._fwd = jax.jit(pure)
        self._vjp = jax.jit(
            lambda leaf_vals, ct: jax.vjp(pure, leaf_vals)[1](ct)[0])
        self.leaf_pos = leaf_pos

    def signature(self) -> tuple:
        return tuple((n.uid, n.name) for n in self.nodes)

    def forward(self) -> None:
        vals = [v.data for v in self.leaves]
        self.root.data = self._fwd(vals)

    def backward(self, seed) -> None:
        vals = [v.data for v in self.leaves]
        ct = jnp.broadcast_to(jnp.asarray(seed, self.root.dtype),
                              self.root.shape)
        grads = self._vjp(vals, ct)
        for v, g in zip(self.leaves, grads):
            if v.need_grad:
                v.grad = g


_compiled_cache: dict[tuple, CompiledGraph] = {}


# --------------------------------------------------------------------------- #
# forward / backward entry points
# --------------------------------------------------------------------------- #

def forward(root: Variable, clear_no_need_grad: bool = False) -> None:
    """Re-execute every ancestor (nnabla semantics: forward() always runs —
    leaf .d assignments take effect on the next forward)."""
    del clear_no_need_grad  # buffer reuse is XLA's job on this runtime
    if root.parent is None:
        if root.data is None:
            raise RuntimeError("forward() on a leaf Variable with no data")
        return
    for node in _topo_nodes(root):
        node.execute(capture_vjp=any(v.need_grad for v in node.inputs))


def backward(root: Variable, seed_grad: Any = 1.0,
             clear_buffer: bool = False) -> None:
    """Reverse-mode sweep. ``seed_grad`` is the loss scale (paper Listing 6)."""
    if root.parent is None:
        return
    nodes = _topo_nodes(root)
    # Ensure forward data exists (static mode may not have run yet).
    if any(not n.executed for n in nodes):
        forward(root)
    # (Re)capture VJPs for nodes executed without them.
    for n in nodes:
        if n.vjp_fn is None and any(v.need_grad for v in n.inputs):
            n.execute(capture_vjp=True)

    cotangents: dict[int, jax.Array] = {
        id(root): jnp.broadcast_to(
            jnp.asarray(seed_grad, root.dtype), root.shape)}

    for node in reversed(nodes):
        outs_ct = []
        has_ct = False
        for ov in node.outputs:
            ct = cotangents.get(id(ov))
            if ct is None:
                ct = jnp.zeros(ov.shape, ov.dtype)
            else:
                has_ct = True
            outs_ct.append(ct)
        if not has_ct or node.vjp_fn is None:
            continue
        in_cts = node.vjp_fn(tuple(outs_ct))
        for iv, ct in zip(node.inputs, in_cts):
            if not iv.need_grad:
                continue
            prev = cotangents.get(id(iv))
            cotangents[id(iv)] = ct if prev is None else prev + ct
        if clear_buffer:
            node.vjp_fn = None
            for ov in node.outputs:
                if not ov.persistent and ov is not root:
                    ov.data = None
            node.executed = False

    # Deposit gradients on leaves (and persistent intermediates), once per
    # unique Variable even if it feeds a node through several slots.
    produced = {id(ov) for n in nodes for ov in n.outputs}
    deposited: set[int] = set()
    for n in nodes:
        for v in n.inputs:
            if (v.need_grad and id(v) in cotangents
                    and id(v) not in produced and id(v) not in deposited):
                deposited.add(id(v))
                g = cotangents[id(v)]
                v.grad = g if v.grad is None else v.grad + g
    for n in nodes:
        for ov in n.outputs:
            if ov.persistent and ov.need_grad and id(ov) in cotangents:
                ov.grad = cotangents[id(ov)]


def compile_graph(root: Variable) -> CompiledGraph:
    """Build (or fetch) the fused XLA program for a static graph."""
    probe = CompiledGraph(root)
    sig = probe.signature()
    cached = _compiled_cache.get(sig)
    if cached is None:
        _compiled_cache[sig] = probe
        return probe
    return cached
