"""Functional plane: ``init`` / ``apply`` / ``layer_stack``.

Bridges the nnabla-style scoped ``PF.*`` definitions to the pure
``params -> outputs`` functions pjit needs. The same model code runs on both
planes; this module only manages registry frames.

``layer_stack`` is the scale workhorse: parameters of N identical blocks are
stacked on a leading layer axis and the block is applied with ``lax.scan``,
keeping HLO size O(1) in depth (62–88-layer configs must compile for a
512-way SPMD mesh) and giving remat a natural per-layer boundary.
"""

from __future__ import annotations

from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import parameter as P

Params = dict[str, Any]

REMAT_POLICIES = {
    "none": None,
    # recompute everything in backward (max memory saving)
    "full": jax.checkpoint_policies.nothing_saveable,
    # keep matmul outputs, recompute the cheap elementwise work
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def init(fn: Callable, rng: jax.Array, *inputs, **kwargs) -> Params:
    """Run ``fn`` in create mode; return the flat param dict it registered."""
    store: Params = {}
    with P.parameter_state(P.ParameterState("create", store, rng)):
        fn(*inputs, **kwargs)
    return store


def init_shapes(fn: Callable, rng: jax.Array, *input_structs,
                **kwargs) -> Params:
    """Shape-only init (no FLOPs, no allocation) — used by the dry-run."""
    def _go(rng_, inputs_):
        return init(fn, rng_, *inputs_, **kwargs)
    return jax.eval_shape(_go, rng, tuple(input_structs))


def apply(fn: Callable, params: Params, *inputs, **kwargs):
    """Run ``fn`` in read mode against an immutable param pytree."""
    with P.parameter_state(P.read_state(params)):
        return fn(*inputs, **kwargs)


def capture(name: str, build_fn: Callable, *args, **kwargs) -> Params:
    """Create-or-fetch a *shared* submodule's params as a plain dict.

    In create mode runs ``build_fn`` (PF calls on representative inputs)
    under scope ``name`` and registers the result; in read mode slices the
    prefix back out. The returned dict (relative paths) can be closed over
    inside ``lax.scan``/``lax.cond`` bodies and re-applied with
    ``with parameter_state(read_state(d)):`` — zamba2's shared attention
    block is the canonical user.
    """
    frame = P._current_frame()
    if frame is None:
        raise RuntimeError("capture requires a functional frame")
    prefix = P.full_path(name) + P.SEP
    if frame.mode == "create":
        store: Params = {}
        sub_rng = jax.random.fold_in(frame.rng, abs(hash(name)) % (1 << 30))
        with P.parameter_state(P.ParameterState("create", store, sub_rng)):
            build_fn(*args, **kwargs)
        for k, v in store.items():
            frame.store[prefix + k] = v
        return store
    sub = {k[len(prefix):]: v for k, v in frame.store.items()
           if k.startswith(prefix)}
    if not sub:
        raise KeyError(f"no shared parameters under {prefix!r}")
    return sub


def apply_shared(shared: Params, fn: Callable, *args, **kwargs):
    """Apply ``fn`` reading params from a captured shared dict."""
    with P.parameter_state(P.read_state(shared)):
        return fn(*args, **kwargs)


def _build_or_fetch_stack(name: str, n_layers: int, body: Callable, carry,
                          xs: Any) -> Params:
    """Create (vmap over per-layer RNGs) or slice out the stacked params."""
    frame = P._current_frame()
    if frame is None:
        raise RuntimeError("layer_stack requires a functional frame "
                           "(wrap the model in module.init/apply)")
    prefix = P.full_path(name) + P.SEP

    if frame.mode == "create":
        keys = jax.random.split(frame.rng, n_layers)
        xs0 = jax.tree.map(lambda a: a[0], xs) if xs is not None else None

        def one_init(key):
            store: Params = {}
            with P.parameter_state(P.ParameterState("create", store, key)):
                if xs is None:
                    body(carry, jnp.zeros((), jnp.int32))
                else:
                    body(carry, jnp.zeros((), jnp.int32), xs0)
            return store

        stacked = jax.vmap(one_init)(keys)
        for k, v in stacked.items():
            frame.store[prefix + k] = v
        return stacked

    stacked = {k[len(prefix):]: v for k, v in frame.store.items()
               if k.startswith(prefix)}
    if not stacked:
        raise KeyError(f"no stacked parameters under {prefix!r}")
    return stacked


def layer_stack(name: str, n_layers: int, body: Callable, carry, *,
                xs: Any = None, remat: str = "none", unroll: int = 1):
    """Apply ``body(carry, layer_idx[, xs_slice]) -> carry`` N times.

    Parameters created inside ``body`` are stacked on a leading layer axis
    under ``<scope>/<name>/...``; optional ``xs`` pytrees (leading axis
    n_layers) are scanned alongside (per-layer constants, e.g. rope phase).
    """
    stacked = _build_or_fetch_stack(name, n_layers, body, carry, xs)
    idxs = jnp.arange(n_layers)

    if xs is None:
        def step(c, scanned):
            layer_params, idx = scanned
            with P.parameter_state(P.read_state(layer_params)):
                return body(c, idx), None
        scan_xs = (stacked, idxs)
    else:
        def step(c, scanned):
            layer_params, idx, x = scanned
            with P.parameter_state(P.read_state(layer_params)):
                return body(c, idx, x), None
        scan_xs = (stacked, idxs, xs)

    if remat != "none":
        step = jax.checkpoint(step, policy=REMAT_POLICIES[remat],
                              prevent_cse=False)
    out, _ = lax.scan(step, carry, scan_xs, unroll=unroll)
    return out


def layer_stack_with_output(name: str, n_layers: int, body: Callable, carry,
                            *, xs: Any = None, remat: str = "none",
                            unroll: int | bool = 1):
    """Like :func:`layer_stack` but ``body`` returns ``(carry, y)``; the ys
    are stacked along a leading layer axis (e.g. per-layer KV-cache updates).
    """
    stacked = _build_or_fetch_stack(
        name, n_layers,
        (lambda c, i, x=None: (body(c, i) if x is None else body(c, i, x))[0]),
        carry, xs)

    if xs is None:
        def step(c, scanned):
            layer_params, idx = scanned
            with P.parameter_state(P.read_state(layer_params)):
                return body(c, idx)
        if remat != "none":
            step = jax.checkpoint(step, policy=REMAT_POLICIES[remat],
                                  prevent_cse=False)
        return lax.scan(step, carry, (stacked, jnp.arange(n_layers)),
                        unroll=unroll)

    def step_xs(c, scanned):
        layer_params, idx, x = scanned
        with P.parameter_state(P.read_state(layer_params)):
            return body(c, idx, x)
    if remat != "none":
        step_xs = jax.checkpoint(step_xs, policy=REMAT_POLICIES[remat],
                                 prevent_cse=False)
    return lax.scan(step_xs, carry, (stacked, jnp.arange(n_layers), xs),
                    unroll=unroll)
