"""Execution context — the nnabla ``extension context`` adapted to JAX/TPU.

The paper (§2.3, Listing 2) switches backends with a single line::

    nn.set_default_context(get_extension_context('cudnn'))

Here the same one-liner selects the XLA backend, the numeric policy
(paper §3.3 ``type_config``) and — TPU-specific — whether perf-critical ops
lower to Pallas kernels or plain XLA:

    import repro.core as nn
    nn.set_default_context(nn.get_extension_context("tpu", type_config="bf16"))
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Literal

import jax.numpy as jnp

Backend = Literal["cpu", "tpu", "gpu"]
KernelMode = Literal["xla", "xla_chunked", "pallas", "pallas_interpret"]
# runtime twin of KernelMode for call sites that receive the mode as a
# string (CLI flags, env vars): anything outside this set would silently
# take the compiled-Pallas dispatch branch
KERNEL_MODES: tuple[str, ...] = ("xla", "xla_chunked", "pallas",
                                 "pallas_interpret")


@dataclasses.dataclass(frozen=True)
class Policy:
    """Mixed-precision dtype policy (paper §3.3).

    ``param_dtype``   — storage dtype of trainable parameters.
    ``compute_dtype`` — dtype activations/matmuls run in.
    ``output_dtype``  — dtype losses/logits are produced in (norms, softmax and
    reductions always accumulate in fp32, mirroring the paper's fp32 batch-norm
    inside fp16 networks).
    """

    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    output_dtype: jnp.dtype = jnp.float32

    @property
    def needs_loss_scaling(self) -> bool:
        # fp16 has a 5-bit exponent -> gradients underflow without scaling.
        # bf16 shares fp32's exponent range -> no scaling required (TPU default).
        return self.compute_dtype == jnp.float16


POLICIES: dict[str, Policy] = {
    "float": Policy(),
    "fp32": Policy(),
    # TPU-native mixed precision: bf16 compute/storage-of-activations,
    # fp32 master params held by the solver.
    "bf16": Policy(jnp.float32, jnp.bfloat16, jnp.bfloat16),
    # Paper-faithful mixed precision (V100 TensorCore style): fp16 storage +
    # compute, fp32 master copy, loss scaling REQUIRED.
    "half": Policy(jnp.float16, jnp.float16, jnp.float16),
    # Fully-cast variant used by some serving configs.
    "pure_bf16": Policy(jnp.bfloat16, jnp.bfloat16, jnp.bfloat16),
}


@dataclasses.dataclass(frozen=True)
class Context:
    backend: Backend = "cpu"
    type_config: str = "float"
    kernels: KernelMode = "xla"
    # device_memory budget used by compile-time checks (bytes; v5e HBM default).
    device_memory: int = 16 * 2**30

    @property
    def policy(self) -> Policy:
        return POLICIES[self.type_config]


class _ContextState(threading.local):
    def __init__(self) -> None:
        self.ctx = Context()
        # auto_forward=True  -> dynamic (define-by-run) graph, paper §2.2 right
        # auto_forward=False -> static (deferred) graph, paper §2.2 left
        self.auto_forward = False


_state = _ContextState()


def get_extension_context(backend: Backend = "cpu", *, type_config: str = "float",
                          kernels: KernelMode = "xla") -> Context:
    if type_config not in POLICIES:
        raise ValueError(
            f"unknown type_config {type_config!r}; one of {sorted(POLICIES)}")
    return Context(backend=backend, type_config=type_config, kernels=kernels)


def set_default_context(ctx: Context) -> None:
    _state.ctx = ctx


def get_default_context() -> Context:
    return _state.ctx


class context_scope:
    """Temporarily override the default context (used by tests/benchmarks)."""

    def __init__(self, ctx: Context):
        self._ctx = ctx
        self._prev: Context | None = None

    def __enter__(self) -> Context:
        self._prev = _state.ctx
        _state.ctx = self._ctx
        return self._ctx

    def __exit__(self, *exc) -> None:
        assert self._prev is not None
        _state.ctx = self._prev


def set_auto_forward(flag: bool) -> None:
    _state.auto_forward = flag


def get_auto_forward() -> bool:
    return _state.auto_forward


class auto_forward:
    """``with nn.auto_forward():`` — switch to the dynamic graph (paper Fig. 1)."""

    def __init__(self, flag: bool = True):
        self._flag = flag
        self._prev: bool | None = None

    def __enter__(self) -> None:
        self._prev = _state.auto_forward
        _state.auto_forward = self._flag

    def __exit__(self, *exc) -> None:
        assert self._prev is not None
        _state.auto_forward = self._prev
