"""``PF`` — Parametric Functions (paper §2.1 building block #3).

Functions with trainable parameters, auto-registered into the scoped global
registry — no pre-declared layers, code executes linearly (paper Listing 4)::

    h = PF.convolution(x, 16, (5, 5), name="conv1")
    h = F.max_pooling(h, kernel=(2, 2))
    ...

Every PF casts its parameters from storage dtype (``Policy.param_dtype``) to
compute dtype at use — that single cast point is the whole mixed-precision
forward story (paper §3.3: storage fp16/bf16, compute on the MXU, masters in
the solver).
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import context as _ctx
from repro.core import functions as F
from repro.core import initializer as I
from repro.core.parameter import (get_parameter_or_create, parameter_scope)
from repro.core.variable import Variable


def _compute_cast(p):
    policy = _ctx.get_default_context().policy
    if isinstance(p, Variable):
        if p.dtype != policy.compute_dtype:
            return F.cast(p, dtype=policy.compute_dtype)
        return p
    return p.astype(policy.compute_dtype) if p.dtype != policy.compute_dtype else p


@contextlib.contextmanager
def _maybe_scope(name: str | None, default: str):
    with parameter_scope(name if name is not None else default):
        yield


def affine(x, n_outmaps: int, *, base_axis: int = 1, name: str | None = None,
           w_init=None, b_init=None, with_bias: bool = True):
    """y = flatten(x) @ W + b over trailing dims from ``base_axis`` on."""
    shape = tuple(x.shape)
    n_in = int(np.prod(shape[base_axis:]))
    with _maybe_scope(name, "affine"):
        w = get_parameter_or_create("W", (n_in, n_outmaps),
                                    w_init or I.uniform_fanin())
        b = get_parameter_or_create("b", (n_outmaps,),
                                    b_init or I.zeros()) if with_bias else None
    w = _compute_cast(w)
    h = F.reshape(x, shape=shape[:base_axis] + (n_in,))
    y = F.matmul(h, w)
    if b is not None:
        y = F.add(y, _compute_cast(b))
    return y


def dense(x, features: int, *, name: str | None = None, use_bias: bool = False,
          w_init=None, b_init=None):
    """Last-axis dense — the transformer workhorse (keeps leading dims)."""
    n_in = int(x.shape[-1])
    with _maybe_scope(name, "dense"):
        w = get_parameter_or_create("kernel", (n_in, features),
                                    w_init or I.lecun_normal())
        b = get_parameter_or_create("bias", (features,),
                                    b_init or I.zeros()) if use_bias else None
    y = F.matmul(x, _compute_cast(w))
    if b is not None:
        y = F.add(y, _compute_cast(b))
    return y


def convolution(x, outmaps: int, kernel, *, pad=(0, 0), stride=(1, 1),
                dilation=(1, 1), group: int = 1, name: str | None = None,
                w_init=None, b_init=None, with_bias: bool = True):
    inmaps = int(x.shape[1])
    kshape = (outmaps, inmaps // group) + tuple(kernel)
    with _maybe_scope(name, "conv"):
        w = get_parameter_or_create("W", kshape, w_init or I.he_normal())
        b = get_parameter_or_create("b", (outmaps,),
                                    b_init or I.zeros()) if with_bias else None
    return F.convolution(x, _compute_cast(w),
                         _compute_cast(b) if b is not None else None,
                         pad=tuple(pad), stride=tuple(stride),
                         dilation=tuple(dilation), group=group)


def convolution_1d(x, outmaps: int, kernel: int, *, pad: int = 0,
                   group: int = 1, name: str | None = None, w_init=None,
                   with_bias: bool = True, b_init=None):
    inmaps = int(x.shape[1])
    kshape = (outmaps, inmaps // group, kernel)
    with _maybe_scope(name, "conv1d"):
        w = get_parameter_or_create("W", kshape, w_init or I.he_normal())
        b = get_parameter_or_create("b", (outmaps,),
                                    b_init or I.zeros()) if with_bias else None
    return F.convolution_1d(x, _compute_cast(w),
                            _compute_cast(b) if b is not None else None,
                            pad=pad, group=group)


def embed(ids, n_inputs: int, n_features: int, *, name: str | None = None,
          w_init=None):
    with _maybe_scope(name, "embed"):
        table = get_parameter_or_create("W", (n_inputs, n_features),
                                        w_init or I.normal(0.02))
    return F.embed(ids, _compute_cast(table))


def layer_normalization(x, *, name: str | None = None, eps: float = 1e-5):
    dim = int(x.shape[-1])
    with _maybe_scope(name, "ln"):
        gamma = get_parameter_or_create("gamma", (dim,), I.ones(),
                                        dtype=jnp.float32)
        beta = get_parameter_or_create("beta", (dim,), I.zeros(),
                                       dtype=jnp.float32)
    return F.layer_normalization(x, gamma, beta, eps=eps)


def rms_norm(x, *, name: str | None = None, eps: float = 1e-6):
    dim = int(x.shape[-1])
    with _maybe_scope(name, "rmsnorm"):
        gamma = get_parameter_or_create("gamma", (dim,), I.ones(),
                                        dtype=jnp.float32)
    return F.rms_normalization(x, gamma, eps=eps)


def batch_normalization(x, *, name: str | None = None, batch_stat: bool = True,
                        eps: float = 1e-5):
    c = int(x.shape[1])
    with _maybe_scope(name, "bn"):
        gamma = get_parameter_or_create("gamma", (c,), I.ones(),
                                        dtype=jnp.float32)
        beta = get_parameter_or_create("beta", (c,), I.zeros(),
                                       dtype=jnp.float32)
        mean = get_parameter_or_create("mean", (c,), I.zeros(),
                                       need_grad=False, dtype=jnp.float32)
        var = get_parameter_or_create("var", (c,), I.ones(),
                                      need_grad=False, dtype=jnp.float32)
    return F.batch_normalization(x, gamma, beta, mean, var, eps=eps,
                                 batch_stat=batch_stat)
