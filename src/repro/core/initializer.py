"""Weight initializers (nnabla ``nnabla.initializer`` equivalents)."""

from __future__ import annotations

import math
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Initializer = Callable[[jax.Array, tuple[int, ...], Any], jax.Array]


def constant(value: float = 0.0) -> Initializer:
    def f(rng, shape, dtype):
        del rng
        return jnp.full(shape, value, dtype=dtype)
    return f


def zeros() -> Initializer:
    return constant(0.0)


def ones() -> Initializer:
    return constant(1.0)


def normal(sigma: float = 1.0) -> Initializer:
    def f(rng, shape, dtype):
        return (sigma * jax.random.normal(rng, shape, jnp.float32)).astype(dtype)
    return f


def uniform(lim: float = 1.0) -> Initializer:
    def f(rng, shape, dtype):
        return jax.random.uniform(
            rng, shape, jnp.float32, -lim, lim).astype(dtype)
    return f


def _fans(shape: tuple[int, ...]) -> tuple[int, int]:
    if len(shape) == 0:
        return 1, 1
    if len(shape) == 1:
        return shape[0], shape[0]
    if len(shape) == 2:
        return shape[0], shape[1]
    # conv kernels: (..., in, out) receptive field = prod of leading dims
    receptive = int(np.prod(shape[:-2]))
    return shape[-2] * receptive, shape[-1] * receptive


def uniform_fanin() -> Initializer:
    """nnabla's default: U(-1/sqrt(fan_in), 1/sqrt(fan_in))."""
    def f(rng, shape, dtype):
        fan_in, _ = _fans(shape)
        lim = 1.0 / math.sqrt(max(1, fan_in))
        return jax.random.uniform(
            rng, shape, jnp.float32, -lim, lim).astype(dtype)
    return f


def glorot_uniform() -> Initializer:
    def f(rng, shape, dtype):
        fan_in, fan_out = _fans(shape)
        lim = math.sqrt(6.0 / (fan_in + fan_out))
        return jax.random.uniform(
            rng, shape, jnp.float32, -lim, lim).astype(dtype)
    return f


def he_normal() -> Initializer:
    def f(rng, shape, dtype):
        fan_in, _ = _fans(shape)
        sigma = math.sqrt(2.0 / max(1, fan_in))
        return (sigma * jax.random.normal(rng, shape, jnp.float32)).astype(dtype)
    return f


def lecun_normal() -> Initializer:
    def f(rng, shape, dtype):
        fan_in, _ = _fans(shape)
        sigma = math.sqrt(1.0 / max(1, fan_in))
        return (sigma * jax.random.normal(rng, shape, jnp.float32)).astype(dtype)
    return f


def scaled_normal(scale: float, axis_dim: int) -> Initializer:
    """sigma = scale / sqrt(axis_dim); used for residual-output projections."""
    def f(rng, shape, dtype):
        sigma = scale / math.sqrt(max(1, axis_dim))
        return (sigma * jax.random.normal(rng, shape, jnp.float32)).astype(dtype)
    return f
