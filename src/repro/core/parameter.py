"""Scoped global parameter registry — the paper's §2.1 core UX.

nnabla registers every trainable parameter created by a parametric function in
a globally accessible dictionary, keyed by a "/"-joined scope path::

    with nn.parameter_scope("block1"):
        h = PF.affine(x, 128)        # creates "block1/affine/W", "block1/affine/b"
    nn.get_parameters()              # -> {"block1/affine/W": ..., ...}

JAX needs functional purity for jit/pjit, so the registry here has two planes:

* **eager plane** (no functional frame pushed) — ``PF.*`` materialize
  :class:`Parameter` objects (Variables!) in the process-global store, so the
  graph engine backpropagates straight into ``param.grad`` and solvers update
  ``param.data`` — exactly the paper's Listing 1 workflow.
* **functional plane** — under :func:`parameter_state` frames, ``PF.*`` either
  *create* raw arrays into a frame-local dict (init trace, deterministic
  per-path RNG) or *read* them from an immutable pytree (the dict pjit threads
  through the compiled step).
"""

from __future__ import annotations

import contextlib
import re
import threading
from collections.abc import Callable, Iterator
from typing import Any

import jax
import numpy as np

from repro.core import context as _ctx
from repro.core import initializer as init_mod
from repro.core.variable import Variable

SEP = "/"


class Parameter(Variable):
    """A named trainable Variable (the paper's ``Parameter`` kind)."""

    def __init__(self, name: str, data: jax.Array, need_grad: bool = True):
        super().__init__(need_grad=need_grad, data=data, name=name)
        self.persistent = True


class ParameterState:
    """One functional frame (mode + backing store + RNG)."""

    def __init__(self, mode: str, store: dict[str, Any], rng: jax.Array | None):
        assert mode in ("create", "read")
        self.mode = mode
        self.store = store  # flat path -> array
        self.rng = rng


class _Registry(threading.local):
    def __init__(self) -> None:
        self.scope: list[str] = []
        self.global_store: dict[str, Parameter] = {}
        self.frames: list[ParameterState] = []
        self.rng_seed = 313


_reg = _Registry()


def in_functional_frame() -> bool:
    return bool(_reg.frames)


def _current_frame() -> ParameterState | None:
    return _reg.frames[-1] if _reg.frames else None


@contextlib.contextmanager
def parameter_scope(name: str) -> Iterator[None]:
    """Paper-parity scoped naming: ``with nn.parameter_scope("conv1"): ...``"""
    if not name or not all(part for part in name.split(SEP)):
        raise ValueError(f"invalid scope name {name!r}")
    _reg.scope.append(name)
    try:
        yield
    finally:
        _reg.scope.pop()


def current_scope_path() -> str:
    return SEP.join(_reg.scope)


def full_path(name: str) -> str:
    prefix = current_scope_path()
    return f"{prefix}{SEP}{name}" if prefix else name


def _path_rng(base: jax.Array, path: str) -> jax.Array:
    # Deterministic per-path key: fold a stable FNV-1a hash of the path in.
    h = np.uint32(2166136261)
    for ch in path.encode():
        h = np.uint32((int(h) ^ ch) * 16777619 & 0xFFFFFFFF)
    return jax.random.fold_in(base, int(h))


@contextlib.contextmanager
def parameter_state(state: ParameterState) -> Iterator[ParameterState]:
    _reg.frames.append(state)
    try:
        yield state
    finally:
        _reg.frames.pop()


def create_state(store: dict[str, Any] | None = None,
                 rng: jax.Array | None = None) -> ParameterState:
    if rng is None:
        rng = jax.random.key(_reg.rng_seed)
    return ParameterState("create", {} if store is None else store, rng)


def read_state(params: dict[str, Any]) -> ParameterState:
    return ParameterState("read", params, None)


def get_parameter_or_create(
    name: str,
    shape: tuple[int, ...],
    initializer: Callable[[jax.Array, tuple[int, ...], Any], jax.Array] | None = None,
    need_grad: bool = True,
    dtype: Any | None = None,
):
    """The single entry point every ``PF.*`` uses to obtain its parameters.

    Returns a raw array in functional frames, a :class:`Parameter` (Variable)
    on the eager plane.
    """
    path = full_path(name)
    policy = _ctx.get_default_context().policy
    dtype = dtype or policy.param_dtype
    frame = _current_frame()

    if frame is not None and frame.mode == "read":
        try:
            value = frame.store[path]
        except KeyError as e:
            known = ", ".join(list(sorted(frame.store))[:8])
            raise KeyError(
                f"parameter {path!r} missing from provided params "
                f"(have: {known} ...)") from e
        got = tuple(value.shape)
        if got != tuple(shape):
            raise ValueError(
                f"parameter {path!r}: stored shape {got} != requested "
                f"{tuple(shape)}")
        return value

    if initializer is None:
        initializer = init_mod.uniform_fanin()

    if frame is not None:  # functional create
        existing = frame.store.get(path)
        if existing is not None:
            if tuple(existing.shape) != tuple(shape):
                raise ValueError(
                    f"parameter {path!r} exists with shape "
                    f"{tuple(existing.shape)}, requested {tuple(shape)}")
            return existing
        data = initializer(_path_rng(frame.rng, path), tuple(shape), dtype)
        frame.store[path] = data
        return data

    # eager plane: global Parameter registry
    existing_p = _reg.global_store.get(path)
    if existing_p is not None:
        if tuple(existing_p.shape) != tuple(shape):
            raise ValueError(
                f"parameter {path!r} exists with shape {existing_p.shape}, "
                f"requested {tuple(shape)}")
        return existing_p
    base_rng = jax.random.key(_reg.rng_seed)
    data = initializer(_path_rng(base_rng, path), tuple(shape), dtype)
    p = Parameter(path, data, need_grad=need_grad)
    _reg.global_store[path] = p
    return p


def get_parameter(name: str) -> Parameter | None:
    return _reg.global_store.get(full_path(name))


def get_parameters(grad_only: bool = True) -> dict[str, Parameter]:
    """Paper Listing 1: all trainable parameters under the current scope."""
    prefix = current_scope_path()
    out: dict[str, Parameter] = {}
    for path, p in _reg.global_store.items():
        if prefix and not path.startswith(prefix + SEP):
            continue
        if grad_only and not p.need_grad:
            continue
        out[path] = p
    return out


def set_parameter(name: str, value: jax.Array, need_grad: bool = True) -> Parameter:
    path = full_path(name)
    p = Parameter(path, value, need_grad=need_grad)
    _reg.global_store[path] = p
    return p


def clear_parameters() -> None:
    _reg.global_store.clear()


def seed_parameters(seed: int) -> None:
    _reg.rng_seed = int(seed)


def parameter_count(params: dict[str, Any] | None = None) -> int:
    if params is None:
        params = {k: p.data for k, p in _reg.global_store.items()}
    return sum(int(np.prod(tuple(v.shape))) for v in params.values())


def filter_parameters(params: dict[str, Any], pattern: str) -> dict[str, Any]:
    rx = re.compile(pattern)
    return {k: v for k, v in params.items() if rx.search(k)}
