"""``Variable`` — nnabla's data+grad tensor handle (paper §2.1, Listing 1).

A Variable owns a data array (``.d``) and a gradient array (``.g``), and
remembers the :class:`FunctionNode` that produced it so ``forward()`` /
``backward()`` can traverse the computation graph in either execution mode
(static/deferred or dynamic/auto-forward, paper §2.2).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


class Variable:
    __slots__ = ("data", "grad", "parent", "need_grad", "name", "_shape",
                 "_dtype", "persistent")

    def __init__(self, shape: tuple[int, ...] = (), need_grad: bool = False,
                 data: jax.Array | None = None, name: str = "",
                 dtype=None):
        if data is not None:
            self.data: jax.Array | None = jnp.asarray(data)
            self._shape = tuple(self.data.shape)
            self._dtype = self.data.dtype
        else:
            self.data = None
            self._shape = tuple(int(s) for s in shape)
            self._dtype = jnp.dtype(dtype) if dtype is not None \
                else jnp.float32
        self.grad: jax.Array | None = None
        self.parent = None  # FunctionNode | None
        self.need_grad = need_grad
        self.name = name
        self.persistent = False

    # -- nnabla-parity accessors ------------------------------------------------
    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.data.shape) if self.data is not None else self._shape

    @property
    def dtype(self):
        return self.data.dtype if self.data is not None else self._dtype

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def d(self) -> np.ndarray:
        """Data as numpy (paper: ``x.d = np.random.random(x.shape)``)."""
        if self.data is None:
            # Lazily materialize zeros so `x.d[...] = v` style code works.
            self.data = jnp.zeros(self._shape, self._dtype)
        return np.asarray(self.data)

    @d.setter
    def d(self, value: Any) -> None:
        arr = jnp.asarray(value)
        if self._shape and tuple(arr.shape) != self._shape:
            raise ValueError(
                f"Variable shape {self._shape} != assigned {tuple(arr.shape)}")
        self.data = arr.astype(self._dtype) if arr.dtype != self._dtype else arr

    @property
    def g(self) -> np.ndarray:
        if self.grad is None:
            self.grad = jnp.zeros(self.shape, self.dtype)
        return np.asarray(self.grad)

    @g.setter
    def g(self, value: Any) -> None:
        self.grad = jnp.asarray(value)

    # -- graph execution ---------------------------------------------------------
    def forward(self, clear_no_need_grad: bool = False) -> None:
        """Execute every not-yet-computed ancestor function (topological)."""
        from repro.core import graph
        graph.forward(self, clear_no_need_grad=clear_no_need_grad)

    def backward(self, grad: Any = 1.0, clear_buffer: bool = False) -> None:
        """Backprop from this variable.

        ``grad`` doubles as the loss scale (paper Listing 6:
        ``loss.backward(loss_scale)``).
        """
        from repro.core import graph
        graph.backward(self, seed_grad=grad, clear_buffer=clear_buffer)

    # -- operator sugar (dispatches into F so the tape sees it) ------------------
    def _f(self):
        from repro.core import functions as F
        return F

    def __add__(self, o):   return self._f().add(self, o)
    def __radd__(self, o):  return self._f().add(o, self)
    def __sub__(self, o):   return self._f().sub(self, o)
    def __rsub__(self, o):  return self._f().sub(o, self)
    def __mul__(self, o):   return self._f().mul(self, o)
    def __rmul__(self, o):  return self._f().mul(o, self)
    def __truediv__(self, o):   return self._f().div(self, o)
    def __rtruediv__(self, o):  return self._f().div(o, self)
    def __neg__(self):      return self._f().neg(self)
    def __pow__(self, o):   return self._f().pow(self, o)
    def __matmul__(self, o):    return self._f().matmul(self, o)

    def reshape(self, shape):
        return self._f().reshape(self, shape=tuple(shape))

    def sum(self, axis=None):
        return self._f().sum(self, axis=axis)

    def mean(self, axis=None):
        return self._f().mean(self, axis=axis)

    def __repr__(self) -> str:  # pragma: no cover
        tag = self.name or hex(id(self))
        state = "unset" if self.data is None else "set"
        return f"Variable({tag}, shape={self.shape}, data={state})"


def as_variable(x: Any) -> Variable:
    if isinstance(x, Variable):
        return x
    return Variable(data=jnp.asarray(x))
