import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without hardware:
``jax.jit(step).lower(...).compile()`` must succeed on the 16x16 single-pod
mesh AND the 2x16x16 multi-pod mesh for every assigned cell, and the compiled
artifact yields the roofline terms.

Cost accounting: XLA's HloCostAnalysis counts a ``while`` body ONCE, so a
scan-over-layers model under-reports flops/bytes/collectives by ~n_layers x.
We therefore compile two small *probe* variants with fully-unrolled layer
stacks (L1 and L2 layers) and extrapolate linearly:
    cost(L) = cost(L1) + (L - L1) * (cost(L2) - cost(L1)) / (L2 - L1)
which is exact for layer-homogeneous models (all of ours, with the hybrid
probed at its attn_every period). memory_analysis comes from the real
full-depth artifact.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod]
Results land in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp

import repro.core as nn
from repro.configs import ARCHS, SHAPES, cell_applicable, get_arch
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import param_spec, sharding_env
from repro.distributed.train_step import (make_prefill_step, make_serve_step,
                                          make_train_step, train_state_shapes)
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (batch_specs, decode_state_specs_sharding,
                                    make_env, train_state_shardings)
from repro.models.registry import get_model
from repro.precision.loss_scale import static_scaler
from repro.solvers import Adam

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] \
    / "benchmarks" / "results" / "dryrun"

_MESHES: dict[bool, object] = {}


def _mesh(multi_pod: bool):
    if multi_pod not in _MESHES:
        _MESHES[multi_pod] = make_production_mesh(multi_pod=multi_pod)
    return _MESHES[multi_pod]


def _param_shapes(api, shape: ShapeConfig):
    """Shape-only param init (forward trace with a tiny seq)."""
    cfg = api.cfg
    B = 2
    S = min(shape.seq_len, 64)
    if cfg.family == "moe":
        S = max(S, cfg.moe_group_size // B)
    if cfg.ssm_state:
        S = max(S, cfg.ssm_chunk)
        S = -(-S // cfg.ssm_chunk) * cfg.ssm_chunk
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    extras: dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.mrope:
        extras["positions"] = jax.ShapeDtypeStruct((B, S, 3), jnp.int32)
    if cfg.family == "audio":
        extras["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    keys = sorted(extras)

    def fn(tokens, *vals):
        return api.forward(tokens, **dict(zip(keys, vals)))

    return nn.init_shapes(fn, jax.random.key(0), tok,
                          *[extras[k] for k in keys])


def pick_microbatches(shape: ShapeConfig, mesh, d_model: int = 4096) -> int:
    """Gradient-accumulation factor so train cells fit 16 GB HBM.

    Target: <= ~64 MB per activation tensor per chip per microbatch
    (tokens_per_chip_per_micro * d_model * 2B), i.e. wider models get more
    accumulation steps.
    """
    if shape.kind != "train":
        return 1
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    target_tokens = max(2048, int(64e6 / (2 * d_model)))
    best = 1
    for m in (1, 2, 4, 8, 16, 32):
        if shape.global_batch % m or (shape.global_batch // m) % dp:
            continue
        best = m
        if (shape.global_batch // m // dp) * shape.seq_len <= target_tokens:
            break
    return best


def optimized_settings(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """The beyond-paper optimized configuration per cell (EXPERIMENTS SPerf)."""
    overrides: dict = {}
    kw: dict = {"kernels": "xla_chunked"}
    if shape.kind == "train":
        overrides["loss_chunk"] = 512
    if cfg.ssm_state:
        overrides["ssm_split_proj"] = True
    if cfg.n_experts and cfg.d_ff < cfg.d_model:
        # tiny-expert MoE (granite): dispatch one-hot flops scale with
        # capacity ~ group_size/E; smaller groups halve the overhead
        overrides["moe_group_size"] = 512
    if cfg.param_count() > 4e9 and shape.kind == "train":
        kw["fsdp"] = True   # params/grads must shard over data to be resident
    if cfg.param_count() > 30e9 and shape.kind in ("decode", "prefill"):
        # weight-sharded serving: model-axis params + 32k cache exceed HBM
        # at >30B; per-layer param all-gathers trade bound for residency
        kw["fsdp"] = True
    if cfg.name == "mamba2-370m" and shape.kind == "train":
        kw["rules_preset"] = "dp_only"   # sub-1B: TP collectives dominate
        overrides.pop("ssm_split_proj", None)
    kw["cfg_overrides"] = overrides
    return kw


def _lower(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
           axis_overrides=None, rules_preset=None, microbatches: int = 1,
           donate: bool = True, kernels: str = "xla", fsdp: bool = False,
           type_config: str | None = None):
    """Build + lower one cell's step. Returns (lowered, n_chips)."""
    env = make_env(mesh, cfg, shape, axis_overrides=axis_overrides,
                   rules_preset=rules_preset)
    api = get_model(cfg)
    if type_config is None:
        type_config = "bf16" if shape.kind == "train" else "pure_bf16"
    ctx = nn.get_extension_context("tpu", type_config=type_config,
                                   kernels=kernels)
    from jax.sharding import NamedSharding

    with nn.context_scope(ctx), sharding_env(env):
        params_shapes = _param_shapes(api, shape)
        bspecs = batch_specs(cfg, shape, env)
        from repro.launch.shardings import zero1_spec
        def pspec(k, v):
            spec = param_spec(k, tuple(v.shape))
            if fsdp:  # ZeRO-3: params themselves sharded over data
                spec = zero1_spec(spec, tuple(v.shape), mesh)
            return NamedSharding(mesh, spec)
        param_sh = {k: pspec(k, v) for k, v in params_shapes.items()}

        if shape.kind == "train":
            solver = Adam(alpha=1e-4)
            scaler = static_scaler(1.0)
            state_shapes = train_state_shapes(params_shapes, solver, scaler)
            state_sh = train_state_shardings(state_shapes, env)
            if fsdp:
                state_sh = dataclasses.replace(state_sh, params=param_sh)

            def loss(p, batch):
                return nn.apply(lambda **kw: api.loss_fn(**kw), p, **batch)

            # ZeRO-2: grad accumulator sharded like the optimizer state
            grad_sh = {k: state_sh.opt_state["slots"][k]["m"]
                       if "m" in state_sh.opt_state["slots"][k]
                       else state_sh.params[k]
                       for k in params_shapes} if microbatches > 1 else None
            step = make_train_step(loss, solver, scaler,
                                   microbatches=microbatches,
                                   grad_shardings=grad_sh)
            in_batch = api.input_specs(shape)
            batch_sh = {k: NamedSharding(mesh, bspecs[k]) for k in in_batch}
            jitted = jax.jit(step,
                             in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,) if donate else ())
            return jitted.lower(state_shapes, in_batch), mesh.size
        if shape.kind == "prefill":
            def fwd(p, batch):
                logits, _ = nn.apply(
                    lambda **kw: api.forward(last_only=True, **kw), p,
                    **{k: v for k, v in batch.items() if k != "labels"})
                return logits
            step = make_prefill_step(fwd)
            in_batch = api.input_specs(shape)
            batch_sh = {k: NamedSharding(mesh, bspecs[k]) for k in in_batch}
            jitted = jax.jit(step, in_shardings=(param_sh, batch_sh),
                             out_shardings=None)
            return jitted.lower(params_shapes, in_batch), mesh.size
        # decode
        def dec(p, tokens, state, pos, **extras):
            return nn.apply(
                lambda t, s, pp, **kw: api.decode_step(t, s, pp, **kw),
                p, tokens, state, pos, **extras)
        step = make_serve_step(dec)
        in_batch = api.input_specs(shape)
        state_sh = decode_state_specs_sharding(in_batch["state"], env)
        batch_sh = dict(
            {k: NamedSharding(mesh, bspecs[k]) for k in bspecs},
            state=state_sh)
        jitted = jax.jit(step, in_shardings=(param_sh, batch_sh),
                         out_shardings=(None, state_sh),
                         donate_argnums=(1,) if donate else ())
        return jitted.lower(params_shapes, in_batch), mesh.size


def _probe_cfg(cfg: ModelConfig, L: int) -> ModelConfig:
    kw = dict(n_layers=L, scan_unroll=True)
    if cfg.family == "audio":
        kw["n_encoder_layers"] = L
    return dataclasses.replace(cfg, **kw)


def _compile_costs(cfg, shape, mesh, **kw) -> dict:
    lowered, _ = _lower(cfg, shape, mesh, **kw)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    cost = cost or {}
    colls = roofline.parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_operand": float(colls.operand_bytes),
        "coll_wire": float(colls.wire_bytes),
        "by_kind": dict(colls.by_kind_bytes),
        "by_count": dict(colls.by_kind_count),
    }


def _lin(terms: list[tuple[float, dict]]) -> dict:
    """Linear combination of probe cost dicts."""
    keys = ("flops", "bytes", "coll_operand", "coll_wire")
    out = {k: sum(c * d[k] for c, d in terms) for k in keys}
    kinds = terms[0][1]["by_kind"]
    out["by_kind"] = {k: sum(c * d["by_kind"][k] for c, d in terms)
                      for k in kinds}
    out["by_count"] = {k: round(sum(c * d["by_count"][k] for c, d in terms), 1)
                       for k in kinds}
    return out


def _probe_estimate(cfg: ModelConfig, shape: ShapeConfig, mesh, **kw) -> dict:
    """Layer-extrapolated per-step cost (exact for layer-linear models)."""
    L = cfg.n_layers
    if cfg.family == "hybrid" and cfg.attn_every > 2:
        # 3-point probe: mamba-layer delta from (1,2), shared-attn cost from
        # the first full period; exact for the periodic structure.
        from repro.models.hybrid import n_attn_sites
        e = cfg.attn_every
        s = n_attn_sites(cfg)
        c1 = _compile_costs(_probe_cfg(cfg, 1), shape, mesh, **kw)
        c2 = _compile_costs(_probe_cfg(cfg, 2), shape, mesh, **kw)
        ce = _compile_costs(_probe_cfg(cfg, e), shape, mesh, **kw)
        return _lin([(1.0 - (L - 1) - s + s * (e - 1), c1),
                     ((L - 1) - s * (e - 1), c2),
                     (float(s), ce)])
    c1 = _compile_costs(_probe_cfg(cfg, 1), shape, mesh, **kw)
    c2 = _compile_costs(_probe_cfg(cfg, 2), shape, mesh, **kw)
    t = float(L - 1)
    return _lin([(1.0 - t, c1), (t, c2)])


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, *, multi_pod: bool,
               axis_overrides=None, rules_preset=None,
               remat: str | None = None, probes: bool = True,
               donate: bool = True, microbatches: int | None = None,
               kernels: str = "xla", cfg_overrides: dict | None = None,
               fsdp: bool = False, type_config: str | None = None) -> dict:
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    mesh = _mesh(multi_pod)
    n_chips = mesh.size
    mb = pick_microbatches(shape, mesh, cfg.d_model) if microbatches is None \
        else microbatches
    common = dict(axis_overrides=axis_overrides, rules_preset=rules_preset,
                  donate=donate, kernels=kernels, fsdp=fsdp,
                  type_config=type_config)

    # ---- full-depth artifact: the compile proof + memory analysis ----
    t0 = time.time()
    lowered, _ = _lower(cfg, shape, mesh, microbatches=mb, **common)
    t_lower = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    mem_info = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes"):
            mem_info[attr] = getattr(mem, attr, None)
    raw_cost = compiled.cost_analysis()
    if isinstance(raw_cost, (list, tuple)):
        raw_cost = raw_cost[0]
    hlo_len = len(compiled.as_text())

    # ---- probe extrapolation for true per-step costs ----
    if probes:
        est = _probe_estimate(cfg, shape, mesh, microbatches=1, **common)
    else:
        colls = roofline.parse_collectives(compiled.as_text())
        est = {"flops": float((raw_cost or {}).get("flops", 0.0)),
               "bytes": float((raw_cost or {}).get("bytes accessed", 0.0)),
               "coll_operand": float(colls.operand_bytes),
               "coll_wire": float(colls.wire_bytes),
               "by_kind": dict(colls.by_kind_bytes),
               "by_count": dict(colls.by_kind_count)}

    mem_adjust = None
    if kernels != "xla":  # Pallas kernels are the deployment path
        mesh_shape = dict(mesh.shape)
        mem_adjust = roofline.kernel_memory_adjustment(
            cfg, shape, mesh_shape, shape.kind)
    terms = roofline.roofline_terms(
        {"flops": est["flops"], "bytes accessed": est["bytes"]},
        roofline.CollectiveStats(est["by_kind"], est["by_count"],
                                 est["coll_operand"], est["coll_wire"], []),
        n_chips, mem_adjust=mem_adjust)
    if mem_adjust:
        terms["memory_adjustment"] = mem_adjust
    mf = roofline.model_flops(cfg, shape)
    terms["model_flops_total"] = mf
    terms["model_flops_per_chip"] = mf / n_chips
    if terms["flops_per_chip"]:
        terms["useful_compute_ratio"] = \
            terms["model_flops_per_chip"] / terms["flops_per_chip"]
    terms["mfu_at_bound"] = (
        terms["model_flops_per_chip"] / roofline.PEAK_FLOPS
        / terms["step_time_lower_bound_s"]
        if terms["step_time_lower_bound_s"] else 0.0)

    return {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "microbatches": mb,
        "kernels": kernels,
        "fsdp": fsdp,
        "rules_preset": rules_preset,
        "cfg_overrides": cfg_overrides or {},
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": mem_info,
        "collectives": {"by_kind_bytes": est["by_kind"],
                        "by_kind_count": est["by_count"]},
        "roofline": terms,
        "hlo_bytes": hlo_len,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path, tag: str | None = None, **kw) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    fname = f"{arch}__{shape_name}__{mesh_tag}"
    if tag:
        fname += f"__{tag}"
    out = out_dir / f"{fname}.json"
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "skipped", "reason": why}
    else:
        try:
            rec = lower_cell(cfg, shape, multi_pod=multi_pod, **kw)
            rec["status"] = "ok"
        except Exception as e:  # record failures as data, not crashes
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--kernels", default="xla")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--type-config", default=None)
    ap.add_argument("--optimized", action="store_true",
                    help="apply the per-arch optimized settings (SPerf)")
    ap.add_argument("--rules", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (int/bool/str)")
    ap.add_argument("--tag", default=None,
                    help="suffix for result filenames (hillclimb runs)")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR))
    args = ap.parse_args(argv)
    out_dir = pathlib.Path(args.out_dir)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v

    cells: list[tuple[str, str]] = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all) required")
        cells.append((args.arch, args.shape))

    failures = 0
    for a, s in cells:
        kw = dict(remat=args.remat, probes=not args.no_probes,
                  kernels=args.kernels, rules_preset=args.rules,
                  cfg_overrides=dict(overrides),
                  microbatches=args.microbatches, tag=args.tag,
                  fsdp=args.fsdp, type_config=args.type_config)
        if args.optimized:
            opt = optimized_settings(get_arch(a), SHAPES[s])
            kw["kernels"] = opt.get("kernels", kw["kernels"])
            kw["fsdp"] = kw["fsdp"] or opt.get("fsdp", False)
            kw["rules_preset"] = kw["rules_preset"] or opt.get("rules_preset")
            kw["type_config"] = kw["type_config"] or opt.get("type_config")
            merged = dict(opt.get("cfg_overrides", {}))
            merged.update(kw["cfg_overrides"])
            kw["cfg_overrides"] = merged
        rec = run_cell(a, s, args.multi_pod, out_dir, **kw)
        status = rec.get("status")
        line = f"[{status:7s}] {a:28s} {s:12s} {rec.get('mesh')}"
        if status == "ok":
            r = rec["roofline"]
            temp_gb = (rec["memory_analysis"].get("temp_size_in_bytes") or 0) \
                / 2**30
            line += (f"  compile={rec['compile_s']}s temp={temp_gb:.1f}GiB"
                     f"  bound={r['bottleneck']:10s}"
                     f"  t=({r['t_compute_s']:.4f},{r['t_memory_s']:.4f},"
                     f"{r['t_collective_s']:.4f})s"
                     f"  frac={r['roofline_fraction']:.2f}"
                     f"  useful={r.get('useful_compute_ratio', 0):.2f}")
        elif status == "error":
            line += f"  {rec['error'][:160]}"
            failures += 1
        print(line, flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
