"""Render EXPERIMENTS.md tables from dry-run result JSONs."""

from __future__ import annotations

import json
import pathlib
import sys


def load_results(dir_: pathlib.Path, mesh: str) -> list[dict]:
    out = []
    for p in sorted(dir_.glob(f"*__{mesh}.json")):
        out.append(json.loads(p.read_text()))
    return out


def fmt_bytes(n) -> str:
    if n is None:
        return "-"
    return f"{n / 2**30:.1f}"


def roofline_table(results: list[dict]) -> str:
    head = ("| arch | shape | kind | t_comp (s) | t_mem (s) | t_coll (s) | "
            "bound | bound t (s) | roofline frac | useful | MFU@bound | "
            "temp GiB |\n"
            "|---|---|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in results:
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skip | - | - | - | "
                        f"— {r['reason'][:60]}… | - | - | - | - | - |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | - | "
                        f"{r.get('error', '')[:60]} | - | - | - | - | - |")
            continue
        t = r["roofline"]
        temp = r["memory_analysis"].get("temp_size_in_bytes")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {t['t_compute_s']:.4f} | {t['t_memory_s']:.4f} "
            f"| {t['t_collective_s']:.4f} | {t['bottleneck']} "
            f"| {t['step_time_lower_bound_s']:.4f} "
            f"| {t['roofline_fraction']:.3f} "
            f"| {t.get('useful_compute_ratio', 0):.2f} "
            f"| {t.get('mfu_at_bound', 0):.3f} "
            f"| {fmt_bytes(temp)} |")
    return head + "\n".join(rows) + "\n"


def dryrun_table(results: list[dict]) -> str:
    head = ("| arch | shape | mesh | status | compile (s) | args GiB/chip | "
            "temp GiB/chip | AR/AG/RS/A2A/CP count | coll GiB/chip |\n"
            "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in results:
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skipped | - | - | - | - | - |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR {r.get('error', '')[:50]} | - | - | - | - | - |")
            continue
        m = r["memory_analysis"]
        c = r["collectives"]["by_kind_count"]
        t = r["roofline"]
        counts = "/".join(str(int(round(c.get(k, 0)))) for k in
                          ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r['compile_s']} | {fmt_bytes(m.get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(m.get('temp_size_in_bytes'))} | {counts} "
            f"| {t['collective_operand_bytes'] / 2**30:.2f} |")
    return head + "\n".join(rows) + "\n"


def main() -> int:
    base = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 else \
        pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
        "results" / "dryrun"
    single = load_results(base, "16x16")
    multi = load_results(base, "2x16x16")
    print("## Single-pod (16x16, 256 chips) roofline\n")
    print(roofline_table(single))
    print("\n## Dry-run detail (single-pod)\n")
    print(dryrun_table(single))
    print("\n## Multi-pod (2x16x16, 512 chips) dry-run\n")
    print(dryrun_table(multi))
    return 0


if __name__ == "__main__":
    sys.exit(main())
