"""Production meshes.

Single pod: 16x16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — 'pod' is the
cross-pod (DCN-connected) axis; it carries either outer data parallelism
(default) or pipeline stages (PP mode).

Serving meshes use the same two ICI axes with serving semantics
(:mod:`repro.launch.serve_shardings` owns the rule table):

* ``model`` — tensor parallelism for the decode step: attention/MLP/vocab
  weights shard Megatron-style and the paged K/V block pools shard on the
  kv-head axis, so each chip holds ``1/tp`` of the KV memory and walks only
  its local pool slice. Page tables, positions and lengths replicate (they
  are tiny int32 metadata the host scheduler mutates every step).
* ``data`` — replica parallelism across engine instances; a single engine
  runs with ``data = 1`` (continuous batching fills the batch axis, there
  is nothing to split).

Functions, not module constants — importing this module never touches jax
device state (smoke tests must keep seeing 1 device).
"""

from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            " before importing jax")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Small helper meshes for tests/benchmarks (e.g. (8,) 'data')."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before "
            "importing jax (a silent [:n] slice would build a mesh of the "
            "wrong size)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_serving_mesh(tp: int, *, data: int = 1):
    """(data, model) mesh for one tensor-parallel serving engine.

    ``tp`` chips shard the decode step and the paged KV pools; ``data``
    defaults to 1 — a serving engine is one replica, continuous batching
    (not the mesh) fills its batch axis.
    """
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    return make_host_mesh((data, tp), ("data", "model"))


def make_replica_meshes(replicas: int, tp: int = 1):
    """Carve the device set into ``replicas`` disjoint (1, tp) serving
    meshes — the realized form of the ``data`` axis for multi-replica
    serving.

    A single engine's mesh always has ``data = 1`` (continuous batching
    fills its batch axis); *replica* parallelism is R independent engines
    on disjoint device slices, each with its own params copy, KV pool and
    scheduler, fronted by :class:`repro.serving.router.Router`. Device
    ``r*tp .. (r+1)*tp - 1`` belongs to replica ``r`` — contiguous slices
    so each replica's tp shards stay ICI-adjacent on real hardware.
    Raises (never silently overlaps) when ``replicas * tp`` exceeds the
    device count.
    """
    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    n = replicas * tp
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"{replicas} replicas x tp={tp} need {n} devices, have "
            f"{len(devices)} — set XLA_FLAGS="
            "--xla_force_host_platform_device_count before importing jax, "
            "or lower --replicas/--tp")
    return [
        jax.make_mesh((1, tp), ("data", "model"),
                      devices=devices[r * tp:(r + 1) * tp])
        for r in range(replicas)
    ]
