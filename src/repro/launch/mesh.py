"""Production meshes.

Single pod: 16x16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — 'pod' is the
cross-pod (DCN-connected) axis; it carries either outer data parallelism
(default) or pipeline stages (PP mode).

Functions, not module constants — importing this module never touches jax
device state (smoke tests must keep seeing 1 device).
"""

from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            " before importing jax")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Small helper meshes for tests/benchmarks (e.g. (8,) 'data')."""
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
