"""Rule tables mapping logical axes / parameter paths to the mesh.

This file IS the parallelism policy: DP over (pod, data), TP over model
(Megatron column->row), EP over model for MoE experts, sequence sharding for
long-context cells, ZeRO-1 sharding of optimizer state over data. Hillclimb
experiments swap these tables (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import ShardingEnv, spec_for

# parameter-path regex -> logical dim names (trailing dims; leading stacked
# layer axes are auto-padded with "layers")
PARAM_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    (r"tok_emb/W$", ("vocab", "embed")),
    (r"lm_head/kernel$", ("embed", "vocab")),
    (r"dec_pos/W$", ("position", "embed")),
    (r"(_q|_k|_v)/kernel$", ("embed", "heads_merged")),
    (r"(_q|_k|_v)/bias$", ("heads_merged",)),
    (r"cross_(k|v)/kernel$", ("embed", "heads_merged")),
    (r"_o/kernel$", ("heads_merged", "embed")),
    (r"mlp_(gate|up)/kernel$", ("embed", "mlp")),
    (r"mlp_up/bias$", ("mlp",)),
    (r"mlp_down/kernel$", ("mlp", "embed")),
    (r"_router/kernel$", (None, None)),
    (r"_wi_(gate|up)$", ("expert", "embed", None)),
    (r"_wo$", ("expert", None, "embed")),
    (r"mamba_in/kernel$", ("embed", "ssm_fused")),
    (r"mamba_(z|x)/kernel$", ("embed", "ssm_inner")),
    (r"mamba_(bc|dtp)/kernel$", (None, None)),
    (r"mamba_convx/W$", ("ssm_inner", None, None)),
    (r"mamba_convbc/W$", (None, None, None)),
    (r"mamba_conv/W$", ("conv_ch", None, None)),
    (r"mamba_conv/b$", (None,)),
    (r"mamba_out/kernel$", ("ssm_inner", "embed")),
    (r"mamba_norm/gamma$", ("ssm_inner",)),
    # everything else (norms, A_log, D, dt_bias, small biases): replicate
]


RULE_PRESETS = {
    # pure data parallelism: batch over every axis, params replicated
    # (+ ZeRO-1 shards optimizer state). Right answer for <1B models where
    # TP's per-layer collectives dominate.
    "dp_only": {
        "heads": None, "heads_merged": None, "kv_heads": None, "mlp": None,
        "vocab": None, "expert": None, "ssm_inner": None, "ssm_fused": None,
        "conv_ch": None, "position": None,
    },
}


def make_axis_rules(mesh: Mesh, cfg: ModelConfig,
                    shape: ShapeConfig) -> dict[str, Any]:
    axes = set(mesh.axis_names)
    dp: Any = ("pod", "data") if "pod" in axes else "data"

    # decode KV cache layout: prefer head sharding when divisible (no
    # softmax-axis collectives); fall back to sequence sharding (flash-decode
    # style) so 0.5M-token caches still fit.
    model_size = mesh.shape["model"]
    kv_heads_shardable = cfg.n_kv_heads % model_size == 0
    rules: dict[str, Any] = {
        "batch": dp,
        "batch_kv": ("data", "model"),   # merged (batch, kv_head) attention
        "attn_seq": "model",             # seq-sharded attention (degraded heads)
        "expert_group": dp,
        "seq": None,
        "embed": None,
        "frames": None,
        "position": "model",
        "heads": "model",
        "heads_merged": "model",
        "kv_heads": "model" if kv_heads_shardable else None,
        "kv_seq": None if kv_heads_shardable else "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "ssm_inner": "model",
        "ssm_fused": "model",
        "conv_ch": "model",
        "state": None,
        "layers": None,
    }
    if shape.kind == "decode" and shape.global_batch < _axis_len(mesh, dp):
        # tiny-batch decode (long_500k): batch can't use all of DP; shard the
        # sequence/cache dim over data as well where possible.
        rules["kv_seq"] = ("data",) if kv_heads_shardable else ("data", "model")
        rules["batch"] = None
    return rules


def _axis_len(mesh: Mesh, val) -> int:
    if val is None:
        return 1
    if isinstance(val, str):
        return mesh.shape[val]
    return int(np.prod([mesh.shape[a] for a in val]))


def make_env(mesh: Mesh, cfg: ModelConfig, shape: ShapeConfig,
             *, axis_overrides: dict[str, Any] | None = None,
             rules_preset: str | None = None,
             param_overrides: list[tuple[str, tuple[str | None, ...]]] | None = None
             ) -> ShardingEnv:
    rules = make_axis_rules(mesh, cfg, shape)
    if rules_preset:
        rules.update(RULE_PRESETS[rules_preset])
        if rules_preset == "dp_only":
            # greedy: largest set of mesh axes whose product divides the
            # global batch (multi-pod: B=256 can't use all 512 chips for DP)
            sel: list[str] = []
            prod = 1
            for a in sorted(mesh.axis_names, key=lambda a: -mesh.shape[a]):
                if shape.global_batch % (prod * mesh.shape[a]) == 0:
                    sel.append(a)
                    prod *= mesh.shape[a]
            dp = tuple(sel) if sel else None
            rules["batch"] = dp
            rules["expert_group"] = dp
    if axis_overrides:
        rules.update(axis_overrides)
    param_rules = list(param_overrides or []) + PARAM_RULES
    return ShardingEnv(mesh=mesh, axis_rules=rules, param_rules=param_rules)


# --------------------------------------------------------------------------- #
# state shardings
# --------------------------------------------------------------------------- #

def train_state_shardings(state_shapes, env: ShardingEnv):
    """Shardings for the whole TrainState: params by rule table; optimizer
    state (masters + slots) additionally ZeRO-1-sharded over data."""
    from repro.distributed.sharding import param_spec, sharding_env
    from repro.distributed.train_step import TrainState
    mesh = env.mesh
    assert mesh is not None
    with sharding_env(env):
        p_sh = {k: NamedSharding(mesh, param_spec(k, tuple(v.shape)))
                for k, v in state_shapes.params.items()}

        def opt_leaf(param_path: str, sds) -> NamedSharding:
            pshape = tuple(state_shapes.params[param_path].shape)
            if tuple(sds.shape) == pshape:
                base = param_spec(param_path, pshape)
            else:  # factored slots (adafactor): start from replicated
                base = P()
            return NamedSharding(
                mesh, zero1_spec(base, tuple(sds.shape), mesh))

        opt = state_shapes.opt_state
        opt_sh = {
            "step": NamedSharding(mesh, P()),
            "master": {k: opt_leaf(k, v) for k, v in opt["master"].items()},
            "slots": {k: {s: opt_leaf(k, v) for s, v in d.items()}
                      for k, d in opt["slots"].items()},
        }
        scaler_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                 state_shapes.scaler_state)
        return TrainState(params=p_sh, opt_state=opt_sh,
                          scaler_state=scaler_sh,
                          step=NamedSharding(mesh, P()))


def zero1_spec(spec: P, shape: tuple[int, ...], mesh: Mesh,
               axis: Any = "data") -> P:
    """ZeRO-1: extend a param spec by sharding its largest unsharded dim over
    the data axis (optimizer state + master weights only)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    axis_size = _axis_len(mesh, axis)
    best, best_dim = -1, -1
    for i, (p, s) in enumerate(zip(parts, shape)):
        if p is None and s % axis_size == 0 and s > best:
            best, best_dim = s, i
    if best_dim >= 0:
        parts[best_dim] = axis
    return P(*parts)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                env: ShardingEnv) -> dict[str, P]:
    """PartitionSpecs for the input batch dict (mirrors input_specs)."""
    from repro.distributed.sharding import sharding_env
    with sharding_env(env):
        if shape.kind in ("train", "prefill"):
            specs = {"tokens": spec_for(("batch", "seq")),
                     "labels": spec_for(("batch", "seq"))}
            if cfg.mrope:
                specs["positions"] = spec_for(("batch", "seq", None))
            if cfg.family == "audio":
                specs["frames"] = spec_for(("batch", "frames", "embed"))
            return specs
        specs = {"tokens": spec_for(("batch", None)),
                 "pos": P()}
        if cfg.mrope:
            specs["positions"] = spec_for(("batch", None, None))
        return specs


def decode_state_specs_sharding(state_specs: Any, env: ShardingEnv) -> Any:
    """Shardings for the decode state pytree by dim semantics.

    KV caches are (layers, batch, seq, kv_heads, head_dim); SSM state is
    (layers, batch, H, P, N); conv buffers (layers, batch, k, ch).
    """
    from repro.distributed.sharding import sharding_env, tree_shardings
    mesh = env.mesh
    assert mesh is not None

    def leaf(path: str, sds) -> NamedSharding:
        shape = tuple(sds.shape)
        last = path.split("/")[-1]
        if last in ("k", "v"):
            names = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        elif last == "h":
            names = ("layers", "batch", "heads", None, "state")
        elif last == "conv":
            names = ("layers", "batch", None, "conv_ch")
        else:
            names = (None,) * len(shape)
        names = tuple(names[:len(shape)])
        names = names + (None,) * (len(shape) - len(names))
        return NamedSharding(mesh, spec_for(names, shape))

    with sharding_env(env):
        return tree_shardings(state_specs, leaf)
