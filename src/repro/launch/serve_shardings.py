"""Serving-side sharding policy: one engine spans a (data, model) mesh.

The training rule tables (:mod:`repro.launch.shardings`) are tuned for
large-batch pjit cells; serving has a different shape — tiny batches, a
latency-bound mixed prefill/decode step, and state that is a *block pool*
with no batch axis at all. This module owns the serving layout:

* **Params** shard by the shared ``PARAM_RULES`` path table (Megatron
  column->row attention/MLP, vocab-sharded embedding + head, expert
  parallelism for MoE, Mamba inner projections over ``model``).
* **Paged K/V pools** ``(layers|sites, num_blocks, block_size, Hkv, hd)``
  shard on the **kv-head axis**: every device holds ``1/tp`` of the KV
  bytes of *every* block, so the host-side allocator, page tables and
  prefix cache stay completely device-agnostic (block ids mean the same
  thing on every shard). GQA models with ``Hkv < tp`` (or indivisible)
  degrade that dim to replicated — query heads still shard, attention
  stays collective-free — and the :class:`~repro.models.registry.CacheSpec`
  ``tp_note`` records the policy.
* **Recurrent state** (hybrid/SSM ``h``, conv windows) shards on its head /
  channel dim when divisible, else replicates: it is O(1) per slot, so
  replication costs bytes, not per-token bandwidth.
* **Step metadata** (tokens, page tables, positions, lengths, sampling
  knobs) replicates — a few KB of int32 the host scheduler rewrites every
  step.

The rule table degrades per-shape (see :func:`repro.distributed.sharding.
spec_for`), so one policy serves every family and every tp width; with
``tp = 1`` the engine never builds an env at all and the single-device
path is bitwise-identical to the pre-mesh engine.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingEnv
from repro.launch.shardings import PARAM_RULES


def serve_axis_rules(mesh: Mesh) -> dict[str, Any]:
    """Logical axis -> mesh axis for the serving step.

    Unlisted names replicate. ``kv_heads`` nominally shards over ``model``
    and degrades per-shape (GQA with ``Hkv % tp != 0`` replicates the KV
    pool while the query projections stay sharded over ``heads_merged``).
    """
    axes = set(mesh.axis_names)
    dp = "data" if "data" in axes else None
    return {
        # activations: batch over data (trivial at data=1), seq/embed local
        "batch": dp,
        "batch_kv": None,
        "seq": None,
        "attn_seq": None,
        "frames": None,
        "embed": None,
        "head_dim": None,
        "state": None,
        "layers": None,
        "position": None,
        "kv_seq": None,
        # tensor parallelism over the model axis
        "heads": "model",
        "heads_merged": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "expert_group": dp,
        "ssm_inner": "model",
        "ssm_fused": "model",
        "conv_ch": "model",
    }


def make_serve_env(mesh: Mesh, cfg: ModelConfig) -> ShardingEnv:
    """The engine's trace-time env: serving axis rules + the shared
    parameter path table. ``cfg`` is accepted for future per-family
    overrides; the per-shape degrade in ``spec_for`` already handles GQA
    and odd head counts."""
    del cfg
    return ShardingEnv(mesh=mesh, axis_rules=serve_axis_rules(mesh),
                       param_rules=list(PARAM_RULES))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def state_layout(state: Any) -> dict[str, str]:
    """Human/test-readable map of state leaf -> placement decision.

    ``{"kv/k": "PartitionSpec(None, None, None, 'model', None)",
    "ssm/h": "replicated", ...}`` — the engine exposes this so tests and
    operators can see exactly which leaves split ``tp``-ways and which
    replicated (and why: see ``CacheSpec.tp_note``).
    """
    out: dict[str, str] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        spec = getattr(getattr(leaf, "sharding", None), "spec", None)
        if spec is None or all(s is None for s in spec):
            out[key] = "replicated"
        else:
            out[key] = str(spec)
    return out


def per_device_state_bytes(state: Any, device=None) -> int:
    """Bytes of ``state`` resident on one device (default: device 0).

    For a kv-head-sharded pool this is ``total / tp``; replicated leaves
    count fully — exactly the number a capacity planner needs.
    """
    device = device if device is not None else jax.devices()[0]
    total = 0
    for leaf in jax.tree.leaves(state):
        for shard in leaf.addressable_shards:
            if shard.device == device:
                total += shard.data.nbytes
    return total
