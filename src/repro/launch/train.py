"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 50 --batch 8 --seq 128

Wires every subsystem: config -> model -> data pipeline -> solver + loss
scaling -> (optional) mesh + sharding rules -> compiled train step ->
checkpoint manager (atomic, async, auto-resume) -> straggler monitor.
``--devices N`` re-execs with N host devices and runs data-parallel via the
same rule tables as the production mesh.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

import numpy as np


def _maybe_reexec_with_devices(argv) -> None:
    try:
        idx = argv.index("--devices")
        n = int(argv[idx + 1])
    except ValueError:
        return
    flag = f"--xla_force_host_platform_device_count={n}"
    if flag not in os.environ.get("XLA_FLAGS", ""):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
        os.execve(sys.executable,
                  [sys.executable, "-m", "repro.launch.train"] + argv, env)


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    _maybe_reexec_with_devices(argv)

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    import repro.core as nn
    from repro.configs import SHAPES, get_arch
    from repro.configs.base import ShapeConfig
    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import SyntheticLMPipeline, as_global_array
    from repro.distributed.resilience import StragglerMonitor
    from repro.distributed.sharding import param_spec, sharding_env
    from repro.distributed.train_step import (init_train_state,
                                              make_train_step)
    from repro.launch.mesh import make_host_mesh
    from repro.launch.shardings import batch_specs, make_env
    from repro.models.registry import get_model
    from repro.monitor import Monitor, MonitorCSV, MonitorSeries
    from repro.precision.loss_scale import dynamic_scaler, static_scaler
    from repro.solvers import make_solver
    from repro.solvers.schedules import SCHEDULES, cosine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="constant",
                    choices=sorted(SCHEDULES))
    ap.add_argument("--warmup", type=int, default=0)
    ap.add_argument("--monitor-dir", default="")
    ap.add_argument("--solver", default="adam")
    ap.add_argument("--type-config", default="float",
                    choices=["float", "bf16", "half", "pure_bf16"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0,
                    help="host devices for data-parallel demo")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=17)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = dataclasses.replace(cfg, remat="none")
    api = get_model(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    ctx = nn.get_extension_context("cpu", type_config=args.type_config)
    nn.set_default_context(ctx)
    scaler = dynamic_scaler() if ctx.policy.needs_loss_scaling \
        else static_scaler(1.0)
    solver = make_solver(args.solver, **(
        {"alpha": args.lr} if args.solver in ("adam", "adamw")
        else {"lr": args.lr}))

    n_dev = len(jax.devices())
    mesh = make_host_mesh((n_dev, 1), ("data", "model")) if n_dev > 1 else None
    env = make_env(mesh, cfg, shape) if mesh is not None else None

    pipe = SyntheticLMPipeline(cfg, shape, seed=args.seed)

    def loss(p, batch):
        return nn.apply(lambda **kw: api.loss_fn(**kw), p, **batch)

    step_fn = make_train_step(loss, solver, scaler,
                              microbatches=args.microbatches)

    def build_state():
        sample = pipe.batch_at(0)
        params = nn.init(lambda **kw: api.loss_fn(**kw), jax.random.key(0),
                         **{k: jnp.asarray(v) for k, v in sample.items()})
        return init_train_state(params, solver, scaler)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    if env is not None:
        with sharding_env(env):
            state = build_state()
            bspecs = batch_specs(cfg, shape, env)
            batch_sh = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}
            jstep = jax.jit(step_fn, donate_argnums=(0,))
    else:
        state = build_state()
        batch_sh = None
        jstep = jax.jit(step_fn, donate_argnums=(0,))

    start = 0
    if ckpt is not None:
        restored = ckpt.restore_latest(jax.tree.map(np.asarray, state))
        if restored is not None:
            start, host_state = restored
            state = jax.tree.map(jnp.asarray, host_state)
            meta = {}
            pipe.restore({"step": start, "seed": args.seed})
            print(f"[resume] restored step {start}", flush=True)

    monitor = StragglerMonitor()
    if args.schedule == "constant":
        sched = SCHEDULES["constant"](args.lr)
    elif args.schedule == "cosine":
        sched = cosine(args.lr, args.steps, args.warmup)
    else:
        sched = SCHEDULES[args.schedule](args.lr, args.warmup or 1000)
    mon_series = mon_csv = None
    if args.monitor_dir:
        mon = Monitor(args.monitor_dir)
        mon_series = MonitorSeries("loss", mon, interval=args.log_every)
        mon_csv = MonitorCSV(mon.path / "training.csv",
                             ["loss", "lr", "grad_norm", "step_time_s"])
    losses = []
    t_total = time.time()
    for step in range(start, args.steps):
        t0 = time.time()
        solver.set_learning_rate(float(sched(step)))
        batch = pipe.batch_at(step)
        if env is not None:
            batch = as_global_array(batch, batch_sh)
        else:
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if env is not None:
            with sharding_env(env):
                state, metrics = jstep(state, batch)
        else:
            state, metrics = jstep(state, batch)
        loss_v = float(metrics["loss"])
        losses.append(loss_v)
        dt = time.time() - t0
        if mon_series is not None:
            mon_series.add(step, loss_v)
            mon_csv.add(step, loss=loss_v, lr=float(sched(step)),
                        grad_norm=float(metrics["grad_norm"]),
                        step_time_s=dt)
        verdict = monitor.observe(dt)
        if verdict.is_straggler:
            print(f"[straggler] step {step}: z={verdict.z_score:.1f} "
                  f"ewma={verdict.ewma_s:.3f}s", flush=True)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {loss_v:8.4f}  "
                  f"scale {float(metrics['loss_scale']):g}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  {dt:.3f}s",
                  flush=True)
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, state,
                            extra={"pipe": pipe.snapshot()})
    if ckpt is not None:
        ckpt.wait()
    span = time.time() - t_total
    print(f"done: {args.steps - start} steps in {span:.1f}s  "
          f"first-loss {losses[0]:.4f}  last-loss {losses[-1]:.4f}",
          flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
