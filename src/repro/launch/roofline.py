"""Roofline extraction from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Terms (per §Roofline of the assignment):
  compute   = HLO_FLOPs_per_chip / peak_FLOPs          [s]
  memory    = HLO_bytes_per_chip / HBM_bw              [s]
  collective= collective_operand_bytes_per_chip / link_bw   [s]

cost_analysis() runs on the post-SPMD per-device module, so its flops/bytes
are already per-chip. Collective bytes are parsed from the optimized HLO:
operand sizes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute ops (headline, per the assignment formula), plus a refined
ring-wire-byte model (reported alongside; used to rank hillclimb targets).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(?P<restype>.*?)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\((?P<operands>[^)]*)\)")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _bytes_of_type(type_str: str) -> int:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return int(total)


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    by_kind_bytes: dict[str, int]
    by_kind_count: dict[str, int]
    operand_bytes: int          # headline: sum of operand sizes (per chip)
    wire_bytes: float           # ring-model bytes actually on the wire/chip
    ops: list[dict[str, Any]]


def parse_collectives(hlo_text: str, max_ops_recorded: int = 200
                      ) -> CollectiveStats:
    by_bytes: dict[str, int] = {k: 0 for k in _COLL_KINDS}
    by_count: dict[str, int] = {k: 0 for k in _COLL_KINDS}
    operand_total = 0
    wire_total = 0.0
    ops: list[dict[str, Any]] = []
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # bytes counted at the -start op
        m = _OP_RE.match(line)
        if not m:
            continue
        kind = m.group("kind")
        # operand sizes: look up the operand type annotations inside the call
        opnd_bytes = _bytes_of_type(m.group("operands"))
        res_bytes = _bytes_of_type(m.group("restype"))
        if opnd_bytes == 0:
            # operands referenced by name only; fall back to result size
            opnd_bytes = res_bytes
        g = _group_size(line)
        if kind == "all-gather":
            wire = res_bytes * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            wire = opnd_bytes * (g - 1) / max(g, 1)
        elif kind == "all-reduce":
            wire = 2 * opnd_bytes * (g - 1) / max(g, 1)
        elif kind == "all-to-all":
            wire = opnd_bytes * (g - 1) / max(g, 1)
        else:  # collective-permute
            wire = opnd_bytes
        by_bytes[kind] += opnd_bytes
        by_count[kind] += 1
        operand_total += opnd_bytes
        wire_total += wire
        if len(ops) < max_ops_recorded:
            ops.append({"kind": kind, "operand_bytes": opnd_bytes,
                        "result_bytes": res_bytes, "group": g,
                        "wire_bytes": wire})
    return CollectiveStats(by_bytes, by_count, operand_total, wire_total, ops)


def kernel_memory_adjustment(cfg, shape, mesh_shape: dict,
                             kind: str) -> dict[str, float]:
    """Per-chip HBM-byte correction when the Pallas kernels are the
    deployment path (``kernels != 'xla'``).

    The XLA fallback materializes each attention block's logits/probs at
    fusion boundaries, and HloCostAnalysis charges them to HBM; the Pallas
    flash kernel holds them in VMEM (same for the SSD kernel's per-chunk
    (Q,Q) decay/score tiles). We subtract the analytically-known
    intermediate traffic and add the kernel's true HBM traffic
    (q/k/v/o streamed once; x3.7 for train = fwd + bwd re-reads + dgrads).

    Assumption documented in EXPERIMENTS.md: 3 fusion crossings per block
    intermediate (p written/read around the two MXU matmuls + mask/where).
    """
    model = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    out = {"attn_intermediate_bytes": 0.0, "attn_kernel_bytes": 0.0,
           "ssd_intermediate_bytes": 0.0, "ssd_kernel_bytes": 0.0}
    if kind == "decode":
        return out  # decode blocks are tiny; adjustment negligible
    B, S = shape.global_batch, shape.seq_len
    b_loc = max(1, B // dp)
    train_factor = 3.7 if kind == "train" else 1.0
    crossings = 3
    hd = cfg.resolved_head_dim

    has_attn = cfg.family not in ("ssm",)
    if has_attn:
        data = mesh_shape.get("data", 1)
        if cfg.n_heads % model == 0:
            hq_loc = cfg.n_heads // model
        elif (S >= 8192
              and (shape.global_batch * cfg.n_kv_heads) % (model * data) == 0):
            # merged batch x kv-head layout: fully sharded
            hq_loc = max(1, cfg.n_heads // cfg.n_kv_heads)
            b_loc = max(1, (shape.global_batch * cfg.n_kv_heads)
                        // (model * data) // cfg.n_kv_heads) or 1
            b_loc = max(1, (shape.global_batch * cfg.n_kv_heads)
                        // (model * data))
            # b_loc now counts merged rows per chip; heads per row = rep
        else:
            hq_loc = cfg.n_heads  # degraded: replicated over model
        causal_frac = 0.5
        n_attn_layers = cfg.n_layers
        if cfg.family == "hybrid" and cfg.attn_every:
            n_attn_layers = sum(1 for i in range(cfg.n_layers)
                                if (i % cfg.attn_every) == cfg.attn_every - 1)
        if cfg.family == "audio":
            # decoder self (causal, SxS) + cross (S x frames) + encoder self
            f = cfg.n_audio_frames
            tot = (S * S * causal_frac + S * f
                   + cfg.n_encoder_layers / max(1, cfg.n_layers) * f * f)
        else:
            tot = S * S * causal_frac
        inter = b_loc * hq_loc * tot * 4.0 * crossings * train_factor
        qkvo = b_loc * S * hd * (2 * cfg.n_heads // model
                                 + 2 * max(1, cfg.n_kv_heads // model)) * 2.0
        out["attn_intermediate_bytes"] = inter * n_attn_layers
        out["attn_kernel_bytes"] = qkvo * train_factor * n_attn_layers
    if cfg.family in ("ssm", "hybrid") and cfg.ssm_state:
        d_inner = cfg.ssm_expand * cfg.d_model
        h_loc = max(1, (d_inner // cfg.ssm_head_dim) // model)
        Q = cfg.ssm_chunk
        inter = b_loc * h_loc * S * Q * 4.0 * 4 * train_factor
        io = b_loc * S * h_loc * (cfg.ssm_head_dim * 2
                                  + 2 * cfg.ssm_state) * 4.0
        out["ssd_intermediate_bytes"] = inter * cfg.n_layers
        out["ssd_kernel_bytes"] = io * train_factor * cfg.n_layers
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd) with N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def roofline_terms(cost: dict[str, Any], colls: CollectiveStats,
                   n_chips: int,
                   mem_adjust: dict[str, float] | None = None
                   ) -> dict[str, float]:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory_raw = bytes_accessed / HBM_BW
    bytes_adj = bytes_accessed
    if mem_adjust:
        removed = (mem_adjust["attn_intermediate_bytes"]
                   + mem_adjust["ssd_intermediate_bytes"])
        added = (mem_adjust["attn_kernel_bytes"]
                 + mem_adjust["ssd_kernel_bytes"])
        bytes_adj = max(bytes_accessed - removed, 0.0) + added
    t_memory = bytes_adj / HBM_BW
    t_coll = colls.operand_bytes / LINK_BW
    t_coll_wire = colls.wire_bytes / LINK_BW
    terms = {
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_accessed,
        "bytes_per_chip_kernel_adjusted": bytes_adj,
        "collective_operand_bytes": float(colls.operand_bytes),
        "collective_wire_bytes": float(colls.wire_bytes),
        "t_compute_s": t_compute,
        "t_memory_raw_s": t_memory_raw,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "t_collective_wire_s": t_coll_wire,
        "n_chips": n_chips,
    }
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])
    terms["bottleneck"] = dom[0]
    bound = max(t_compute, t_memory, t_coll)
    terms["step_time_lower_bound_s"] = bound
    terms["roofline_fraction"] = t_compute / bound if bound > 0 else 0.0
    return terms
