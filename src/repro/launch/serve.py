"""Serving driver: continuous-batching decode for any assigned architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.core as nn
    from repro.configs import get_arch
    from repro.models.registry import get_model
    from repro.serving.engine import Request, ServingEngine

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.family == "audio":
        print("serve CLI drives LM-style decode; whisper needs frames — "
              "use repro.models.whisper.init_decode_state directly")
        return 2
    api = get_model(cfg)
    print(f"loading {cfg.name}: {cfg.param_count():,} params "
          f"({'smoke' if args.smoke else 'full'})", flush=True)
    S0 = max(8, cfg.ssm_chunk if cfg.ssm_state else 8)
    params = nn.init(lambda t: api.forward(t), jax.random.key(0),
                     jnp.zeros((1, S0), jnp.int32))

    engine = ServingEngine(api, params, max_batch=args.max_batch,
                           max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(2, 6))
        prompt = rng.integers(1, cfg.vocab_size, plen).tolist()
        engine.submit(Request(uid=i, prompt=prompt,
                              max_new_tokens=args.max_new))
    t0 = time.time()
    done = engine.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"  req {r.uid}: {r.prompt} -> {r.generated[:8]}...")
    print(f"{len(done)} requests, {toks} tokens, {dt:.2f}s "
          f"-> {toks / dt:.1f} tok/s", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
