"""Serving driver: continuous batching + chunked prefill for any LM arch.

Batch mode (default) drives a synthetic workload through the engine and
prints per-request metrics:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 8 --max-new 16 --prompt-len 64 --chunk 16 \
      --temperature 0.8 --top-k 40 --top-p 0.95

Server mode (``--port``) serves an actual HTTP/SSE port instead: the
asyncio frontend (:mod:`repro.serving.frontend`) streams tokens per
request over Server-Sent Events while engine worker threads run the step
loop continuously; ``--replicas R`` runs R engine replicas behind the
prefix-affinity router (:mod:`repro.serving.router`), carving the device
set into R disjoint (1, tp) meshes when the devices are there:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --port 8080 --replicas 2 --max-queue 64
  curl -N localhost:8080/generate -d '{"prompt": [3, 1, 4], "max_new_tokens": 8}'
  curl localhost:8080/health; curl localhost:8080/metrics

Flags:
  --chunk N        prompt tokens absorbed per slot per prefill step (one
                   fused call writes the KV cache / SSM state for the whole
                   chunk); 1 falls back to token-by-token absorption
  --temperature T  sampling temperature for all requests; 0 = greedy argmax
  --top-k K        keep only the K highest-probability tokens (<= 0 = off)
  --top-p P        nucleus sampling: keep the smallest token set with
                   cumulative probability >= P (>= 1 = off)
  --block-size N   paged-KV block size in tokens (families that support it;
                   pure-SSM state stays dense)
  --num-blocks N   KV pool size in blocks (0 = every slot can reach
                   max-seq); admission is gated on free blocks
  --no-paged       force the PR-1 dense per-slot cache layout
  --no-prefix-cache  disable cross-request prompt-prefix block reuse
  --host-cache-gb G  tiered KV cache: size a host-RAM spill pool to G GiB;
                   cold registered prefixes spill there under eviction
                   pressure and fetch back into HBM on a hit (0 = off)
  --host-cache-blocks N  size the host pool in blocks exactly (tests and
                   benches; overrides --host-cache-gb)
  --kv-store DIR   persist registered prefix chains to DIR at the end of a
                   batch run and warm-load them (into the host tier) at
                   startup — digest-keyed, CRC'd, layout-checked; a stale
                   or corrupt store logs a warning and serves cold
  --kernels MODE   kernel mode for the jitted step: xla (default; gather-
                   then-dense paged references), xla_chunked, pallas (Pallas
                   paged-attention page-table walk — real TPUs only), or
                   pallas_interpret (same kernels on the CPU interpreter).
                   Defaults to $REPRO_KERNELS when set.
  --kv-dtype D     paged KV pool storage dtype: native (default; the
                   compute dtype), int8 or fp8 (quantized pools with
                   per-(token, kv-head) scales — quant fused into the
                   write scatter, dequant into the attention walk; ~0.53x
                   the bf16 HBM bytes/token at head_dim 64, so the same
                   pool holds ~2x the cached tokens), or bf16/fp16/fp32.
                   Defaults to $REPRO_KV_DTYPE when set. fp8 falls back
                   to int8 with a warning on jax builds without float8.
  --tp N           tensor parallelism: shard params and the paged KV pools
                   over an N-wide (data=1, model=N) mesh so one engine
                   spans N devices (each holds 1/N of the KV bytes). Needs
                   N devices — on CPU set
                   XLA_FLAGS=--xla_force_host_platform_device_count=N.
                   1 (default) = the single-device engine, unchanged.
  --scheduler P    queue policy (repro.serving.scheduler): "priority"
                   (default; priority classes, FIFO tie-break, block-level
                   preemption of strictly-lower-priority actives under
                   pool pressure) or "fifo" (priorities ignored, never
                   preempts — the literal pre-PR-5 queue)
  --priority LIST  comma-separated priority cycle assigned round-robin
                   across requests (e.g. "0,0,2": every third request is
                   high-priority); higher = more urgent. Default "0".
  --sched-aging S  anti-starvation: a queued request gains one priority
                   class per S seconds of wait (0 = off)
  --spec-k K       speculative decoding: verify up to K n-gram draft
                   tokens per slot per decode step (paged pure-KV
                   families only). The output stream is bitwise the
                   --spec-k 0 stream — drafts change step count, never
                   tokens. 0 (default) = off.
  --spec-ngram N   longest history suffix the proposer matches (default 3)
  --no-spec        force speculative decoding off (overrides --spec-k)
  --port P         serve HTTP/SSE on port P (0 = ephemeral, printed at
                   startup) instead of running the batch workload
  --host H         bind address for --port (default 127.0.0.1)
  --replicas R     engine replicas behind the prefix-affinity router
                   (server mode; needs R*tp devices for per-replica
                   meshes, else replicas share the default device)
  --max-queue N    per-replica admission backpressure: POSTs get 503
                   once a replica's queue holds N requests (default 32)
  --request-timeout S  default per-request wall-clock deadline in server
                   mode: a stream with no completion within S seconds is
                   cancelled (blocks freed) and fails with 504 semantics;
                   a request's own "deadline_s" body field overrides it
                   (0 = unbounded, the default)
  --step-deadline S  replica health watchdog (multi-replica server mode):
                   a replica whose step exceeds S seconds goes SUSPECT,
                   twice consecutively goes DEAD — its queued + in-flight
                   requests migrate bitwise to survivors and probes
                   re-admit it when it recovers (0 = off, the default)
  --shed-below F   graceful degradation: when the alive-replica fraction
                   drops to <= F (and at least one replica is dead),
                   requests at priority <= --shed-priority are shed with
                   503 + Retry-After (default 0.5)
  --shed-priority P  highest priority class shed under degradation
                   (default 0)

Per-request metrics (TTFT, queue wait, decode tok/s, prefix-hit tokens,
speculative acceptance rate when --spec-k is on) print at the end.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=0,
                    help="fixed prompt length; 0 = random short prompts")
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk size (1 = token-by-token)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="KV pool blocks; 0 = worst-case sized")
    ap.add_argument("--no-paged", action="store_true",
                    help="use the dense per-slot cache layout")
    ap.add_argument("--no-prefix-cache", action="store_true")
    ap.add_argument("--host-cache-gb", type=float, default=0.0,
                    help="host-RAM spill tier size in GiB (0 = no tier)")
    ap.add_argument("--host-cache-blocks", type=int, default=0,
                    help="host-RAM spill tier size in blocks (overrides "
                         "--host-cache-gb; 0 = use the GiB sizing)")
    ap.add_argument("--kv-store", default=None,
                    help="directory for the persistent prefix store "
                         "(warm restarts; None = off)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel width (devices per engine)")
    ap.add_argument("--scheduler", default="priority",
                    choices=["priority", "fifo"],
                    help="queue policy: priority classes + preemption, or "
                         "plain FIFO")
    ap.add_argument("--priority", default="0",
                    help="comma-separated priority cycle assigned "
                         "round-robin across requests (higher = more "
                         "urgent)")
    ap.add_argument("--sched-aging", type=float, default=0.0,
                    help="seconds of queue wait per aged priority class "
                         "(0 = no aging)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="max speculative draft tokens per decode step "
                         "(0 = off)")
    ap.add_argument("--spec-ngram", type=int, default=3,
                    help="longest n-gram the draft proposer matches")
    ap.add_argument("--no-spec", action="store_true",
                    help="force speculative decoding off")
    ap.add_argument("--port", type=int, default=None,
                    help="serve HTTP/SSE on this port (0 = ephemeral) "
                         "instead of running the batch workload")
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address for --port")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the prefix-affinity "
                         "router (server mode)")
    ap.add_argument("--max-queue", type=int, default=32,
                    help="per-replica queue depth that triggers 503 "
                         "backpressure in server mode")
    ap.add_argument("--request-timeout", type=float, default=0.0,
                    help="default per-request deadline in seconds for "
                         "server mode (0 = unbounded)")
    ap.add_argument("--step-deadline", type=float, default=0.0,
                    help="replica step-time deadline in seconds for the "
                         "health watchdog (0 = off; multi-replica only)")
    ap.add_argument("--shed-below", type=float, default=0.5,
                    help="shed low-priority traffic when alive/total "
                         "replicas <= this fraction")
    ap.add_argument("--shed-priority", type=int, default=0,
                    help="highest priority class shed under degraded "
                         "capacity")
    kernel_modes = ["xla", "xla_chunked", "pallas", "pallas_interpret"]
    ap.add_argument("--kernels",
                    default=os.environ.get("REPRO_KERNELS") or None,
                    choices=kernel_modes,
                    help="kernel mode for the serving step "
                         "(default: $REPRO_KERNELS or ambient context)")
    kv_dtypes = ["native", "int8", "fp8", "bf16", "fp16", "fp32"]
    ap.add_argument("--kv-dtype",
                    default=os.environ.get("REPRO_KV_DTYPE") or None,
                    choices=kv_dtypes,
                    help="paged KV pool storage dtype: int8/fp8 quantize "
                         "with fused per-token scales; native (default) "
                         "keeps the compute dtype "
                         "(default: $REPRO_KV_DTYPE or native)")
    args = ap.parse_args(argv)
    # argparse does not validate `choices` against env-supplied defaults
    if args.kernels is not None and args.kernels not in kernel_modes:
        ap.error(f"invalid kernel mode {args.kernels!r} "
                 f"(from $REPRO_KERNELS?)")
    if args.kv_dtype is not None and args.kv_dtype not in kv_dtypes:
        ap.error(f"invalid kv dtype {args.kv_dtype!r} "
                 f"(from $REPRO_KV_DTYPE?)")
    try:
        priorities = [int(p) for p in args.priority.split(",") if p != ""]
    except ValueError:
        ap.error(f"--priority must be a comma-separated int list, "
                 f"got {args.priority!r}")
    if not priorities:
        priorities = [0]

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.core as nn
    from repro.configs import get_arch
    from repro.models.registry import get_model
    from repro.serving.engine import Request, ServingEngine

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.family == "audio":
        print("serve CLI drives LM-style decode; whisper needs frames — "
              "use repro.models.whisper.init_decode_state directly")
        return 2
    api = get_model(cfg)
    print(f"loading {cfg.name}: {cfg.param_count():,} params "
          f"({'smoke' if args.smoke else 'full'})", flush=True)
    S0 = max(8, cfg.ssm_chunk if cfg.ssm_state else 8)
    params = nn.init(lambda t: api.forward(t), jax.random.key(0),
                     jnp.zeros((1, S0), jnp.int32))

    engine_kw = dict(max_batch=args.max_batch,
                     max_seq=args.max_seq, chunk=args.chunk,
                     paged=(None if not args.no_paged else False),
                     block_size=args.block_size,
                     num_blocks=args.num_blocks or None,
                     prefix_cache=not args.no_prefix_cache,
                     kernels=args.kernels,
                     scheduler=args.scheduler,
                     aging_s=args.sched_aging,
                     spec_k=0 if args.no_spec else args.spec_k,
                     spec_ngram=args.spec_ngram,
                     host_cache_blocks=args.host_cache_blocks or None,
                     host_cache_gb=args.host_cache_gb,
                     kv_store=args.kv_store,
                     kv_dtype=args.kv_dtype)

    if args.port is not None:
        # server mode: HTTP/SSE frontend, optional multi-replica router
        from repro.serving.frontend import AsyncFrontend
        from repro.serving.router import Router, make_replica_engines
        if args.replicas < 1:
            ap.error(f"--replicas must be >= 1, got {args.replicas}")
        router_kw = {}
        if args.step_deadline > 0:
            router_kw["step_deadline_s"] = args.step_deadline
        if args.replicas > 1:
            engines = make_replica_engines(
                api, params, replicas=args.replicas, tp=args.tp,
                **engine_kw)
            target = Router(engines, **router_kw)
            print(f"router: {args.replicas} replicas, prefix-affinity "
                  f"routing, tp={args.tp} each"
                  + (f", step deadline {args.step_deadline:g}s"
                     if args.step_deadline > 0 else ""), flush=True)
        else:
            target = ServingEngine(api, params, tp=args.tp, **engine_kw)
        fe = AsyncFrontend(
            target, host=args.host, port=args.port,
            max_queue=args.max_queue,
            request_timeout=args.request_timeout or None,
            step_deadline_s=args.step_deadline or None,
            shed_below=args.shed_below, shed_priority=args.shed_priority)
        fe.run_forever()
        return 0

    engine = ServingEngine(api, params, tp=args.tp, **engine_kw)
    if engine.spec is not None:
        print(f"speculative: k={engine.spec.k} n-gram drafts "
              f"(<= {engine.spec.max_ngram}-token suffix match)",
              flush=True)
    if args.scheduler != "priority" or len(priorities) > 1 \
            or args.sched_aging:
        print(f"scheduler: {args.scheduler}, priority cycle {priorities}, "
              f"aging {args.sched_aging:g}s", flush=True)
    if engine.paged:
        print(f"paged KV: {engine.num_blocks} blocks x "
              f"{engine.block_size} tok, {engine.kv_dtype} pools "
              f"({engine.kv_bytes_per_token():.0f} B/tok)"
              f"{', prefix cache on' if engine.prefix else ''}"
              f" | kernels={args.kernels or 'ambient'}", flush=True)
        if engine.prefix is not None and hasattr(engine.prefix, "host"):
            print(f"tiered KV: host pool {engine.prefix.host.capacity} "
                  f"blocks"
                  + (f", warm store {args.kv_store} "
                     f"({len(engine.prefix.host)} entries preloaded)"
                     if args.kv_store else ""), flush=True)
    if engine.tp > 1:
        from repro.launch.serve_shardings import per_device_state_bytes
        print(f"tensor parallel: tp={engine.tp} over "
              f"{[d.platform for d in jax.devices()[:engine.tp]]} | "
              f"{per_device_state_bytes(engine.state) / 2**20:.2f} MiB "
              f"cache/device", flush=True)
        for leaf, spec in sorted(engine.tp_layout().items()):
            print(f"  state {leaf}: {spec}", flush=True)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = args.prompt_len or int(rng.integers(2, 6))
        prompt = rng.integers(1, cfg.vocab_size, plen).tolist()
        engine.submit(Request(uid=i, prompt=prompt,
                              max_new_tokens=args.max_new,
                              priority=priorities[i % len(priorities)],
                              temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              seed=args.seed + i))
    t0 = time.time()
    done = engine.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"  req {r.uid}: {r.prompt[:6]}{'...' if len(r.prompt) > 6 else ''}"
              f" -> {r.generated[:8]}...")
    print(f"{len(done)} requests, {toks} tokens, {dt:.2f}s "
          f"-> {toks / dt:.1f} tok/s", flush=True)
    m = engine.metrics_summary()
    if m:
        line = (f"mean TTFT {m['mean_ttft_s'] * 1e3:.1f}ms | "
                f"mean queue wait {m['mean_queue_wait_s'] * 1e3:.1f}ms | "
                f"mean decode {m['mean_decode_tok_per_s']:.1f} tok/s")
        if "mean_prefix_hit_tokens" in m:
            line += (f" | prefix hits "
                     f"{m['mean_prefix_hit_tokens']:.1f} tok/req")
        if "kv_bytes_per_token" in m:
            line += (f" | KV {engine.kv_dtype} "
                     f"{m['kv_bytes_per_token']:.0f} B/tok")
        if "host_pool_capacity" in m:
            line += (f" | tier: {m['tier_spilled_blocks']:.0f} spilled / "
                     f"{m['tier_fetched_blocks']:.0f} fetched blk, host "
                     f"{m['host_pool_blocks']:.0f}/"
                     f"{m['host_pool_capacity']:.0f}, host hits "
                     f"{m.get('mean_host_hit_tokens', 0.0):.1f} tok/req, "
                     f"fetch EWMA {m['tier_fetch_ewma_s'] * 1e3:.1f}ms")
        if m.get("preemptions"):
            line += (f" | {m['preemptions']:.0f} preemptions, "
                     f"{m['requeues']:.0f} requeues")
        if m.get("truncated_requests"):
            line += (f" | {m['truncated_requests']:.0f} truncated "
                     f"prompt{'s' if m['truncated_requests'] != 1 else ''}")
        if "spec_accept_rate" in m:
            line += (f" | spec accept {m['spec_accept_rate'] * 100:.0f}% "
                     f"({m['spec_accepted']:.0f}/{m['spec_proposed']:.0f})")
        print(line, flush=True)
    if args.kv_store:
        n = engine.save_kv_store()
        print(f"kv-store: {n} prefix blocks persisted to {args.kv_store}",
              flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
