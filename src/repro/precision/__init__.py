from repro.precision.loss_scale import (DynamicLossScaleState, LossScaler,
                                        all_finite, dynamic_scaler,
                                        static_scaler)

__all__ = ["DynamicLossScaleState", "LossScaler", "all_finite",
           "dynamic_scaler", "static_scaler"]
