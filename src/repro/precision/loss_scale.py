"""Loss scaling for fp16 mixed-precision training (paper §3.3, Listing 6).

The paper's dynamic scheme, verbatim semantics, as pure JAX state transitions
(``lax.cond``, no host round-trip — the whole thing lives inside the compiled
train step):

* on inf/nan gradients: halve the scale, skip the update, reset the counter;
* otherwise: apply the (unscaled) update; after ``interval`` consecutive good
  steps, double the scale.

bf16 (TPU default) shares fp32's exponent so ``static_scaler(1.0)`` is a
no-op passthrough; the fp16 policy wires in :func:`dynamic_scaler`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class DynamicLossScaleState(NamedTuple):
    scale: jax.Array          # f32 scalar
    counter: jax.Array        # i32 consecutive good steps
    total_skipped: jax.Array  # i32 diagnostics


def all_finite(tree: Any) -> jax.Array:
    """Scalar bool: every leaf of the gradient pytree is finite.

    This is the paper's ``solver.check_inf_or_nan_grad()`` (negated).
    """
    leaves = [jnp.isfinite(x).all() for x in jax.tree.leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    if not leaves:
        return jnp.asarray(True)
    return jnp.stack(leaves).all()


@dataclasses.dataclass(frozen=True)
class LossScaler:
    """Config + pure transitions. ``dynamic=False`` -> fixed scale."""

    init_scale: float = 2.0 ** 13
    factor: float = 2.0
    interval: int = 2000
    dynamic: bool = True
    max_scale: float = 2.0 ** 24
    min_scale: float = 1.0

    def init_state(self) -> DynamicLossScaleState:
        return DynamicLossScaleState(
            scale=jnp.asarray(self.init_scale, jnp.float32),
            counter=jnp.zeros((), jnp.int32),
            total_skipped=jnp.zeros((), jnp.int32))

    def scale_loss(self, loss: jax.Array,
                   state: DynamicLossScaleState) -> jax.Array:
        return loss * state.scale.astype(loss.dtype)

    def unscale_grads(self, grads: Any, state: DynamicLossScaleState) -> Any:
        inv = (1.0 / state.scale)
        return jax.tree.map(
            lambda g: (g.astype(jnp.float32) * inv).astype(g.dtype), grads)

    def next_state(self, state: DynamicLossScaleState,
                   grads_finite: jax.Array) -> DynamicLossScaleState:
        if not self.dynamic:
            return state

        def good(s: DynamicLossScaleState) -> DynamicLossScaleState:
            counter = s.counter + 1
            grow = counter >= self.interval
            scale = jnp.where(
                grow, jnp.minimum(s.scale * self.factor, self.max_scale),
                s.scale)
            counter = jnp.where(grow, 0, counter)
            return DynamicLossScaleState(scale, counter, s.total_skipped)

        def bad(s: DynamicLossScaleState) -> DynamicLossScaleState:
            return DynamicLossScaleState(
                jnp.maximum(s.scale / self.factor, self.min_scale),
                jnp.zeros((), jnp.int32),
                s.total_skipped + 1)

        return lax.cond(grads_finite, good, bad, state)


def dynamic_scaler(init_scale: float = 2.0 ** 13, interval: int = 2000,
                   factor: float = 2.0) -> LossScaler:
    return LossScaler(init_scale=init_scale, interval=interval, factor=factor,
                      dynamic=True)


def static_scaler(scale: float = 1.0) -> LossScaler:
    return LossScaler(init_scale=scale, dynamic=False)
