"""Host data pipeline: sharded, prefetching, deterministically resumable.

The DALI role in the paper's setup (§4), host-side. Batches are synthesized
(or drawn from a token file) *by global step index*, so a restarted run
replays the exact same stream — the checkpoint only has to store an integer.

``as_global_array`` builds one sharded jax.Array across the mesh from the
host batch (the single-controller equivalent of per-process sharded loading:
each device gets exactly its shard; in a multi-host deployment each process
would synthesize only its addressable shards — same code path via
``make_array_from_callback``).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class PipelineState:
    step: int = 0


class SyntheticLMPipeline:
    """Deterministic synthetic next-token-prediction stream."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *,
                 seed: int = 17, prefetch: int = 2):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.state = PipelineState()
        self._prefetch = prefetch
        self._q: queue.Queue | None = None
        self._worker: threading.Thread | None = None

    # ---- deterministic batch synthesis ----
    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        B, S = self.shape.global_batch, self.shape.seq_len
        rng = np.random.default_rng((self.seed, step))
        # zipf-ish token distribution: more realistic embedding traffic
        toks = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
        toks = (toks % (self.cfg.vocab_size - 1)) + 1
        batch = {"tokens": toks[:, :S].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        if self.cfg.mrope:
            pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None, :, None],
                                  (B, S, 3))
            batch["positions"] = np.ascontiguousarray(pos)
        if self.cfg.family == "audio":
            batch["frames"] = rng.standard_normal(
                (B, self.cfg.n_audio_frames, self.cfg.d_model)
            ).astype(np.float32)
        return batch

    # ---- iterator + prefetch ----
    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        if self._q is None:
            self._start_worker()
        assert self._q is not None
        item = self._q.get()
        self.state.step += 1
        return item

    def _start_worker(self) -> None:
        q = queue.Queue(maxsize=self._prefetch)
        self._q = q

        def work(start_step: int) -> None:
            s = start_step
            while True:
                q.put(self.batch_at(s))  # bound to THIS queue: a worker
                s += 1                   # orphaned by restore() blocks forever

        self._worker = threading.Thread(
            target=work, args=(self.state.step,), daemon=True)
        self._worker.start()

    # ---- resume ----
    def snapshot(self) -> dict[str, Any]:
        return {"step": self.state.step, "seed": self.seed}

    def restore(self, snap: dict[str, Any]) -> None:
        if self._worker is not None:
            # drop the prefetch queue; restart from the restored index
            self._q = None
            self._worker = None
        self.state.step = int(snap["step"])
        self.seed = int(snap["seed"])


def as_global_array(batch: dict[str, np.ndarray],
                    shardings: dict[str, NamedSharding]
                    ) -> dict[str, jax.Array]:
    """Host batch -> sharded global jax.Arrays (per-device shard placement)."""
    out = {}
    for k, v in batch.items():
        sh = shardings[k]
        out[k] = jax.make_array_from_callback(
            v.shape, sh, lambda idx, v=v: v[idx])
    return out
