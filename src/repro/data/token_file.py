
"""Memory-mapped token-file dataset (the non-synthetic production path).

File format: int32 little-endian flat token stream (``.bin``), the standard
pre-tokenized corpus layout. Deterministic, random-access by step index —
the same restart contract as the synthetic pipeline (checkpoint stores one
integer).
"""

from __future__ import annotations

import pathlib

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def write_token_file(path: str, tokens: np.ndarray) -> None:
    np.asarray(tokens, np.int32).tofile(path)


class TokenFilePipeline:
    """Samples (tokens, labels) windows from a memory-mapped corpus."""

    def __init__(self, path: str, cfg: ModelConfig, shape: ShapeConfig, *,
                 seed: int = 0, shard: tuple[int, int] = (0, 1)):
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        n = (len(self.data) - 1) // shape.seq_len
        if n <= 0:
            raise ValueError(f"{path}: too short for seq_len={shape.seq_len}")
        self.n_windows = n
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.shard_idx, self.n_shards = shard

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        B, S = self.shape.global_batch, self.shape.seq_len
        rng = np.random.default_rng((self.seed, step, self.shard_idx))
        idx = rng.integers(0, self.n_windows, B)
        toks = np.stack([self.data[i * S: i * S + S + 1] for i in idx])
        toks = np.clip(toks, 0, self.cfg.vocab_size - 1)
        return {"tokens": toks[:, :S].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
