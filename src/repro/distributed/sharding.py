"""Logical-axis sharding rules (t5x/MaxText style).

Models annotate activations and parameters with *logical* axis names
("batch", "heads", "mlp", ...). A rule table — owned by the launcher, swapped
per hillclimb experiment — maps logical names to mesh axes. Rules degrade
gracefully: a logical dim that doesn't divide by its mesh-axis size is left
unsharded (e.g. kv_heads=8 on a model axis of 16), so one model definition
serves every mesh.

No mesh set (unit tests, eager plane) -> every call is a no-op.
"""

from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = str | tuple[str, ...] | None


@dataclasses.dataclass
class ShardingEnv:
    mesh: Mesh | None = None
    # logical axis name -> mesh axis (or tuple of mesh axes, or None)
    axis_rules: dict[str, AxisVal] = dataclasses.field(default_factory=dict)
    # param-path regex -> tuple of logical names (one per trailing dim; a
    # leading stacked-layer dim is auto-padded with "layers")
    param_rules: list[tuple[str, tuple[str | None, ...]]] = \
        dataclasses.field(default_factory=list)


class _State(threading.local):
    def __init__(self) -> None:
        self.env = ShardingEnv()


_state = _State()


def get_env() -> ShardingEnv:
    return _state.env


def set_env(env: ShardingEnv) -> None:
    _state.env = env


@contextlib.contextmanager
def sharding_env(env: ShardingEnv):
    prev = _state.env
    _state.env = env
    try:
        yield env
    finally:
        _state.env = prev


def _axis_size(mesh: Mesh, val: AxisVal) -> int:
    if val is None:
        return 1
    if isinstance(val, str):
        return mesh.shape[val]
    return int(np.prod([mesh.shape[a] for a in val]))


def spec_for(names: tuple[str | None, ...],
             shape: tuple[int, ...] | None = None) -> P:
    """Logical names -> PartitionSpec under current rules (+ divisibility)."""
    env = _state.env
    out: list[AxisVal] = []
    for i, n in enumerate(names):
        val = env.axis_rules.get(n) if n else None
        if val is not None and shape is not None and env.mesh is not None:
            if shape[i] % _axis_size(env.mesh, val) != 0:
                val = None  # degrade: dim not divisible by axis size
        out.append(val)
    # PartitionSpec forbids using one mesh axis twice; degrade later uses.
    used: set[str] = set()
    cleaned: list[AxisVal] = []
    for val in out:
        axes = (val,) if isinstance(val, str) else (val or ())
        if any(a in used for a in axes):
            cleaned.append(None)
            continue
        used.update(axes)
        cleaned.append(val)
    return P(*cleaned)


def constrain(x: jax.Array, *names: str | None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    env = _state.env
    if env.mesh is None or env.mesh.empty:
        return x
    if len(names) != x.ndim:
        raise ValueError(f"constrain: {len(names)} names for rank-{x.ndim}")
    spec = spec_for(tuple(names), tuple(x.shape))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(env.mesh, spec))


def param_spec(path: str, shape: tuple[int, ...]) -> P:
    """Parameter PartitionSpec from the path-regex rule table."""
    env = _state.env
    for rx, names in env.param_rules:
        if re.search(rx, path):
            padded = names
            if len(names) < len(shape):  # stacked layer axis in front
                padded = ("layers",) * (len(shape) - len(names)) + tuple(names)
            elif len(names) > len(shape):
                padded = tuple(names[-len(shape):])
            return spec_for(tuple(padded), tuple(shape))
    return P()  # replicate by default


def named_zeros(names: tuple[str | None, ...], shape: tuple[int, ...],
                dtype) -> jax.Array:
    """Zeros placed by the logical-axis rule table.

    Without a mesh this is exactly ``jnp.zeros`` (the eager plane and every
    single-device caller are untouched). Under an active env the array is
    committed to its :class:`NamedSharding` at creation — jit with
    ``out_shardings`` makes each device write only its own shard, so a
    pool sized to the *aggregate* memory of a tp slice never materializes
    as a full single-device copy first. Indivisible dims degrade to
    replicated exactly as :func:`spec_for` does for activations.
    """
    import jax.numpy as jnp
    env = _state.env
    if env.mesh is None or env.mesh.empty:
        return jnp.zeros(shape, dtype)
    sharding = NamedSharding(env.mesh, spec_for(tuple(names), tuple(shape)))
    return jax.jit(lambda: jnp.zeros(shape, dtype),
                   out_shardings=sharding)()


def params_shardings(params: dict[str, Any]) -> dict[str, NamedSharding]:
    env = _state.env
    assert env.mesh is not None
    return {k: NamedSharding(env.mesh, param_spec(k, tuple(v.shape)))
            for k, v in params.items()}


def tree_shardings(tree: Any, spec_fn) -> Any:
    """Map ``spec_fn(path, leaf) -> NamedSharding`` over a pytree with paths."""
    env = _state.env
    assert env.mesh is not None

    def walk(prefix: str, node: Any) -> Any:
        if isinstance(node, dict):
            return {k: walk(f"{prefix}/{k}" if prefix else k, v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [walk(f"{prefix}/{i}", v) for i, v in enumerate(node)]
            return type(node)(vals)
        return spec_fn(prefix, node)

    return walk("", tree)
