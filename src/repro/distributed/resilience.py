"""Straggler detection + elastic-restart policy (fleet-scale runnability).

On a real multi-pod deployment the failure modes are: a slow host
(straggler), a dead host (restart from checkpoint on a smaller mesh), and
transient step blow-ups. This module is the *controller-side* logic — pure
host code, unit-testable in this container, and exactly what the launcher
loops call on real hardware:

* ``StragglerMonitor`` — per-step wall-time EWMA + robust z-score; flags
  sustained slowdowns (>= ``sigma`` for ``patience`` steps), distinguishing
  a slow fleet (recompile, input stall) from a slow step (GC hiccup).
* ``ElasticPolicy`` — given the surviving chip count, picks the largest
  valid mesh <= survivors consistent with the model's divisibility
  constraints, for re-sharded restart via CheckpointManager.restore
  (arrays are stored unsharded, so any target mesh works).
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque


@dataclasses.dataclass
class StragglerVerdict:
    is_straggler: bool
    z_score: float
    ewma_s: float


class StragglerMonitor:
    def __init__(self, alpha: float = 0.05, sigma: float = 4.0,
                 patience: int = 3, warmup: int = 8):
        self.alpha = alpha
        self.sigma = sigma
        self.patience = patience
        self.warmup = warmup
        self.ewma: float | None = None
        self.ewvar: float = 0.0
        self.n = 0
        self._flags: deque[bool] = deque(maxlen=patience)

    def reset(self) -> None:
        """Forget all statistics. A readmitted serving replica must not
        inherit the step-time distribution that got it killed — its first
        post-recovery step would z-score against stale history."""
        self.ewma = None
        self.ewvar = 0.0
        self.n = 0
        self._flags.clear()

    def observe(self, step_time_s: float) -> StragglerVerdict:
        self.n += 1
        if self.ewma is None:
            self.ewma = step_time_s
            return StragglerVerdict(False, 0.0, self.ewma)
        resid = step_time_s - self.ewma
        std = math.sqrt(self.ewvar) if self.ewvar > 0 else abs(resid) + 1e-9
        z = resid / (std + 1e-12)
        slow = self.n > self.warmup and z > self.sigma
        self._flags.append(slow)
        # only adapt statistics on non-outlier steps (robustness)
        if not slow:
            self.ewma += self.alpha * resid
            self.ewvar = (1 - self.alpha) * (self.ewvar
                                             + self.alpha * resid * resid)
        sustained = len(self._flags) == self.patience and all(self._flags)
        return StragglerVerdict(sustained, z, self.ewma)


@dataclasses.dataclass
class MeshChoice:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    chips: int


class ElasticPolicy:
    """Pick a restart mesh after losing chips (power-of-two contraction)."""

    def __init__(self, model_axis: int = 16, min_data: int = 1):
        self.model_axis = model_axis
        self.min_data = min_data

    def choose(self, surviving_chips: int) -> MeshChoice:
        model = self.model_axis
        while model > 1 and surviving_chips < model:
            model //= 2
        data = max(self.min_data, 1)
        d = surviving_chips // model
        # largest power of two <= d
        data = 1 << max(0, (d.bit_length() - 1))
        if data < self.min_data:
            raise RuntimeError(
                f"{surviving_chips} chips cannot satisfy data>="
                f"{self.min_data} with model={model}")
        return MeshChoice(shape=(data, model), axes=("data", "model"),
                          chips=data * model)
