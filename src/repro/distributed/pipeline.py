"""Pipeline parallelism: GPipe schedule over a mesh axis via shard_map.

TPU-native PP: stages live on the ``pod`` axis (cross-pod DCN links carry
only the (microbatch, d_model) activation edge — the whole point of putting
PP, not DP, across pods at 1000+ chips). The schedule is SPMD: every device
runs the same program; ``lax.ppermute`` shifts activations stage->stage+1
each tick, and the first/last stages feed/drain microbatches. Differentiable
(grad flows back through the reverse permutes), so the same primitive serves
training.

Bubble fraction = (S-1)/(M+S-1) — choose n_micro >> n_stages.
"""

from __future__ import annotations

from collections.abc import Callable
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def spmd_pipeline(stage_fn: Callable, n_stages: int, n_micro: int,
                  axis: str = "pod"):
    """Build the per-shard pipeline body.

    ``stage_fn(stage_params, x, stage_idx) -> x`` is one stage's compute.
    The returned body has signature ``(stage_params_local, x_micro) -> y``
    with ``x_micro`` (n_micro, mb, ...) resident on every stage (only stage 0
    reads it) and y (n_micro, mb, ...) produced by the last stage (garbage on
    other stages; caller masks/selects).
    Must run inside ``shard_map`` over ``axis``.
    """

    def body(stage_params: Any, x_micro: jax.Array) -> jax.Array:
        stage = lax.axis_index(axis)
        n_total = n_micro + n_stages - 1
        mb_shape = x_micro.shape[1:]
        state = jnp.zeros(mb_shape, x_micro.dtype)     # in-flight activation
        out = jnp.zeros_like(x_micro)

        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            state, out = carry
            # stage 0 ingests microbatch t (when in range)
            inject = x_micro[jnp.minimum(t, n_micro - 1)]
            state = jnp.where(stage == 0, inject, state)
            state = stage_fn(stage_params, state, stage)
            # last stage drains microbatch t-(S-1)
            emit_idx = t - (n_stages - 1)
            emit = jnp.logical_and(stage == n_stages - 1, emit_idx >= 0)
            out = lax.cond(
                emit,
                lambda o: lax.dynamic_update_index_in_dim(
                    o, state, jnp.maximum(emit_idx, 0), 0),
                lambda o: o, out)
            # shift stage -> stage+1 (the wrap edge's payload is ignored)
            state = lax.ppermute(state, axis, fwd)
            return (state, out), None

        (state, out), _ = lax.scan(tick, (state, out),
                                   jnp.arange(n_total))
        # only the last stage wrote `out` (zeros elsewhere); make it
        # replicated so the P() out_spec is honest
        return lax.psum(out, axis)

    return body


def make_pipeline_fn(stage_fn: Callable, mesh: Mesh, n_micro: int,
                     axis: str = "pod"):
    """jit-ready pipelined apply.

    ``stage_params`` pytree must have a leading stage axis (== axis size);
    inputs/outputs (n_micro, mb, ...) are replicated across the pipe axis.
    """
    n_stages = mesh.shape[axis]
    body = spmd_pipeline(stage_fn, n_stages, n_micro, axis)

    def wrapped(stage_params_local, x_micro):
        # stage params arrive with a leading length-1 stage shard; drop it
        sp = jax.tree.map(lambda a: a[0], stage_params_local)
        return body(sp, x_micro)

    pspec = P(axis)   # prefix spec: leading stage axis on every param leaf
    xspec = P()       # microbatch tensor replicated across the pipe axis
    f = shard_map(wrapped, mesh=mesh, in_specs=(pspec, xspec),
                  out_specs=xspec, check_rep=False)
    return jax.jit(f)
