"""Step builders: train_step / prefill_step / serve_step.

The train step is the full paper pipeline in one compiled program:
scaled loss -> grads -> unscale -> finite check -> clip -> solver update
(fp32 masters) -> conditional skip -> dynamic loss-scale transition
(paper §3.3 Listing 6). Under pjit + the sharding rule tables this is also
the distributed story: DP gradient reduction, TP activation collectives and
ZeRO-1 optimizer sharding all come out of the partitioner.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.precision.loss_scale import LossScaler, all_finite
from repro.solvers.base import Solver, clip_by_global_norm


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: dict[str, Any]
    opt_state: dict[str, Any]
    scaler_state: Any
    step: jax.Array


def init_train_state(params, solver: Solver, scaler: LossScaler) -> TrainState:
    return TrainState(params=params,
                      opt_state=solver.init_state(params),
                      scaler_state=scaler.init_state(),
                      step=jnp.zeros((), jnp.int32))


def train_state_shapes(params_shapes, solver: Solver,
                       scaler: LossScaler) -> TrainState:
    return jax.eval_shape(
        lambda p: init_train_state(p, solver, scaler), params_shapes)


def make_train_step(loss_fn, solver: Solver, scaler: LossScaler,
                    grad_clip: float = 1.0, microbatches: int = 1,
                    grad_shardings=None):
    """loss_fn(params, batch) -> scalar fp32.

    ``microbatches`` > 1 turns on gradient accumulation: the global batch is
    split on its leading axis and scanned, trading one fp32 grad buffer for a
    1/m cut in peak activation memory — how a 1M-token global batch fits a
    16 GB v5e chip. ``grad_shardings`` (dict path->NamedSharding) pins the
    accumulator layout (ZeRO-2: grads sharded like optimizer state, so the
    f32 buffer never exceeds its shard).
    """

    def pin(g):
        if grad_shardings is None:
            return g
        return {k: jax.lax.with_sharding_constraint(v, grad_shardings[k])
                for k, v in g.items()}

    def grads_of(params, batch, scaler_state):
        def scaled_loss(p):
            loss = loss_fn(p, batch)
            return scaler.scale_loss(loss.astype(jnp.float32),
                                     scaler_state), loss
        return jax.grad(scaled_loss, has_aux=True)(params)

    def train_step(state: TrainState, batch: dict[str, Any]):
        if microbatches <= 1:
            grads, loss = grads_of(state.params, batch, state.scaler_state)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_step(acc, mbatch):
                g, l = grads_of(state.params, mbatch, state.scaler_state)
                acc_g, acc_l = acc
                acc_g = pin(jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g))
                return (acc_g, acc_l + l.astype(jnp.float32)), None

            zero_g = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
            (gsum, lsum), _ = jax.lax.scan(
                acc_step, (zero_g, jnp.zeros((), jnp.float32)), mb)
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, gsum)
            loss = lsum * inv
        grads = scaler.unscale_grads(grads, state.scaler_state)
        finite = all_finite(grads)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)

        new_params, new_opt = solver.step(state.params, grads,
                                          state.opt_state)
        # skip the update on inf/nan (paper Listing 6); bf16 never triggers
        keep = finite
        sel = functools.partial(jnp.where, keep)
        params = jax.tree.map(sel, new_params, state.params)
        opt_state = jax.tree.map(sel, new_opt, state.opt_state)
        scaler_state = scaler.next_state(state.scaler_state, finite)

        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "loss_scale": scaler_state.scale,
            "skipped": (~finite).astype(jnp.int32),
        }
        return TrainState(params=params, opt_state=opt_state,
                          scaler_state=scaler_state,
                          step=state.step + 1), metrics

    return train_step


def make_prefill_step(forward_fn):
    """forward_fn(params, batch) -> logits. Inference prefill (no grads)."""

    def prefill_step(params, batch):
        logits = forward_fn(params, batch)
        # next-token argmax — the minimal useful prefill output
        return jnp.argmax(logits[:, -1, :].astype(jnp.float32), axis=-1)

    return prefill_step


def make_serve_step(decode_fn):
    """decode_fn(params, tokens, state, pos, **extras) -> (logits, state)."""

    def serve_step(params, batch):
        extras = {k: v for k, v in batch.items()
                  if k not in ("tokens", "state", "pos")}
        logits, new_state = decode_fn(params, batch["tokens"],
                                      batch["state"], batch["pos"], **extras)
        next_tok = jnp.argmax(logits[:, -1, :].astype(jnp.float32), axis=-1)
        return next_tok, new_state

    return serve_step
