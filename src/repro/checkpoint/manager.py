"""Checkpoint manager: atomic, integrity-checked, async, elastic.

Fault-tolerance contract for 1000+-node runs:

* **Atomic**: state is written to ``step_<n>.tmp-<nonce>/`` and renamed only
  after every file is flushed + checksummed — a killed writer can never
  corrupt the latest checkpoint.
* **Restart**: ``latest_step``/``restore`` pick up the newest complete
  checkpoint; the data pipeline state (a step counter) restores bit-exact
  ordering.
* **Elastic**: arrays are stored unsharded (host gather); ``restore`` takes
  target shardings, so a run can come back on a *different* mesh shape
  (re-shard on load) — scale 512 -> 256 chips after losing a pod.
* **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a background thread, overlapping I/O with the next steps.
"""

from __future__ import annotations

import json
import os
import pathlib
import secrets
import threading
import zlib
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    out: dict[str, Any] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
        return out
    if isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
        return out
    if hasattr(tree, "__dataclass_fields__"):
        for f in tree.__dataclass_fields__:
            out.update(_flatten(getattr(tree, f), f"{prefix}{f}/"))
        return out
    out[prefix.rstrip("/")] = tree
    return out


def _unflatten_into(template: Any, flat: dict[str, np.ndarray],
                    prefix: str = "") -> Any:
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(template)]
        return type(template)(vals) if not hasattr(template, "_fields") \
            else type(template)(*vals)
    if hasattr(template, "__dataclass_fields__"):
        kw = {f: _unflatten_into(getattr(template, f), flat, f"{prefix}{f}/")
              for f in template.__dataclass_fields__}
        return type(template)(**kw)
    return flat[prefix.rstrip("/")]


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ #
    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:010d}"

    def save(self, step: int, state: Any, extra: dict | None = None) -> None:
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}
        self._write(step, host, extra or {})

    def save_async(self, step: int, state: Any,
                   extra: dict | None = None) -> None:
        self.wait()
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}  # sync snapshot
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict[str, np.ndarray],
               extra: dict) -> None:
        final = self._step_dir(step)
        tmp = self.dir / f".tmp-{secrets.token_hex(4)}"
        tmp.mkdir()
        try:
            npz = tmp / "state.npz"
            np.savez(npz, **{k.replace("/", "|"): v for k, v in host.items()})
            crc = zlib.crc32(npz.read_bytes()) & 0xFFFFFFFF
            meta = {"step": step, "crc32": crc,
                    "keys": sorted(host), "extra": extra}
            (tmp / "meta.json").write_text(json.dumps(meta, indent=1))
            os.replace(tmp, final)  # atomic publish
        finally:
            if tmp.exists():
                import shutil
                shutil.rmtree(tmp, ignore_errors=True)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            import shutil
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------ #
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "meta.json").exists():
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any,
                shardings: Any = None) -> Any:
        """Load into ``template``'s structure; optionally re-shard (elastic)."""
        d = self._step_dir(step)
        meta = json.loads((d / "meta.json").read_text())
        npz_path = d / "state.npz"
        crc = zlib.crc32(npz_path.read_bytes()) & 0xFFFFFFFF
        if crc != meta["crc32"]:
            raise IOError(f"checkpoint step {step} failed integrity check")
        with np.load(npz_path) as z:
            flat = {k.replace("|", "/"): z[k] for k in z.files}
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state

    def restore_latest(self, template: Any, shardings: Any = None
                       ) -> tuple[int, Any] | None:
        step = self.latest_step()
        if step is None:
            return None
        return step, self.restore(step, template, shardings)


class PrefixStore:
    """Disk tier of the tiered KV cache: persisted prefix blocks.

    One directory holds one store: ``prefix_store.npz`` (every block of
    every entry, keyed ``<digest hex>|<leaf path>``) plus ``meta.json``
    (CRC of the npz, the per-request priorities, and the pool *layout* —
    block size, cache family, per-leaf block shapes/dtypes). The layout
    is the compatibility contract: a store written by an engine with a
    different block size, model or dtype is useless bytes, and ``load``
    raises rather than let them near a page table. Writes follow the
    manager's atomic idiom (tmp dir + ``os.replace``) so a killed writer
    never corrupts the previous store.

    Callers (the engine's warm-restart path) treat ANY load failure —
    missing, corrupt, layout mismatch — as "serve cold": this class
    raises precisely typed errors; it never half-loads.
    """

    NPZ = "prefix_store.npz"
    META = "meta.json"

    def __init__(self, directory: str | os.PathLike):
        self.dir = pathlib.Path(directory)

    def save(self, entries: dict[bytes, tuple[int, dict[str, np.ndarray]]],
             layout: dict) -> None:
        """Atomically write ``{digest: (priority, {leaf: block array})}``."""
        self.dir.mkdir(parents=True, exist_ok=True)
        tmp = self.dir / f".tmp-{secrets.token_hex(4)}"
        tmp.mkdir()
        try:
            arrays: dict[str, np.ndarray] = {}
            priorities: dict[str, int] = {}
            for key, (pri, data) in entries.items():
                hexkey = key.hex()
                if pri:
                    priorities[hexkey] = int(pri)
                for path, arr in data.items():
                    arrays[f"{hexkey}|{path}"] = np.asarray(arr)
            npz = tmp / self.NPZ
            np.savez(npz, **arrays)
            crc = zlib.crc32(npz.read_bytes()) & 0xFFFFFFFF
            meta = {"crc32": crc, "n_entries": len(entries),
                    "priorities": priorities, "layout": layout}
            (tmp / self.META).write_text(json.dumps(meta, indent=1))
            os.replace(tmp / self.NPZ, self.dir / self.NPZ)
            os.replace(tmp / self.META, self.dir / self.META)
        finally:
            if tmp.exists():
                import shutil
                shutil.rmtree(tmp, ignore_errors=True)

    def load(self, expected_layout: dict
             ) -> dict[bytes, tuple[int, dict[str, np.ndarray]]]:
        """Load and verify. Raises ``FileNotFoundError`` when no store
        exists, ``IOError`` on CRC mismatch, ``ValueError`` on layout
        mismatch — the warm-restart caller maps all three to serve-cold."""
        npz_path = self.dir / self.NPZ
        meta_path = self.dir / self.META
        if not npz_path.exists() or not meta_path.exists():
            raise FileNotFoundError(f"no prefix store in {self.dir}")
        meta = json.loads(meta_path.read_text())
        crc = zlib.crc32(npz_path.read_bytes()) & 0xFFFFFFFF
        if crc != meta.get("crc32"):
            raise IOError(f"prefix store {npz_path} failed integrity check")
        if meta.get("layout") != expected_layout:
            raise ValueError(
                f"prefix store layout mismatch: stored "
                f"{meta.get('layout')}, engine expects {expected_layout}")
        priorities = meta.get("priorities", {})
        out: dict[bytes, tuple[int, dict[str, np.ndarray]]] = {}
        with np.load(npz_path) as z:
            for name in z.files:
                hexkey, path = name.split("|", 1)
                key = bytes.fromhex(hexkey)
                if key not in out:
                    out[key] = (int(priorities.get(hexkey, 0)), {})
                out[key][1][path] = z[name]
        return out
