
"""End-to-end training driver: a ~10M-param llama-family LM for a few hundred
steps on CPU, with checkpointing + resume + straggler monitoring.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200]
(~100M-scale: --arch llama3.2-1b --smoke off on real hardware; every flag of
repro.launch.train applies.)
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or []
    defaults = ["--arch", "llama3.2-1b", "--smoke", "--steps", "200",
                "--batch", "8", "--seq", "128", "--lr", "3e-3",
                "--ckpt-dir", "/tmp/repro_lm_ckpt", "--ckpt-every", "100"]
    sys.exit(main(defaults + argv))
