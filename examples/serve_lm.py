
"""Batched serving with continuous batching: requests stream through a
fixed-slot compiled step; slots refill without recompilation. Prompts are
absorbed through chunked prefill (several tokens per fused step) and each
request carries its own sampling settings (temperature / top-k / top-p /
seed; temperature 0 = greedy) plus a scheduling ``priority`` class —
higher classes are admitted first and may preempt running lower-priority
requests under pressure (see repro.serving.scheduler).

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp

import repro.core as nn
from repro.configs import get_arch
from repro.models import transformer as T
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = get_arch("llama3.2-1b").smoke()
    api = get_model(cfg)
    print(f"serving {cfg.name}: {cfg.n_layers}L d={cfg.d_model}")
    params = nn.init(lambda t: T.forward(cfg, t), jax.random.key(0),
                     jnp.zeros((1, 8), jnp.int32))
    engine = ServingEngine(api, params, max_batch=4, max_seq=128, chunk=8)

    prompts = [[1, 5, 9], [2, 6], [3, 7, 11, 13], [4, 8], [5, 9], [6, 10]]
    for i, p in enumerate(prompts):
        # even uids decode greedily, odd uids sample at temperature 0.8;
        # the last request is high-priority: it jumps the backlog (and
        # would preempt a running bulk request under pool pressure)
        engine.submit(Request(uid=i, prompt=p, max_new_tokens=12,
                              priority=2 if i == len(prompts) - 1 else 0,
                              temperature=0.0 if i % 2 == 0 else 0.8,
                              top_k=40, top_p=0.95, seed=i))

    t0 = time.time()
    done = engine.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    for r in sorted(done, key=lambda r: r.uid):
        print(f"  req {r.uid}: prompt {r.prompt} -> {r.generated}")
    print(f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.0f} tok/s with continuous batching)")
    m = engine.metrics_summary()
    print(f"mean TTFT {m['mean_ttft_s'] * 1e3:.0f}ms, "
          f"mean decode {m['mean_decode_tok_per_s']:.0f} tok/s")


if __name__ == "__main__":
    main()
