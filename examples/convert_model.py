
"""Compatibility tooling (paper §3): export a trained model to .nnp, reload
it WITHOUT the defining code, execute, query unsupported ops, and round-trip
through the mini-ONNX interchange.

Run: PYTHONPATH=src python examples/convert_model.py
"""

import tempfile
import os

import numpy as np

import repro.core as nn
import repro.core.functions as F
import repro.core.parametric as PF
from repro.fileformat import (NnpExecutor, export_model, load_nnp,
                              query_unsupported)
from repro.fileformat.onnx_mini import (export_onnx, import_onnx,
                                        unsupported_for_export)
from repro.models.cnn import lenet


def main():
    nn.clear_parameters()
    x = np.random.default_rng(0).standard_normal((1, 1, 28, 28)) \
        .astype(np.float32)

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "lenet.nnp")
        model = export_model("lenet", lambda x: lenet(x), {"x": x}, path)
        net = model.networks[0]
        print(f"exported {path} ({os.path.getsize(path) // 1024} KiB)")
        print(f"  functions: {[f.type for f in net.functions]}")
        print(f"  unsupported for reload: {query_unsupported(net)}")

        nn.clear_parameters()          # simulate a fresh process
        mf, params = load_nnp(path)
        executor = NnpExecutor(mf.network("lenet"), params)
        out = executor(x=x)[0]
        print(f"reloaded + executed: logits {out.shape}")

        print(f"  unsupported for ONNX export: "
              f"{unsupported_for_export(net)}")
        onnx = export_onnx(net, params)
        back = import_onnx(onnx)
        print(f"ONNX round-trip: {len(onnx['graph']['node'])} nodes -> "
              f"{len(back.functions)} functions re-imported")


if __name__ == "__main__":
    main()
