
"""Paper §3.3 / Listing 6: fp16 training with dynamic loss scaling, on the
eager plane — scale_grad / check_inf_or_nan_grad / update, exactly the
paper's loop.

Run: PYTHONPATH=src python examples/mixed_precision_training.py
"""

import numpy as np

import repro.core as nn
import repro.core.functions as F
import repro.core.parametric as PF
from repro.solvers import Adam


def main():
    nn.set_default_context(
        nn.get_extension_context("cpu", type_config="half"))
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((64, 16)).astype(np.float16)
    ys = rng.integers(0, 4, 64)

    x = nn.Variable((8, 16), dtype=np.float16)
    t = nn.Variable((8,), dtype=np.int32)
    h = F.relu(PF.affine(x, 32, name="fc1"))
    logits = PF.affine(h, 4, name="fc2")
    loss = F.mean(F.softmax_cross_entropy(logits, t))

    solver = Adam(alpha=1e-2)
    solver.set_parameters(nn.get_parameters())

    loss_scale, factor, interval, counter = 8.0, 2.0, 20, 0
    for step in range(60):
        i = (step * 8) % 64
        x.d = xs[i:i + 8]; t.d = ys[i:i + 8]
        loss.forward()
        solver.zero_grad()
        loss.backward(grad=loss_scale)          # paper: backward(loss_scale)
        if solver.check_inf_or_nan_grad():      # overflow -> shrink + skip
            loss_scale /= factor
            counter = 0
            print(f"step {step}: overflow, scale -> {loss_scale}")
            continue
        solver.scale_grad(1.0 / loss_scale)     # paper Listing 6
        solver.clip_grad_by_norm(1.0)
        solver.update()
        if counter > interval:                  # stable -> grow
            loss_scale *= factor
            counter = 0
        counter += 1
        if step % 10 == 0:
            print(f"step {step:3d}  loss {float(loss.data):7.4f}  "
                  f"scale {loss_scale:g}")
    print("fp16 storage dtype:",
          nn.get_parameters()["fc1/W"].dtype)


if __name__ == "__main__":
    main()
