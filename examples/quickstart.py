
"""Quickstart — the paper's Listings 1 & 4, line for line, plus the dynamic
graph (paper Figure 1 right) and the functional plane.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import repro.core as nn
import repro.core.functions as F
import repro.core.parametric as PF


def listing1():
    """Forward/Backward of the affine function (paper Listing 1)."""
    x = nn.Variable((16, 10), need_grad=True)
    y = PF.affine(x, 5)

    x.d = np.random.random(x.shape)
    y.forward()
    y.backward()

    print("Listing 1 — parameters registered:")
    for name, p in nn.get_parameters().items():
        print(f"  {name}: {p.shape}, grad set: {p.grad is not None}")


def listing4():
    """LeNet by stacking (paper Listing 4)."""
    nn.clear_parameters()
    x = nn.Variable(data=np.random.random((2, 1, 28, 28)).astype(np.float32))
    h = PF.convolution(x, 16, (5, 5), name="conv1")
    h = F.max_pooling(h, kernel=(2, 2))
    h = F.relu(h, inplace=False)
    h = PF.convolution(h, 16, (5, 5), name="conv2")
    h = F.max_pooling(h, kernel=(2, 2))
    h = F.relu(h, inplace=False)
    h = PF.affine(h, 50, name="affine3")
    h = F.relu(h, inplace=False)
    h = PF.affine(h, 10, name="affine4")
    h.forward()
    print(f"Listing 4 — LeNet logits: {h.shape}, "
          f"{nn.parameter_count():,} parameters")


def dynamic_mode():
    """One line switches to define-by-run (paper Figure 1, right block)."""
    nn.clear_parameters()
    with nn.auto_forward():
        x = nn.Variable(data=np.ones((2, 8), np.float32), need_grad=True)
        h = F.tanh(PF.affine(x, 4, name="fc"))
        # data available IMMEDIATELY, no forward() call:
        print(f"dynamic mode — h.d computed at op call: {h.d.shape}")
        F.sum(h).backward()
        print(f"dynamic mode — x.g: {np.asarray(x.g).shape}")


def functional_plane():
    """The same PF code as a pure init/apply pair (what pjit consumes)."""
    import jax
    import jax.numpy as jnp

    def model(x):
        return F.tanh(PF.dense(x, 4, name="fc"))

    params = nn.init(model, jax.random.key(0), jnp.ones((2, 8)))
    out = jax.jit(lambda p, x: nn.apply(model, p, x))(params,
                                                      jnp.ones((2, 8)))
    print(f"functional plane — params {list(params)}, out {out.shape}")


if __name__ == "__main__":
    listing1()
    listing4()
    dynamic_mode()
    functional_plane()
