"""Multi-replica router: prefix-affinity placement over R engine replicas.

Three layers: routing-policy tests drive :class:`repro.serving.router.
Router` decisions directly (cold-hash stickiness, live-cache affinity,
load escape), end-to-end tests assert the serving contract (token streams
bitwise identical to a single-replica run, affinity strictly beats random
placement on shared-prefix traffic, zero leaked blocks), and mesh tests
cover :func:`repro.launch.mesh.make_replica_meshes` device gating.
"""

import math

import jax
import jax.numpy as jnp
import pytest

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.router import POLICIES, Router, make_replica_engines

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, remat="none")

_PARAMS_CACHE: dict[str, dict] = {}


def init_params(cfg=CFG):
    if cfg.name not in _PARAMS_CACHE:
        api = get_model(cfg)
        _PARAMS_CACHE[cfg.name] = nn.init(
            lambda t: api.forward(t), jax.random.key(0),
            jnp.zeros((1, 8), jnp.int32))
    return _PARAMS_CACHE[cfg.name]


def make_replicas(n=2, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("chunk", 8)
    return make_replica_engines(get_model(CFG), init_params(), replicas=n,
                                use_meshes=False, **kw)


def family_prompt(f: int, plen: int = 32) -> list[int]:
    """One shared prefix per family f (covers plen // block_size blocks)."""
    return [1 + (7 * f + j) % (CFG.vocab_size - 1) for j in range(plen)]


def wave(n_fam: int, w: int, uid0: int, new: int = 4) -> list[Request]:
    """One request per family: shared family prefix + short unique tail."""
    return [
        Request(uid=uid0 + f,
                prompt=family_prompt(f) + [11 + (13 * f + 5 * w + j) % 89
                                           for j in range(3)],
                max_new_tokens=new)
        for f in range(n_fam)
    ]


def drive(router: Router, n_fam: int = 2, waves: int = 3) -> dict:
    """Submit `waves` arrival waves, draining between them (so live-cache
    affinity has warmed caches to aim at); returns {uid: tokens}."""
    uid = 0
    for w in range(waves):
        for r in wave(n_fam, w, uid):
            router.submit(r)
        uid += n_fam
        router.run_until_drained()
    return {r.uid: list(r.generated) for r in router.completed}


# ---------------------------------------------------------------------- #
# construction and validation
# ---------------------------------------------------------------------- #

def test_empty_and_unknown_policy_rejected():
    with pytest.raises(ValueError, match="at least one"):
        Router([])
    with pytest.raises(ValueError, match="unknown router policy"):
        Router(make_replicas(), policy="sticky")
    assert "affinity" in POLICIES


def test_heterogeneous_replicas_rejected():
    a = ServingEngine(get_model(CFG), init_params(), max_batch=2,
                      max_seq=64, chunk=8)
    b = ServingEngine(get_model(CFG), init_params(), max_batch=2,
                      max_seq=32, chunk=8)
    with pytest.raises(ValueError, match="interchangeable"):
        Router([a, b])


def test_replica_engines_tp_needs_meshes():
    with pytest.raises(ValueError, match="meshes"):
        make_replica_engines(get_model(CFG), init_params(), replicas=2,
                             tp=2, use_meshes=False)


# ---------------------------------------------------------------------- #
# routing decisions (no stepping needed)
# ---------------------------------------------------------------------- #

def test_cold_hash_keeps_a_prefix_family_together():
    router = Router(make_replicas())
    for r in wave(1, 0, 0) + wave(1, 1, 1) + wave(1, 2, 2):
        router.submit(r)
    # same family => same keys[0] => same replica, before any cache exists
    assert sorted(router.routed) == [0, 3]
    assert router.cold_affinity == 3
    assert router.affinity_hits == 0


def test_load_escape_overrides_cold_hash():
    # imbalance=0: one queued request on the hash target is already
    # "overloaded", so the second submission must take the load fallback
    router = Router(make_replicas(), imbalance=0)
    router.submit(wave(1, 0, 0)[0])
    router.submit(wave(1, 1, 1)[0])
    assert router.load_fallbacks >= 1
    assert sorted(router.routed) == [1, 1]


def test_short_prompts_route_by_load():
    router = Router(make_replicas())
    # shorter than one block (16 tokens): no prefix keys to hash
    for i in range(4):
        router.submit(Request(uid=i, prompt=[1 + i, 2, 3],
                              max_new_tokens=2))
    assert router.load_routed == 4
    assert router.routed == [2, 2]      # least-load alternates


def test_round_robin_and_seeded_random():
    rr = Router(make_replicas(), policy="round_robin")
    for i in range(4):
        rr.submit(Request(uid=i, prompt=[1 + i], max_new_tokens=2))
    assert rr.routed == [2, 2]
    picks = []
    for _ in range(2):
        rnd = Router(make_replicas(), policy="random", seed=11)
        picks.append([rnd.route(Request(uid=i, prompt=[1 + i],
                                        max_new_tokens=2))
                      for i in range(6)])
    assert picks[0] == picks[1], "same seed must route identically"


def test_observe_ttft_ewma():
    router = Router(make_replicas())
    assert all(math.isnan(t) for t in router.ewma_ttft)
    router.observe_ttft(0, 0.10)
    assert router.ewma_ttft[0] == pytest.approx(0.10)
    router.observe_ttft(0, 0.20, alpha=0.5)
    assert router.ewma_ttft[0] == pytest.approx(0.15)
    assert math.isnan(router.ewma_ttft[1])
    router.observe_ttft(1, float("nan"))    # undefined TTFTs are ignored
    assert math.isnan(router.ewma_ttft[1])


# ---------------------------------------------------------------------- #
# end-to-end serving contract
# ---------------------------------------------------------------------- #

def test_live_cache_affinity_follows_warm_replica():
    router = Router(make_replicas())
    drive(router, n_fam=1, waves=3)
    # wave 1 went cold-hash; waves 2 and 3 found the live cached prefix
    assert router.affinity_hits == 2
    assert router.affinity_hit_blocks > 0
    assert max(router.routed) == 3, "the family must stay on one replica"


def test_streams_bitwise_identical_to_single_replica():
    streams = {}
    for policy in ("affinity", "random"):
        streams[policy] = drive(Router(make_replicas(), policy=policy,
                                       seed=3))
    ref_eng = ServingEngine(get_model(CFG), init_params(), max_batch=2,
                            max_seq=64, chunk=8)
    uid = 0
    for w in range(3):
        for r in wave(2, w, uid):
            ref_eng.submit(r)
        uid += 2
        ref_eng.run_until_drained()
    ref = {r.uid: list(r.generated) for r in ref_eng.completed}
    assert streams["affinity"] == ref
    assert streams["random"] == ref


def test_affinity_beats_random_on_shared_prefix_traffic():
    runs = {}
    for policy in ("affinity", "random"):
        router = Router(make_replicas(), policy=policy, seed=3)
        drive(router)
        runs[policy] = router.metrics_summary()
    aff = runs["affinity"]["mean_prefix_hit_tokens"]
    rnd = runs["random"]["mean_prefix_hit_tokens"]
    assert aff > rnd, (
        f"affinity routing must strictly beat random placement: "
        f"{aff:.1f} vs {rnd:.1f} prefix-hit tokens/request")
    assert runs["affinity"]["affinity_hit_rate"] > 0.0


def test_zero_leaked_blocks_after_drain():
    router = Router(make_replicas())
    drive(router)
    for eng in router.engines:
        assert eng.alloc.check_conservation()
        live = {b for b in range(1, eng.num_blocks)
                if eng.alloc.refcount(b) > 0}
        # every live block is pinned by the prefix map (refcount 1), not
        # by a vanished request
        assert live <= eng.prefix.registered_blocks(), \
            f"leaked blocks: {sorted(live - eng.prefix.registered_blocks())}"
        assert all(eng.alloc.refcount(b) == 1 for b in live)
        eng.prefix.evict(eng.num_blocks)
        assert eng.alloc.free_blocks == eng.num_blocks - 1


def test_metrics_summary_aggregates_across_replicas():
    router = Router(make_replicas())
    drive(router)
    m = router.metrics_summary()
    assert m["requests"] == 6.0
    assert m["routed_total"] == 6.0
    assert m["replicas"] == 2.0
    assert m["mean_ttft_s"] > 0.0
    assert m["truncated_requests"] == 0.0
    # the cross-replica mean is request-weighted over per-replica means
    per = [e.metrics_summary() for e in router.engines if e.completed]
    want = (sum(s["mean_ttft_s"] * s["requests"] for s in per)
            / sum(s["requests"] for s in per))
    assert m["mean_ttft_s"] == pytest.approx(want)


# ---------------------------------------------------------------------- #
# replica meshes: the realized data axis
# ---------------------------------------------------------------------- #

def test_replica_meshes_validate_and_gate_on_devices():
    from repro.launch.mesh import make_replica_meshes
    with pytest.raises(ValueError):
        make_replica_meshes(0)
    with pytest.raises(ValueError):
        make_replica_meshes(2, tp=0)
    with pytest.raises(RuntimeError, match="devices"):
        make_replica_meshes(jax.device_count() + 1)


def test_replica_meshes_are_disjoint_slices():
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (REPRO_HOST_DEVICES)")
    from repro.launch.mesh import make_replica_meshes
    meshes = make_replica_meshes(2, tp=1)
    assert len(meshes) == 2
    devs = [set(m.devices.flat) for m in meshes]
    assert not (devs[0] & devs[1]), "replica meshes must not share devices"
    for m in meshes:
        assert m.axis_names == ("data", "model")
        assert m.devices.shape == (1, 1)


def test_router_over_meshed_replicas_matches_single():
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (REPRO_HOST_DEVICES)")
    engines = make_replica_engines(get_model(CFG), init_params(),
                                   replicas=2, use_meshes=True,
                                   max_batch=2, max_seq=64, chunk=8)
    streams = drive(Router(engines), n_fam=2, waves=2)
    ref = ServingEngine(get_model(CFG), init_params(), max_batch=2,
                        max_seq=64, chunk=8)
    uid = 0
    for w in range(2):
        for r in wave(2, w, uid):
            ref.submit(r)
        uid += 2
        ref.run_until_drained()
    assert streams == {r.uid: list(r.generated) for r in ref.completed}
