
"""SSD Pallas kernel + chunked oracle vs naive recurrence."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels.ssd import ref, ssd_kernel


def make(B, S, H, P, G, N, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(B, S, H, P)), dtype),
            jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32),
            jnp.asarray(-rng.uniform(0.5, 2.0, H), jnp.float32),
            jnp.asarray(rng.normal(size=(B, S, G, N)), dtype),
            jnp.asarray(rng.normal(size=(B, S, G, N)), dtype),
            jnp.asarray(rng.normal(size=H), jnp.float32))


SWEEP = [
    (1, 32, 2, 16, 1, 16, 8, jnp.float32),
    (2, 64, 4, 32, 2, 16, 16, jnp.float32),
    (1, 128, 4, 64, 1, 32, 32, jnp.float32),
    (2, 64, 4, 32, 2, 16, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,P,G,N,chunk,dtype", SWEEP)
def test_kernel_vs_naive(B, S, H, P, G, N, chunk, dtype):
    x, dt, A, Bm, Cm, D = make(B, S, H, P, G, N, dtype)
    got, hk = ssd_kernel.ssd(x, dt, A, Bm, Cm, D, chunk=chunk,
                             return_state=True, interpret=True)
    want, hr = ref.ssd_naive(x, dt, A, Bm, Cm, D, return_state=True)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               atol=tol, rtol=tol)


@given(st.integers(0, 10_000),
       st.sampled_from([8, 16, 32]),
       st.sampled_from([1, 2]))
@settings(max_examples=10, deadline=None)
def test_chunked_oracle_vs_naive_property(seed, chunk, G):
    B, S, H, P, N = 1, 64, 2, 8, 8
    x, dt, A, Bm, Cm, D = make(B, S, H, P, G, N, seed=seed)
    y1 = ref.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
    y2 = ref.ssd_naive(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-3)


def test_decode_chain_matches_scan():
    B, S, H, P, G, N = 2, 16, 2, 8, 1, 8
    x, dt, A, Bm, Cm, D = make(B, S, H, P, G, N, seed=5)
    y_ref = ref.ssd_naive(x, dt, A, Bm, Cm, D)
    h = jnp.zeros((B, H, P, N))
    outs = []
    for t in range(S):
        y_t, h = ref.ssd_decode_step(h, x[:, t], dt[:, t], A, Bm[:, t],
                                     Cm[:, t], D)
        outs.append(y_t)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(y_ref), atol=1e-4, rtol=1e-3)


def test_state_continuation():
    """Split-sequence chunked runs chain exactly via h0."""
    B, S, H, P, G, N = 1, 64, 2, 8, 1, 8
    x, dt, A, Bm, Cm, D = make(B, S, H, P, G, N, seed=9)
    full = ref.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=8)
    y1, h1 = ref.ssd_chunked(x[:, :32], dt[:, :32], A, Bm[:, :32],
                             Cm[:, :32], D, chunk=8, return_state=True)
    y2 = ref.ssd_chunked(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:],
                         D, chunk=8, h0=h1)
    np.testing.assert_allclose(
        np.concatenate([np.asarray(y1), np.asarray(y2)], 1),
        np.asarray(full), atol=1e-4, rtol=1e-3)
