"""Quantized KV pools: kernel parity ladder, fused write, engine streams.

The scheme (:mod:`repro.kernels.quant`): pools stored int8/fp8 with one
float32 absmax scale per (token slot, kv-head), quant fused into the
write scatter, dequant into the attention walk. Covered here:

* op x mode parity at quantized dtypes — xla / xla_chunked /
  pallas_interpret against the fp32 dense oracle with a per-dtype
  tolerance ladder, and pallas against xla tight (same math, the only
  difference is where the dequant runs);
* GQA/MQA head ratios, lengths exactly on / one off block edges, chunk
  widths spanning block boundaries mid-chunk;
* the fused quant write: bit-identical pools+scales across modes
  (donation-compatible), bounded round-trip error, garbage-block overrun;
* engine end-to-end: identical int8 streams across kernel modes, greedy
  stability vs unquantized pools (divergence rate bounded + reported),
  spec-decode bitwise guarantee, bytes/token accounting (<= 0.55x bf16
  at D=64), $REPRO_KV_DTYPE resolution, fp8 fallback.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.core import context as ctx
from repro.kernels import ops, quant
from repro.kernels.flash_attention import paged_attention as pa
from repro.kernels.flash_attention import ref as fa_ref
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine

QDTYPES = [jnp.int8] + ([quant.FP8_DTYPE] if quant.FP8_DTYPE else [])
QIDS = ["int8"] + (["fp8"] if quant.FP8_DTYPE else [])
# attention-output tolerance vs the fp32 oracle: int8 keeps ~0.4%
# relative error per element, fp8 e4m3 (3 mantissa bits) several times
# that — the ladder the acceptance criteria ask for
TOL = {jnp.dtype(jnp.int8): 5e-2}
if quant.FP8_DTYPE:
    TOL[jnp.dtype(quant.FP8_DTYPE)] = 1.5e-1


def rand(shape, seed, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), dtype)


def make_qpools(B, MB, bs, Hkv, D, qdtype, seed=0):
    """fp32 pools + their quantized twins + a DISJOINT page table (block
    ids unique across rows, like the real allocator hands out)."""
    NB = B * MB + 1
    kp = rand((NB, bs, Hkv, D), seed)
    vp = rand((NB, bs, Hkv, D), seed + 1)
    kq, ks = quant.quantize(kp, qdtype)
    vq, vs = quant.quantize(vp, qdtype)
    perm = np.random.default_rng(seed + 2).permutation(np.arange(1, NB))
    pages = jnp.asarray(perm[:B * MB].reshape(B, MB), jnp.int32)
    return (kp, vp), (kq, ks, vq, vs), pages


def mode_ctx(mode):
    return ctx.context_scope(dataclasses.replace(
        ctx.get_default_context(), kernels=mode))


# ---------------------------------------------------------------------- #
# quant scheme unit behavior
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("qdtype", QDTYPES, ids=QIDS)
def test_quantize_round_trip_bounded(qdtype):
    x = rand((3, 7, 2, 32), 5)
    q, s = quant.quantize(x, qdtype)
    assert q.dtype == jnp.dtype(qdtype)
    assert s.dtype == quant.SCALE_DTYPE and s.shape == x.shape[:-1]
    back = quant.dequantize(q, s)
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < (0.01 if qdtype == jnp.int8 else 0.08), rel


def test_quantize_zero_vector_is_safe():
    q, s = quant.quantize(jnp.zeros((2, 4, 8)), jnp.int8)
    assert not np.any(np.isnan(np.asarray(s)))
    np.testing.assert_array_equal(np.asarray(quant.dequantize(q, s)), 0.0)


def test_resolve_kv_dtype_names():
    assert quant.resolve_kv_dtype(None, jnp.bfloat16) == jnp.bfloat16
    assert quant.resolve_kv_dtype("native", jnp.float32) == jnp.float32
    assert quant.resolve_kv_dtype("int8", jnp.bfloat16) == jnp.int8
    assert quant.resolve_kv_dtype("bf16", jnp.float32) == jnp.bfloat16
    assert quant.is_quantized(quant.resolve_kv_dtype("int8", jnp.float32))
    assert not quant.is_quantized(jnp.bfloat16)
    with pytest.raises(ValueError):
        quant.resolve_kv_dtype("int7", jnp.float32)
    if quant.FP8_DTYPE is None:
        with pytest.warns(RuntimeWarning, match="falls back to int8"):
            assert quant.resolve_kv_dtype("fp8", jnp.float32) == jnp.int8
    else:
        got = quant.resolve_kv_dtype("fp8", jnp.float32)
        assert quant.is_quantized(got) and quant.kv_dtype_name(got) == "fp8"


# ---------------------------------------------------------------------- #
# op x mode parity ladder
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("qdtype", QDTYPES, ids=QIDS)
@pytest.mark.parametrize("bs", [4, 8])
@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2), (8, 1)])  # GQA + MQA
def test_paged_decode_quant_parity(bs, Hq, Hkv, qdtype):
    B, D, MB = 4, 32, 32 // bs
    (kp, vp), (kq, ks, vq, vs), pages = make_qpools(
        B, MB, bs, Hkv, D, qdtype, seed=bs)
    q = rand((B, 1, Hq, D), 7)
    # boundary sweep: exactly on a block edge, one before, one after, full
    lengths = jnp.asarray([bs, bs - 1, bs + 1, MB * bs], jnp.int32)
    oracle = fa_ref.paged_decode_reference(q, kp, vp, pages, lengths)
    got_x = fa_ref.paged_decode_reference(q, kq, vq, pages, lengths,
                                          k_scale=ks, v_scale=vs)
    got_p = pa.paged_decode(q, kq, vq, pages, lengths,
                            k_scale=ks, v_scale=vs, interpret=True)
    tol = TOL[jnp.dtype(qdtype)]
    # ladder rung 1: quantized output near the fp32 oracle
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(oracle),
                               atol=tol, rtol=tol)
    # rung 2: VMEM-dequant kernel tight against the gather-dequant ref
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(got_x),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("qdtype", QDTYPES, ids=QIDS)
@pytest.mark.parametrize("C", [1, 5])
@pytest.mark.parametrize("bs", [4, 8])
def test_paged_prefill_quant_parity(bs, C, qdtype):
    """Chunks spanning block boundaries mid-chunk, incl. C=1 (the decode-
    as-prefill shape the mixed step actually runs)."""
    B, Hq, Hkv, D, MB = 4, 4, 2, 32, 32 // bs
    (kp, vp), (kq, ks, vq, vs), pages = make_qpools(
        B, MB, bs, Hkv, D, qdtype, seed=10 + bs)
    q = rand((B, C, Hq, D), 13)
    pos = jnp.asarray([0, bs - 1, bs, bs + 1], jnp.int32)
    oracle = fa_ref.paged_prefill_reference(q, kp, vp, pages, pos)
    got_x = fa_ref.paged_prefill_reference(q, kq, vq, pages, pos,
                                           k_scale=ks, v_scale=vs)
    got_p = pa.paged_prefill(q, kq, vq, pages, pos,
                             k_scale=ks, v_scale=vs, interpret=True)
    tol = TOL[jnp.dtype(qdtype)]
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(oracle),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(got_x),
                               atol=1e-5, rtol=1e-5)


def test_ops_dispatch_modes_agree_quant():
    """All three CPU-runnable modes through the ops layer, same result."""
    B, bs, MB, Hq, Hkv, D = 2, 8, 4, 4, 2, 32
    _, (kq, ks, vq, vs), pages = make_qpools(B, MB, bs, Hkv, D, jnp.int8,
                                             seed=41)
    q = rand((B, 1, Hq, D), 42)
    qc = rand((B, 3, Hq, D), 43)
    lengths = jnp.asarray([7, 2 * bs], jnp.int32)
    pos = jnp.asarray([2, bs - 2], jnp.int32)
    outs_d, outs_p = [], []
    for mode in ("xla", "xla_chunked", "pallas_interpret"):
        with mode_ctx(mode):
            outs_d.append(np.asarray(ops.attention_decode_paged(
                q, kq, vq, pages, lengths, k_scale=ks, v_scale=vs)))
            outs_p.append(np.asarray(ops.attention_prefill_paged(
                qc, kq, vq, pages, pos, k_scale=ks, v_scale=vs)))
    for got_d, got_p in zip(outs_d[1:], outs_p[1:]):
        np.testing.assert_allclose(got_d, outs_d[0], atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(got_p, outs_p[0], atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------- #
# fused quant write
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("qdtype", QDTYPES, ids=QIDS)
def test_paged_write_quant_bitwise_across_modes(qdtype):
    """The Pallas fused quant-scatter and the jnp quantize-then-scatter
    must produce BIT-IDENTICAL pools and scales: the engine flips kernel
    modes between runs and the prefix digests assume the pool bytes are
    a pure function of the written tokens."""
    B, C, bs, MB, Hkv, D = 2, 5, 4, 4, 2, 16
    NB = B * MB + 1
    pool = jnp.zeros((NB, bs, Hkv, D), qdtype)
    scale = jnp.zeros((NB, bs, Hkv), quant.SCALE_DTYPE)
    new = rand((B, C, Hkv, D), 52)
    perm = np.random.default_rng(53).permutation(np.arange(1, NB))
    pages = jnp.asarray(perm[:B * MB].reshape(B, MB), jnp.int32)
    pos = jnp.asarray([3, 9], jnp.int32)
    with mode_ctx("xla"):
        want_p, want_s = ops.paged_cache_write(pool, new, pages, pos,
                                               pool_scale=scale)
    with mode_ctx("pallas_interpret"):
        got_p, got_s = ops.paged_cache_write(pool, new, pages, pos,
                                             pool_scale=scale)
    np.testing.assert_array_equal(
        np.asarray(got_p).view(np.uint8), np.asarray(want_p).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


def test_paged_write_quant_round_trip():
    """write -> dequant recovers the written tokens within int8 error."""
    B, C, bs, MB, Hkv, D = 2, 4, 4, 3, 2, 16
    NB = B * MB + 1
    pool = jnp.zeros((NB, bs, Hkv, D), jnp.int8)
    scale = jnp.zeros((NB, bs, Hkv), quant.SCALE_DTYPE)
    new = rand((B, C, Hkv, D), 60)
    pages = jnp.asarray(1 + np.arange(B * MB).reshape(B, MB), jnp.int32)
    pos = jnp.asarray([0, bs - 1], jnp.int32)
    with mode_ctx("pallas_interpret"):
        pool2, scale2 = ops.paged_cache_write(pool, new, pages, pos,
                                              pool_scale=scale)
    back = quant.dequantize(pool2, scale2)
    for b in range(B):
        for i in range(C):
            p = int(pos[b]) + i
            blk, slot = int(pages[b, p // bs]), p % bs
            np.testing.assert_allclose(
                np.asarray(back[blk, slot]), np.asarray(new[b, i]),
                atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("mode", ["xla", "pallas_interpret"])
def test_paged_write_quant_overrun_hits_garbage_block(mode):
    """The overrun->garbage-block guarantee must hold for the scale
    scatter too — an overrun scale landing in a live block would corrupt
    a neighbour's dequant even with the payload safely redirected."""
    B, C, bs, MB, Hkv, D = 1, 4, 4, 3, 2, 8
    NB = B * MB + 1
    pool = jnp.zeros((NB, bs, Hkv, D), jnp.int8)
    scale = jnp.full((NB, bs, Hkv), 7.0, quant.SCALE_DTYPE)
    new = rand((B, C, Hkv, D), 62)
    pages = jnp.asarray([[3, 1, 2]], jnp.int32)
    pos = jnp.asarray([bs * MB - 2], jnp.int32)   # tokens 2,3 overrun
    with mode_ctx(mode):
        out_p, out_s = ops.paged_cache_write(pool, new, pages, pos,
                                             pool_scale=scale)
    out_s = np.asarray(out_s)
    # in-bounds scales land in the last column's block (id 2), overruns
    # in garbage block 0; everything else keeps the 7.0 sentinel
    assert (out_s[2, bs - 2:] != 7.0).all()
    assert (out_s[0, :2] != 7.0).all()
    mask = np.ones((NB, bs), bool)
    mask[0, :2] = False
    mask[2, bs - 2:] = False
    np.testing.assert_array_equal(out_s[mask], 7.0)


# ---------------------------------------------------------------------- #
# engine end-to-end
# ---------------------------------------------------------------------- #

CFG = ModelConfig(name="qkv", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, remat="none")
HYB = ModelConfig(name="qhyb", family="hybrid", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, ssm_state=16, ssm_head_dim=32, ssm_chunk=4,
                  attn_every=2, remat="none")
# head_dim 64: the geometry the bytes-ratio acceptance bound is stated at
CFG64 = ModelConfig(name="qkv64", family="dense", n_layers=1, d_model=128,
                    n_heads=2, n_kv_heads=1, d_ff=128, vocab_size=97,
                    head_dim=64, remat="none")

_PARAMS: dict[str, dict] = {}


def init_params(cfg=CFG):
    if cfg.name not in _PARAMS:
        api = get_model(cfg)
        _PARAMS[cfg.name] = nn.init(
            lambda t: api.forward(t), jax.random.key(0),
            jnp.zeros((1, 8), jnp.int32))
    return _PARAMS[cfg.name]


def run_streams(cfg, n=5, **kw):
    eng = ServingEngine(get_model(cfg), init_params(cfg), max_batch=3,
                        max_seq=64, chunk=8, **kw)
    rng = np.random.default_rng(0)
    for uid in range(n):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(1, 96, 11 + uid).tolist(),
                           max_new_tokens=10))
    return {r.uid: r.generated for r in eng.run_until_drained()}, eng


@pytest.mark.parametrize("cfg", [CFG, HYB], ids=["dense", "hybrid"])
def test_engine_int8_streams_identical_across_modes(cfg):
    xla, e = run_streams(cfg, kv_dtype="int8")
    pi, _ = run_streams(cfg, kv_dtype="int8", kernels="pallas_interpret")
    assert e.kv_dtype == "int8"
    assert e.state["kv"]["k"].dtype == jnp.int8 if cfg is HYB else True
    assert xla == pi, "int8 streams differ between kernel modes"


def test_engine_int8_greedy_stability():
    """Quantization may flip near-tied argmaxes, but most greedy streams
    must survive intact; the divergence rate is the reported number.

    The baseline pins kv_dtype="native" so the int8 CI leg's
    REPRO_KV_DTYPE can't quantize BOTH engines and pass vacuously."""
    base, eb = run_streams(CFG, n=6, kv_dtype="native")
    q, eq = run_streams(CFG, n=6, kv_dtype="int8")
    assert eb.kv_dtype == "fp32" and eq.kv_dtype == "int8"
    div = sum(base[u] != q[u] for u in base) / len(base)
    print(f"\nint8 greedy divergence rate: {div:.2f} "
          f"({sum(base[u] != q[u] for u in base)}/{len(base)} streams)")
    assert div <= 0.5, f"int8 pools diverge {div:.0%} of greedy streams"


def test_engine_int8_spec_decode_stays_bitwise():
    plain, _ = run_streams(CFG, kv_dtype="int8")
    spec, e = run_streams(CFG, kv_dtype="int8", spec_k=3)
    assert e.spec is not None
    assert spec == plain, "speculation changed an int8 token stream"


def test_engine_scale_leaves_and_reset_safety():
    """Scale leaves exist, carry the block axis at 1, and survive slot
    admission untouched (the _admit reset must skip them — zeroing would
    corrupt every live block's dequant)."""
    _, eng = run_streams(CFG, n=4, kv_dtype="int8")
    ks = eng.state["k_scale"]
    assert ks.dtype == quant.SCALE_DTYPE
    assert ks.shape[1] == eng.num_blocks     # block axis at 1
    assert eng.state["k"].dtype == jnp.int8
    # 4 requests through 3 slots => slot reuse happened; live scales must
    # be non-zero (a zeroed scale dequantizes the whole block to 0)
    assert float(jnp.abs(ks[:, 1:]).max()) > 0.0


def test_kv_bytes_per_token_ratio_at_d64():
    """The acceptance bound: int8 pools + scales <= 0.55x the bf16 bytes
    at head_dim 64 — (D + 4) / (2D) = 0.531, from spec accounting."""
    _, e_bf = run_streams(CFG64, n=1, cache_dtype=jnp.bfloat16,
                          kv_dtype="native")
    _, e_q = run_streams(CFG64, n=1, cache_dtype=jnp.bfloat16,
                         kv_dtype="int8")
    b_bf, b_q = e_bf.kv_bytes_per_token(), e_q.kv_bytes_per_token()
    ratio = b_q / b_bf
    print(f"\nkv bytes/token: bf16={b_bf:.0f} int8={b_q:.0f} "
          f"ratio={ratio:.3f}")
    assert ratio <= 0.55, f"int8 bytes ratio {ratio:.3f} > 0.55"
    assert e_q.metrics_summary()["kv_bytes_per_token"] == b_q


def test_engine_env_var_resolution(monkeypatch):
    monkeypatch.setenv("REPRO_KV_DTYPE", "int8")
    eng = ServingEngine(get_model(CFG), init_params(CFG), max_batch=2,
                        max_seq=32, chunk=8)
    assert eng.kv_dtype == "int8"
    assert eng.state["k"].dtype == jnp.int8
    # explicit arg wins over the env
    eng2 = ServingEngine(get_model(CFG), init_params(CFG), max_batch=2,
                         max_seq=32, chunk=8, kv_dtype="native")
    assert eng2.kv_dtype == "fp32"


def test_engine_fp8_requested_always_quantizes():
    """kv_dtype=fp8 quantizes on every build: natively where float8
    exists, else falling back to int8 with a warning — never silently
    unquantized."""
    if quant.FP8_DTYPE is None:
        with pytest.warns(RuntimeWarning, match="falls back to int8"):
            _, eng = run_streams(CFG, n=2, kv_dtype="fp8")
        assert eng.kv_dtype == "int8"
    else:
        _, eng = run_streams(CFG, n=2, kv_dtype="fp8")
        assert eng.kv_dtype == "fp8"
        assert quant.is_quantized(eng.state["k"].dtype)
