"""Tensor-parallel serving: one engine spans a (data, model) mesh.

Runs in-process against forced host devices — set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (or
``REPRO_HOST_DEVICES=8``, wired through conftest) before starting pytest;
without enough devices every test here skips. CI's tp leg provides 8.

Covers the ISSUE-4 acceptance matrix: greedy-decode token equality
tp=1 vs tp=2 vs tp=4 across transformer (GQA + MQA) and hybrid families,
with prefix-cache hits in the mix; the per-device KV-pool split assertion;
kernel-mode parity (shard_map-wrapped interpret Pallas == GSPMD XLA); and
tp=1 identity with the mesh-free engine.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine

GQA = ModelConfig(name="tp-gqa", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, remat="none")
MQA = dataclasses.replace(GQA, name="tp-mqa", n_kv_heads=1)
HYBRID = ModelConfig(name="tp-hyb", family="hybrid", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                     head_dim=16, ssm_state=16, ssm_head_dim=32, ssm_chunk=4,
                     attn_every=2, remat="none")

_PARAMS_CACHE: dict[str, dict] = {}
_BASELINE_CACHE: dict[str, dict[int, list[int]]] = {}


def _needs_devices(n: int) -> None:
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} host devices, have {len(jax.devices())} — "
                    "set XLA_FLAGS=--xla_force_host_platform_device_count")


def init_params(cfg):
    if cfg.name not in _PARAMS_CACHE:
        api = get_model(cfg)
        _PARAMS_CACHE[cfg.name] = nn.init(
            lambda t: api.forward(t), jax.random.key(0),
            jnp.zeros((1, 8), jnp.int32))
    return _PARAMS_CACHE[cfg.name]


def _prompts(cfg, shared_prefix: bool = False) -> list[list[int]]:
    rng = np.random.default_rng(7)
    if shared_prefix:
        # two waves over a common 20-token prefix: wave 2 hits the prefix
        # cache (pure-KV families) while wave 1 is still a cold miss
        pre = rng.integers(1, cfg.vocab_size, 20).tolist()
        wave = [pre + rng.integers(1, cfg.vocab_size, 4).tolist()
                for _ in range(3)]
        return wave + wave
    return [rng.integers(1, cfg.vocab_size, 12).tolist() for _ in range(4)]


def run_engine(cfg, tp: int, *, kernels=None, shared_prefix=False,
               **kw) -> tuple[dict[int, list[int]], ServingEngine]:
    api = get_model(cfg)
    eng = ServingEngine(api, init_params(cfg), max_batch=2, max_seq=64,
                        chunk=8, tp=tp, kernels=kernels, **kw)
    for i, p in enumerate(_prompts(cfg, shared_prefix)):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=8))
    done = eng.run_until_drained()
    assert all(r.done for r in done) and done
    return {r.uid: r.generated for r in done}, eng


def baseline(cfg, shared_prefix: bool = False) -> dict[int, list[int]]:
    key = f"{cfg.name}/{shared_prefix}"
    if key not in _BASELINE_CACHE:
        _BASELINE_CACHE[key], _ = run_engine(cfg, tp=1,
                                             shared_prefix=shared_prefix)
    return _BASELINE_CACHE[key]


# ---------------------------------------------------------------------- #
# greedy-decode token equality across tp widths
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("tp", [2, 4])
@pytest.mark.parametrize("cfg", [GQA, MQA, HYBRID],
                         ids=["gqa", "mqa", "hybrid"])
def test_tp_greedy_matches_single_device(cfg, tp):
    """tp=2 shards the kv-head axis; tp=4 with Hkv<=2 exercises the
    replicate-KV / shard-query-heads GQA path. Both must reproduce the
    single-device greedy tokens exactly."""
    _needs_devices(tp)
    got, eng = run_engine(cfg, tp=tp)
    assert eng.tp == tp and eng.mesh is not None
    assert got == baseline(cfg)


@pytest.mark.parametrize("cfg,tp", [(GQA, 2), (MQA, 2), (HYBRID, 2),
                                    (GQA, 4), (MQA, 4)],
                         ids=["gqa-tp2", "mqa-tp2", "hybrid-tp2",
                              "gqa-tp4", "mqa-tp4"])
def test_tp_pallas_interpret_matches_xla(cfg, tp):
    """The shard_map-wrapped interpret-mode Pallas kernels produce the
    same greedy tokens as the GSPMD-partitioned XLA references — across
    all three _tp_heads_call branches: kv-head sharding (GQA tp=2),
    grouped query heads with replicated KV (MQA, and MQA tp=4), and the
    fully-replicated fallback (GQA Hkv=2 on tp=4: group=2 % 4 != 0)."""
    _needs_devices(tp)
    got, _ = run_engine(cfg, tp=tp, kernels="pallas_interpret")
    assert got == baseline(cfg)


def test_tp_prefix_cache_hits(cfg=GQA):
    """Prefix reuse stays sound under TP: the host-side prefix map is
    layout-blind (block ids are global), so hit counts AND tokens match
    the single-device engine."""
    _needs_devices(2)
    got, eng = run_engine(cfg, tp=2, shared_prefix=True)
    hits = sum(r.metrics.prefix_hit_tokens for r in eng.completed)
    assert hits > 0, "shared-prefix wave 2 must hit the prefix cache"
    assert got == baseline(cfg, shared_prefix=True)
    _, e1 = run_engine(cfg, tp=1, shared_prefix=True)
    assert hits == sum(r.metrics.prefix_hit_tokens for r in e1.completed)


# ---------------------------------------------------------------------- #
# memory layout: the pool really is split tp-ways
# ---------------------------------------------------------------------- #

def test_pool_sharded_per_device():
    """Each device holds exactly 1/tp of every KV pool: the kv-head dim of
    every addressable shard is Hkv/tp and per-device bytes are total/tp."""
    from repro.launch.serve_shardings import per_device_state_bytes
    _needs_devices(2)
    tp = 2
    _, eng = run_engine(GQA, tp=tp)
    for name in ("k", "v"):
        pool = eng.state[name]
        assert pool.sharding.spec[3] == "model"
        for shard in pool.addressable_shards:
            assert shard.data.shape[3] == GQA.n_kv_heads // tp
            assert shard.data.nbytes == pool.nbytes // tp
    total = sum(a.nbytes for a in jax.tree.leaves(eng.state))
    for dev in eng.mesh.devices.flat:
        assert per_device_state_bytes(eng.state, dev) == total // tp


def test_gqa_indivisible_kv_replicates_with_note():
    """Hkv=2 on tp=4 can't split: pools replicate (the recorded CacheSpec
    policy) while the engine still answers correctly — covered above."""
    _needs_devices(4)
    _, eng = run_engine(GQA, tp=4)
    layout = eng.tp_layout()
    assert layout["k"] == "replicated" and layout["v"] == "replicated"
    assert "replicates" in get_model(GQA).cache_spec.tp_note


def test_hybrid_ssm_state_layout_recorded():
    """Hybrid under tp=2: per-site pools shard on kv heads, SSM h on SSD
    heads; the layout report and the CacheSpec note both say so."""
    _needs_devices(2)
    _, eng = run_engine(HYBRID, tp=2)
    layout = eng.tp_layout()
    assert "'model'" in layout["kv/k"] and "'model'" in layout["kv/v"]
    assert "'model'" in layout["ssm/h"]
    assert "SSD heads" in get_model(HYBRID).cache_spec.tp_note


# ---------------------------------------------------------------------- #
# scheduler: host-side policy is layout-blind
# ---------------------------------------------------------------------- #

def _run_forced_preemption(cfg, tp: int) -> tuple[dict[int, list[int]], int]:
    """Deterministic preemption trace: a backlog of bulk requests plus a
    late high-priority arrival on a tight pool, with one *explicitly*
    forced preemption — the same host-side schedule at any tp width."""
    api = get_model(cfg)
    eng = ServingEngine(api, init_params(cfg), max_batch=2, max_seq=64,
                        chunk=8, block_size=4, num_blocks=24,
                        prefix_cache=False, tp=tp)
    rng = np.random.default_rng(11)
    for i in range(4):
        prompt = rng.integers(1, cfg.vocab_size, 20).tolist()
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=10))
    for _ in range(3):
        eng.step()
    victim = next(s for s in range(2) if eng.active[s] is not None)
    eng.scheduler.preempt(victim)       # forced, identical at any tp
    eng.submit(Request(uid=9, prompt=rng.integers(
        1, cfg.vocab_size, 8).tolist(), max_new_tokens=6, priority=3))
    done = eng.run_until_drained()
    assert eng.alloc.free_blocks == eng.num_blocks - 1, "leaked blocks"
    assert eng.alloc.check_conservation()
    return ({r.uid: r.generated for r in done}, eng.scheduler.preemptions)


@pytest.mark.parametrize("cfg", [GQA, HYBRID], ids=["gqa", "hybrid"])
def test_tp_preemption_parity(cfg):
    """Priority scheduling and preemption are host-side policy over
    global block ids: under the same forced preemption trace a tp=2
    engine emits token streams identical to tp=1, with the same
    preemption count."""
    _needs_devices(2)
    got1, n1 = _run_forced_preemption(cfg, tp=1)
    got2, n2 = _run_forced_preemption(cfg, tp=2)
    assert n1 == n2 and n1 >= 1
    assert got2 == got1


def test_tp_scheduler_constructed_identically():
    """tp=N engines build the exact same scheduler as tp=1: same pool
    geometry, same policy — the mesh never reaches the policy layer."""
    _needs_devices(2)
    e1 = ServingEngine(get_model(GQA), init_params(GQA), max_batch=2,
                       max_seq=64, chunk=8, tp=1)
    e2 = ServingEngine(get_model(GQA), init_params(GQA), max_batch=2,
                       max_seq=64, chunk=8, tp=2)
    for attr in ("num_blocks", "block_size", "max_blocks", "policy",
                 "aging_s", "preemption", "B", "max_seq"):
        assert getattr(e1.scheduler, attr) == getattr(e2.scheduler, attr)


# ---------------------------------------------------------------------- #
# tp=1 stays the single-device engine
# ---------------------------------------------------------------------- #

def test_tp1_is_identity():
    """tp=1 builds no mesh and takes the exact pre-mesh code path; its
    tokens match the default engine's bitwise (same trace, same arrays)."""
    got1, e1 = run_engine(GQA, tp=1)
    got_default, e_default = run_engine(GQA, tp=None)
    assert e1.mesh is None and e1.tp == 1 and e1.tp_layout() == {}
    assert e_default.mesh is None
    assert got1 == got_default


def test_tp_rejects_bad_width():
    with pytest.raises(ValueError, match="tp must be >= 1"):
        ServingEngine(get_model(GQA), init_params(GQA), tp=0)


def test_explicit_mesh_validated():
    """A hand-built mesh must carry a 'model' axis, and a conflicting
    tp=/mesh= pair is rejected instead of silently ignoring tp."""
    from repro.launch.mesh import make_host_mesh
    no_model = make_host_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="needs a 'model' axis"):
        ServingEngine(get_model(GQA), init_params(GQA), mesh=no_model)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="conflicts"):
        ServingEngine(get_model(GQA), init_params(GQA), mesh=mesh, tp=2)
