"""Scheduler subsystem: priority classes, aging, block-level preemption.

Two layers: pure host-side tests drive :class:`repro.serving.scheduler.
Scheduler` directly with synthetic clocks (no jax step involved — the
scheduler is layout-blind by construction), and engine-level tests check
that preempted requests resume through re-prefill with the same greedy
tokens the uninterrupted engine produces.
"""

import math

import jax
import jax.numpy as jnp
import pytest

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.scheduler import Scheduler

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, remat="none")

_PARAMS_CACHE: dict[str, dict] = {}


def init_params(cfg=CFG):
    if cfg.name not in _PARAMS_CACHE:
        api = get_model(cfg)
        _PARAMS_CACHE[cfg.name] = nn.init(
            lambda t: api.forward(t), jax.random.key(0),
            jnp.zeros((1, 8), jnp.int32))
    return _PARAMS_CACHE[cfg.name]


def make_engine(**kw):
    return ServingEngine(get_model(CFG), init_params(), **kw)


def make_sched(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("chunk", 8)
    kw.setdefault("paged", True)
    kw.setdefault("block_size", 4)
    return Scheduler(**kw)


def host_step(sched, now, chunk=8):
    """One engine step, emulated host-side: absorb prompts, append a
    dummy generated token on emit, finish completed requests."""
    sched.admit(now)
    for s, req in enumerate(list(sched.active)):
        if req is None:
            continue
        pend = sched.pending_prompt[s]
        if pend:
            k = min(chunk, len(pend))
            for _ in range(k):
                pend.popleft()
            sched.advance(s, k)
            if pend:
                continue
            sched.register_prompt_blocks(s)
        else:
            sched.advance(s, 1)
        req.generated.append(0)
        if (len(req.generated) >= req.max_new_tokens
                or sched.pos[s] >= sched.max_seq - 1):
            req.done = True
            sched.finish(s)


def host_drain(sched, now=0.0, max_steps=1000):
    for _ in range(max_steps):
        if not sched.has_work():
            return now
        now += 1.0
        host_step(sched, now)
    raise AssertionError("scheduler failed to drain")


# ---------------------------------------------------------------------- #
# queue policy (host-side)
# ---------------------------------------------------------------------- #

def test_priority_order_with_fifo_tie_break():
    sched = make_sched(max_batch=1)
    reqs = [Request(uid=i, prompt=[1 + i] * 6, max_new_tokens=2,
                    priority=p)
            for i, p in enumerate([0, 2, 1, 2, 0])]
    for t, r in enumerate(reqs):
        sched.submit(r, now=float(t))
    # admits: class 2 first (uids 1 then 3, FIFO within class), then 1,
    # then class 0 (uids 0 then 4)
    host_drain(sched, now=10.0)
    admits = sorted(reqs, key=lambda r: r.metrics.admit_t)
    assert [r.uid for r in admits] == [1, 3, 2, 0, 4]


def test_fifo_policy_admit_order():
    sched = make_sched(max_batch=1, policy="fifo")
    reqs = [Request(uid=i, prompt=[1 + i] * 6, max_new_tokens=2,
                    priority=p) for i, p in enumerate([0, 9, 3])]
    for i, r in enumerate(reqs):
        sched.submit(r, now=float(i))
    host_drain(sched, now=5.0)
    admits = [r.metrics.admit_t for r in reqs]
    assert admits == sorted(admits)     # priorities had no effect


def test_aging_boosts_starved_request():
    """With aging on, a long-waiting bulk request eventually outranks a
    fresher high-priority one; with aging off it never does."""
    for aging_s, expect_first in ((0.0, 1), (10.0, 0)):
        sched = make_sched(max_batch=1, aging_s=aging_s)
        bulk = Request(uid=0, prompt=[1] * 6, max_new_tokens=2, priority=0)
        hi = Request(uid=1, prompt=[2] * 6, max_new_tokens=2, priority=3)
        sched.submit(bulk, now=0.0)
        sched.submit(hi, now=100.0)
        # at now=100: bulk aged 100s/10s = +10 classes > 3 when aging on
        sched.admit(100.0)
        active = [r for r in sched.active if r is not None]
        assert [r.uid for r in active] == [expect_first], \
            f"aging_s={aging_s}"


def test_aging_never_reorders_within_class():
    sched = make_sched(max_batch=1, aging_s=0.5)
    reqs = [Request(uid=i, prompt=[1 + i] * 6, max_new_tokens=2)
            for i in range(4)]
    for i, r in enumerate(reqs):
        sched.submit(r, now=float(i))
    host_drain(sched, now=50.0)
    admits = [r.metrics.admit_t for r in reqs]
    assert admits == sorted(admits)


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown scheduler policy"):
        make_sched(policy="sjf")
    with pytest.raises(ValueError, match="unknown scheduler policy"):
        make_engine(scheduler="sjf")


# ---------------------------------------------------------------------- #
# preemption (host-side)
# ---------------------------------------------------------------------- #

def test_preemption_frees_blocks_and_requeues():
    # pool fits one bulk request (prompt 16 + new 8 = 6 blocks of 4);
    # 7 usable blocks
    sched = make_sched(max_batch=1, num_blocks=8, prefix_cache=False)
    bulk = Request(uid=0, prompt=list(range(1, 17)), max_new_tokens=8)
    sched.submit(bulk, now=0.0)
    host_step(sched, 1.0)               # absorb prompt chunk 1
    host_step(sched, 2.0)               # absorb chunk 2, emit 1st token
    assert sched.active[0] is bulk and len(bulk.generated) == 1
    used = sched.alloc.free_blocks
    hi = Request(uid=1, prompt=[50] * 8, max_new_tokens=4, priority=5)
    sched.submit(hi, now=3.0)           # needs 3 blocks, 1 free: preempt
    sched.admit(3.0)
    assert sched.active[0] is hi
    assert sched.preemptions == 1 and bulk.metrics.preemptions == 1
    assert not bulk.done
    # victim requeued with generated folded into its resume prompt
    assert sched.queue == [bulk]
    resume = sched._queue[0].prompt
    assert resume == bulk.prompt + bulk.generated
    # and the pool actually recovered the victim's blocks
    assert sched.alloc.free_blocks > used
    assert sched.alloc.check_conservation()
    host_drain(sched, now=4.0)
    assert bulk.done and hi.done
    assert sched.requeues == 1
    assert len(bulk.generated) == bulk.max_new_tokens
    assert sched.alloc.free_blocks == sched.num_blocks - 1


def test_no_preemption_within_equal_priority():
    sched = make_sched(max_batch=1, num_blocks=8, prefix_cache=False)
    a = Request(uid=0, prompt=list(range(1, 17)), max_new_tokens=8)
    b = Request(uid=1, prompt=[50] * 8, max_new_tokens=4)  # same class
    sched.submit(a, now=0.0)
    host_step(sched, 1.0)
    sched.submit(b, now=2.0)
    sched.admit(3.0)
    assert sched.active[0] is a         # FIFO holds; nothing preempted
    assert sched.preemptions == 0
    host_drain(sched, now=4.0)
    assert a.done and b.done and sched.preemptions == 0


def test_fifo_policy_never_preempts():
    sched = make_sched(max_batch=1, num_blocks=8, prefix_cache=False,
                       policy="fifo")
    a = Request(uid=0, prompt=list(range(1, 17)), max_new_tokens=8)
    hi = Request(uid=1, prompt=[50] * 8, max_new_tokens=4, priority=9)
    sched.submit(a, now=0.0)
    host_step(sched, 1.0)
    sched.submit(hi, now=2.0)
    sched.admit(3.0)
    assert sched.active[0] is a and sched.preemptions == 0


def test_preemption_skipped_when_it_cannot_help():
    """A doomed candidate — its need exceeds free + evictable + every
    *eligible* victim's blocks — must not evict anyone: lost work with
    no admission to show for it."""
    sched = make_sched(max_batch=2, max_seq=128, num_blocks=12,
                       prefix_cache=False)
    low = Request(uid=0, prompt=[1] * 8, max_new_tokens=4)      # 3 blocks
    peer = Request(uid=1, prompt=[2] * 12, max_new_tokens=8,    # 5 blocks
                   priority=2)
    sched.submit(low, now=0.0)
    sched.submit(peer, now=1.0)
    host_step(sched, 2.0)               # both active; 3 of 11 free
    cand = Request(uid=2, prompt=[3] * 20, max_new_tokens=8,    # 7 blocks
                   priority=2)
    sched.submit(cand, now=3.0)
    sched.admit(4.0)
    # only `low` (pri 0 < 2) is preemptible: 3 free + 3 victim = 6 < 7.
    # peer (same class as cand) is untouchable — nobody is evicted.
    assert low in sched.active and peer in sched.active
    assert sched.preemptions == 0
    host_drain(sched, now=5.0)          # completions eventually admit it
    assert cand.done and sched.preemptions == 0


def test_oversized_request_rejected_at_submit():
    sched = make_sched(max_batch=1, max_seq=128, num_blocks=8,
                       prefix_cache=False)
    big = Request(uid=1, prompt=[2] * 24, max_new_tokens=8, priority=5)
    with pytest.raises(ValueError, match="needs 8 blocks"):
        sched.submit(big, now=0.0)      # 8 > 7 usable: can never fit
    assert not sched.queue and not sched._prompt_keys


def test_preempted_victim_resumes_on_own_prefix_blocks():
    """A victim preempted after its prompt was registered re-prefills
    through prefix hits on the blocks it published itself."""
    sched = make_sched(max_batch=1, num_blocks=16)
    bulk = Request(uid=0, prompt=list(range(1, 17)), max_new_tokens=8)
    sched.submit(bulk, now=0.0)
    host_step(sched, 1.0)
    host_step(sched, 2.0)               # prompt registered, 1 token out
    sched.preempt(0, now=3.0)
    sched.admit(4.0)                    # resumes immediately (slot free)
    assert sched.active[0] is bulk
    # 16-token prompt = 4 full blocks registered; resume prompt is 17
    # tokens, hits capped below the full prompt -> 4 blocks / 16 tokens
    assert bulk.metrics.prefix_hit_tokens == 16
    host_drain(sched, now=5.0)
    assert bulk.done


def test_duplicate_inflight_uid_rejected():
    """Two in-flight requests with one uid would alias the uid-keyed
    prompt-key memo — request A could ride prefix hits licensed by B's
    keys and serve the wrong KV content. Rejected at submit; the uid is
    reusable again once the first request finishes."""
    sched = make_sched(max_batch=1)
    a = Request(uid=7, prompt=[1] * 8, max_new_tokens=2)
    sched.submit(a, now=0.0)
    with pytest.raises(ValueError, match="already in flight"):
        sched.submit(Request(uid=7, prompt=[2] * 8, max_new_tokens=2),
                     now=1.0)
    host_step(sched, 2.0)               # a is ACTIVE now, still in flight
    with pytest.raises(ValueError, match="already in flight"):
        sched.submit(Request(uid=7, prompt=[3] * 8, max_new_tokens=2),
                     now=3.0)
    host_drain(sched, now=4.0)
    sched.submit(Request(uid=7, prompt=[4] * 8, max_new_tokens=2),
                 now=9.0)               # finished: uid free again
    host_drain(sched, now=10.0)


def test_aging_never_blocks_preemption():
    """Aging grants admission precedence, not eviction immunity: a bulk
    request active for many aging periods is still preemptible by a
    higher static class (regression: effective-priority victim selection
    made old actives un-preemptible whenever aging was on)."""
    sched = make_sched(max_batch=1, num_blocks=8, prefix_cache=False,
                       aging_s=1.0)
    bulk = Request(uid=0, prompt=list(range(1, 17)), max_new_tokens=8)
    sched.submit(bulk, now=0.0)
    host_step(sched, 1.0)
    # bulk has been in the system 10 aging periods when hi arrives
    hi = Request(uid=1, prompt=[50] * 8, max_new_tokens=4, priority=5)
    sched.submit(hi, now=10.0)
    sched.admit(10.0)
    assert sched.active[0] is hi and sched.preemptions == 1
    # but an aged EQUAL-class arrival still never preempts
    host_drain(sched, now=11.0)
    sched.submit(Request(uid=2, prompt=list(range(1, 17)),
                         max_new_tokens=8), now=20.0)
    host_step(sched, 21.0)
    late = Request(uid=3, prompt=[60] * 8, max_new_tokens=4)
    sched.submit(late, now=21.5)
    sched.admit(80.0)                   # late aged +58 classes — still 0
    assert sched.active[0].uid == 2 and sched.preemptions == 1


def test_reclaimable_ignores_blocks_shared_with_peers():
    """The preemption pre-check must not count a victim's prefix-hit
    blocks that a non-victim peer still shares — preempting would not
    free them, so a candidate that can only be satisfied on paper must
    disturb nobody (regression: len(_slot_blocks) overcounting)."""
    sched = make_sched(max_batch=2, num_blocks=14)
    prompt = list(range(1, 17))         # 4 full blocks, registered
    a = Request(uid=0, prompt=prompt, max_new_tokens=8, priority=1)
    sched.submit(a, now=0.0)
    while sched.pending_prompt[0] or sched.active[0] is None:
        host_step(sched, 1.0)           # absorb + register the 4 blocks
    b = Request(uid=1, prompt=prompt, max_new_tokens=8, priority=2)
    sched.submit(b, now=2.0)
    sched.admit(3.0)                    # b shares 3 of a's prompt blocks
    assert b.metrics.prefix_hit_tokens == 12
    # candidate outranks a (pri 1) but not b. Preempting a would free
    # only its private blocks (+1 map-only block): 4 free + 2 private
    # + 1 newly-evictable = 7 reclaimable. The old len(_slot_blocks)
    # overcount said 10 — enough on paper for a 9-block candidate, so a
    # was evicted for nothing.
    cand = Request(uid=2, prompt=[70] * 28, max_new_tokens=8, priority=2)
    assert sched._entry_blocks(cand.prompt, cand) == 9
    assert sched._reclaimable(2) == 7
    assert len(sched._slot_blocks[0]) + sched.alloc.free_blocks == 10
    sched.submit(cand, now=4.0)
    sched.admit(5.0)
    assert sched.preemptions == 0, \
        "preempted a victim the candidate could not benefit from"
    assert a in sched.active and b in sched.active


def test_tickets_and_key_memos_do_not_leak():
    sched = make_sched(max_batch=2, num_blocks=16)
    reqs = [Request(uid=i, prompt=[1 + i] * 10, max_new_tokens=4)
            for i in range(6)]
    for i, r in enumerate(reqs):
        sched.submit(r, now=float(i))
    assert set(sched._prompt_keys) <= {r.uid for r in reqs}
    host_step(sched, 10.0)
    # admitted requests leave the memo the moment they leave the queue
    active_uids = {r.uid for r in sched.active if r is not None}
    assert not (set(sched._prompt_keys) & active_uids)
    host_drain(sched, now=11.0)
    assert sched._prompt_keys == {}     # nothing left behind
    assert sched._ticket == {}
    assert all(r.done for r in reqs)


# ---------------------------------------------------------------------- #
# engine-level: preemption preserves the token stream
# ---------------------------------------------------------------------- #

def test_engine_preempted_request_matches_uninterrupted():
    """Forcing a preemption mid-decode must not change the greedy tokens:
    resume-as-prefill recomputes the same KV content the victim lost."""
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]
    ref = make_engine(max_batch=1, max_seq=64, chunk=8, block_size=4)
    ref.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    want = ref.run_until_drained()[0].generated

    eng = make_engine(max_batch=1, max_seq=64, chunk=8, block_size=4)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    for _ in range(4):                  # prompt + 3 decode steps
        eng.step()
    victim = eng.active[0]
    assert victim is not None and 0 < len(victim.generated) < 8
    eng.scheduler.preempt(0)
    assert eng.active[0] is None and victim.metrics.preemptions == 1
    done = eng.run_until_drained()
    assert done[0].generated == want
    assert eng.metrics_summary()["preemptions"] == 1.0
    assert eng.alloc.check_conservation()


def test_engine_preempted_sampled_stream_continues():
    """The per-(seed, count) PRNG stream survives preemption: a resumed
    sampled request emits the same tokens as an uninterrupted run."""
    prompt = [5, 6, 7, 8]
    kw = dict(max_new_tokens=8, temperature=0.9, top_k=11, seed=123)
    ref = make_engine(max_batch=1, max_seq=64, chunk=8, block_size=4)
    ref.submit(Request(uid=0, prompt=prompt, **kw))
    want = ref.run_until_drained()[0].generated

    eng = make_engine(max_batch=1, max_seq=64, chunk=8, block_size=4)
    eng.submit(Request(uid=0, prompt=prompt, **kw))
    for _ in range(3):
        eng.step()
    eng.scheduler.preempt(0)
    assert eng.run_until_drained()[0].generated == want


def test_engine_priority_jumps_queue_end_to_end():
    """Backlogged single-slot engine: a late high-priority submit
    preempts the running bulk request, is served first, and the victim
    resumes at the head of its class — ahead of the untouched backlog."""
    eng = make_engine(max_batch=1, max_seq=64, chunk=8, block_size=4)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=[1 + i] * 6, max_new_tokens=6))
    eng.step()                          # bulk 0 occupies the only slot
    eng.submit(Request(uid=9, prompt=[60] * 6, max_new_tokens=4,
                       priority=3))
    done = eng.run_until_drained()
    order = sorted(done, key=lambda r: r.metrics.admit_t)
    # uid 0's admit_t is its RE-admission after being preempted for uid 9;
    # its original FIFO ticket still puts it before bulk 1 and 2
    assert [r.uid for r in order] == [9, 0, 1, 2]
    victim = next(r for r in done if r.uid == 0)
    assert victim.metrics.preemptions == 1
    assert len(victim.generated) == 6   # preemption lost no tokens
    m = eng.metrics_summary()
    assert m["requests"] == 4.0 and not math.isnan(m["mean_ttft_s"])
    assert m["preemptions"] == 1.0 and m["requeues"] == 1.0


def test_engine_preemption_under_pool_pressure_end_to_end():
    """The bench workload in miniature: bulk overcommits the pool, a
    high-priority arrival preempts, everyone still completes with the
    right token counts and zero leaked blocks."""
    eng = make_engine(max_batch=2, max_seq=64, chunk=8, block_size=4,
                      num_blocks=22, prefix_cache=False)
    for i in range(4):
        eng.submit(Request(uid=i, prompt=[1 + i] * 24, max_new_tokens=16))
    for _ in range(3):
        eng.step()
    eng.submit(Request(uid=100, prompt=[90] * 8, max_new_tokens=8,
                       priority=2))
    done = eng.run_until_drained()
    assert {r.uid for r in done} == {0, 1, 2, 3, 100}
    assert all(len(r.generated) == r.max_new_tokens for r in done)
    m = eng.metrics_summary()
    assert m["preemptions"] >= 1 and m["requeues"] >= 1
    hi = next(r for r in done if r.uid == 100)
    bulk_unstarted = [r for r in done if r.uid in (2, 3)]
    assert all(hi.metrics.ttft < r.metrics.ttft for r in bulk_unstarted)
    assert eng.alloc.free_blocks == eng.num_blocks - 1
    assert eng.alloc.check_conservation()


# ---------------------------------------------------------------------- #
# finish-over-evict, per-stint wait accounting, submit truncation (PR 6)
# ---------------------------------------------------------------------- #

def test_preempt_refused_near_max_seq_boundary():
    """Regression: a victim whose resume prompt (prompt + generated) no
    longer fits ``max_seq - 1`` used to be silently sliced on requeue,
    dropping its newest GENERATED tokens — the resumed stream diverged
    from an unpreempted run. Such slots must be refused by ``preempt()``
    and never offered as victims; they are about to finish anyway."""
    sched = make_sched(max_batch=1, max_seq=16, num_blocks=8,
                       prefix_cache=False)
    bulk = Request(uid=0, prompt=[1] * 12, max_new_tokens=8)
    sched.submit(bulk, now=0.0)
    host_step(sched, 1.0)               # absorb chunk 1 of the prompt
    host_step(sched, 2.0)               # finish prompt, emit 1st token
    assert sched.active[0] is bulk
    # emulate a multi-token verify step landing the slot right at the
    # finish boundary: 12 prompt + 4 generated = 16 > max_seq - 1 = 15
    bulk.generated.extend([0, 0, 0])
    assert not sched._resumable(bulk)
    with pytest.raises(ValueError, match="not preemptible"):
        sched.preempt(0, now=3.0)
    assert sched.active[0] is bulk and sched.preemptions == 0
    # pool-pressure admission must route around it too: hi outranks bulk
    # but the only victim is non-resumable -> nobody is evicted
    assert sched._victims(5) == []
    hi = Request(uid=1, prompt=[50] * 8, max_new_tokens=4, priority=5)
    sched.submit(hi, now=4.0)
    sched.admit(5.0)
    assert sched.active[0] is bulk and sched.preemptions == 0
    # the boundary itself is still preemptible: one token less fits
    bulk.generated.pop()
    assert sched._resumable(bulk)
    assert sched._victims(5) == [0]


def test_preempt_at_exact_boundary_keeps_full_stream():
    """prompt + generated == max_seq - 1 exactly: still resumable, and
    the resume prompt keeps every generated token (the old requeue path
    applied an outer ``[:max_seq - 1]`` slice that this state tickles)."""
    sched = make_sched(max_batch=1, max_seq=16, num_blocks=8,
                       prefix_cache=False)
    bulk = Request(uid=0, prompt=[1] * 12, max_new_tokens=8)
    sched.submit(bulk, now=0.0)
    host_step(sched, 1.0)
    host_step(sched, 2.0)
    bulk.generated.extend([7, 8])       # 12 + 3 = 15 == max_seq - 1
    sched.preempt(0, now=3.0)
    assert sched._queue[0].prompt == bulk.prompt + bulk.generated
    assert len(sched._queue[0].prompt) == sched.max_seq - 1


def test_queue_wait_sums_stints_not_wall_clock():
    """A preempted request's time RUNNING between stints is service, not
    wait: queue_wait must be the sum of per-stint waits, not last-admit
    minus first-submit."""
    sched = make_sched(max_batch=1, num_blocks=16, prefix_cache=False)
    bulk = Request(uid=0, prompt=[1] * 12, max_new_tokens=8)
    sched.submit(bulk, now=0.0)
    sched.admit(2.0)                    # stint 1 wait: 2s
    host_step(sched, 3.0)
    host_step(sched, 4.0)               # running 2..100 is service time
    sched.preempt(0, now=100.0)
    sched.admit(110.0)                  # stint 2 wait: 10s
    assert sched.active[0] is bulk
    assert bulk.metrics.queue_wait == pytest.approx(12.0)
    assert bulk.metrics.queued_s == pytest.approx(12.0)


def test_aging_meters_current_stint_only():
    """Regression: aging used to boost a requeued victim by its ORIGINAL
    submit time, so a fresh preemptee instantly outranked every class
    above it and thrashed the slot it was just evicted from. The clock
    must reset on requeue: a higher-class arrival beats a victim that
    has waited only seconds in its current stint."""
    sched = make_sched(max_batch=1, num_blocks=16, prefix_cache=False,
                       aging_s=10.0)
    bulk = Request(uid=0, prompt=[1] * 12, max_new_tokens=8)
    sched.submit(bulk, now=0.0)
    host_step(sched, 1.0)
    host_step(sched, 2.0)
    sched.preempt(0, now=100.0)         # requeued with enq_t=100
    mid = Request(uid=1, prompt=[50] * 8, max_new_tokens=4, priority=1)
    sched.submit(mid, now=100.0)
    # at now=105 the victim's CURRENT stint is 5s = 0 aged classes; under
    # the old accounting it had "waited" 105s = +10 classes and would win
    sched.admit(105.0)
    assert sched.active[0] is mid
    e = next(e for e in sched._queue if e.req is bulk)
    assert e.enq_t == 100.0
    host_drain(sched, now=106.0)
    assert bulk.done and mid.done


def test_submit_truncation_warns_and_marks_request():
    sched = make_sched(max_batch=1, max_seq=16)
    long_req = Request(uid=0, prompt=[1] * 40, max_new_tokens=4)
    with pytest.warns(RuntimeWarning, match=r"40 tokens truncated to 15"):
        sched.submit(long_req, now=0.0)
    assert long_req.truncated
    assert len(sched._queue[0].prompt) == 15
    import warnings as _warnings
    short = Request(uid=1, prompt=[2] * 8, max_new_tokens=4)
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")     # any warning -> test failure
        sched.submit(short, now=1.0)
    assert not short.truncated

# ---------------------------------------------------------------------- #
# would_admit: the pure admission probe (frontend backpressure signal)
# ---------------------------------------------------------------------- #

def sched_snapshot(sched):
    """Everything a pure probe must leave untouched."""
    return (sched.alloc.free_blocks if sched.paged else None,
            len(sched._queue), list(sched.active), list(sched._placing),
            dict(sched._ticket),
            sched.prefix.stats() if getattr(sched, "prefix", None) is not None
            and hasattr(sched.prefix, "stats") else None)


def test_would_admit_true_then_admit_places():
    sched = make_sched(max_batch=1, num_blocks=12)
    req = Request(uid=0, prompt=[1] * 8, max_new_tokens=4)
    assert sched.would_admit(req)
    sched.submit(req, now=0.0)
    sched.admit(0.0)
    assert sched.active[0] is req


def test_would_admit_false_when_pool_can_never_fit():
    # 3 usable 4-token blocks = 12 tokens; the request writes 24
    sched = make_sched(max_batch=1, num_blocks=4)
    req = Request(uid=0, prompt=[1] * 20, max_new_tokens=4)
    assert not sched.would_admit(req)
    with pytest.raises(ValueError, match="blocks"):
        sched.submit(req, now=0.0)


def test_would_admit_tracks_slot_occupancy():
    sched = make_sched(max_batch=1, num_blocks=16)
    a = Request(uid=0, prompt=[1] * 8, max_new_tokens=8)
    sched.submit(a, now=0.0)
    sched.admit(0.0)
    b = Request(uid=1, prompt=[2] * 8, max_new_tokens=4)
    # equal priority: no slot, no victims
    assert not sched.would_admit(b)
    sched.finish(0)
    assert sched.would_admit(b)


def test_would_admit_sees_preemption_headroom():
    for preemption, want in ((True, True), (False, False)):
        sched = make_sched(max_batch=1, num_blocks=12,
                           preemption=preemption)
        low = Request(uid=0, prompt=[1] * 8, max_new_tokens=8, priority=0)
        sched.submit(low, now=0.0)
        sched.admit(0.0)
        hi = Request(uid=1, prompt=[2] * 8, max_new_tokens=4, priority=2)
        assert sched.would_admit(hi) is want, \
            f"preemption={preemption}: probe must mirror admit behavior"


def test_would_admit_mutates_nothing():
    sched = make_sched(max_batch=2, num_blocks=12)
    a = Request(uid=0, prompt=[1] * 8, max_new_tokens=8)
    sched.submit(a, now=0.0)
    sched.admit(0.0)
    before = sched_snapshot(sched)
    # probe across the whole outcome space: admitted, queued-for-pool,
    # flat-out impossible — none may leave a trace
    sched.would_admit(Request(uid=1, prompt=[2] * 4, max_new_tokens=4))
    sched.would_admit(Request(uid=2, prompt=[3] * 30, max_new_tokens=30))
    sched.would_admit(Request(uid=3, prompt=[4] * 8, max_new_tokens=4,
                              priority=3))
    assert sched_snapshot(sched) == before


def test_would_admit_probes_unsubmitted_requests():
    # the frontend probes BEFORE submit: the request has no ticket, no
    # key memo, no metrics — the probe must not require any of them
    sched = make_sched(max_batch=1, num_blocks=12)
    req = Request(uid=7, prompt=[1] * 8, max_new_tokens=4)
    assert sched.would_admit(req)
    assert req.uid not in sched._ticket
    assert not req.truncated


def test_queue_depth_property():
    sched = make_sched(max_batch=1, num_blocks=16)
    assert sched.queue_depth == 0
    sched.submit(Request(uid=0, prompt=[1] * 4, max_new_tokens=2), now=0.0)
    sched.submit(Request(uid=1, prompt=[2] * 4, max_new_tokens=2), now=0.0)
    assert sched.queue_depth == 2
    sched.admit(0.0)
    assert sched.queue_depth == 1
