import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_in_subprocess(code: str, devices: int = 1, timeout: int = 300) -> str:
    """Run python code in a fresh process with N host devices.

    Multi-device tests must not pollute this process's jax device count
    (smoke tests and benches must keep seeing 1 device).
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices > 1:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={devices}").strip()
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_in_subprocess


@pytest.fixture(autouse=True)
def _fresh_registry():
    """Each test sees an empty global parameter registry."""
    import repro.core as nn
    nn.clear_parameters()
    yield
    nn.clear_parameters()
