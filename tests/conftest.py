import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# REPRO_KERNELS=<mode> pins the default-context kernel mode for the whole
# test run (CI's pallas-interpret leg re-runs the kernel/serving subset
# with the interpret-mode Pallas kernels instead of the XLA references).
_KERNELS_ENV = os.environ.get("REPRO_KERNELS")
if _KERNELS_ENV:
    import dataclasses

    from repro.core import context as _ctx
    if _KERNELS_ENV not in _ctx.KERNEL_MODES:
        raise SystemExit(f"REPRO_KERNELS={_KERNELS_ENV!r} is not a kernel "
                         f"mode; one of {_ctx.KERNEL_MODES}")
    _ctx.set_default_context(dataclasses.replace(
        _ctx.get_default_context(), kernels=_KERNELS_ENV))


def run_in_subprocess(code: str, devices: int = 1, timeout: int = 300) -> str:
    """Run python code in a fresh process with N host devices.

    Multi-device tests must not pollute this process's jax device count
    (smoke tests and benches must keep seeing 1 device).
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices > 1:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={devices}").strip()
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_in_subprocess


@pytest.fixture(autouse=True)
def _fresh_registry():
    """Each test sees an empty global parameter registry."""
    import repro.core as nn
    nn.clear_parameters()
    yield
    nn.clear_parameters()
