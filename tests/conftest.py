import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# REPRO_HOST_DEVICES=<n> forces N XLA host devices for the whole run (CI's
# tp leg runs the distributed/serving/TP subset on 8). Must land in
# XLA_FLAGS here, before anything initializes a jax backend; tests gate on
# len(jax.devices()) and skip when the flag isn't set.
_HOST_DEVS = os.environ.get("REPRO_HOST_DEVICES")
if _HOST_DEVS and "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={int(_HOST_DEVS)}"
    ).strip()

# REPRO_KERNELS=<mode> pins the default-context kernel mode for the whole
# test run (CI's pallas-interpret leg re-runs the kernel/serving subset
# with the interpret-mode Pallas kernels instead of the XLA references).
_KERNELS_ENV = os.environ.get("REPRO_KERNELS")
if _KERNELS_ENV:
    import dataclasses

    from repro.core import context as _ctx
    if _KERNELS_ENV not in _ctx.KERNEL_MODES:
        raise SystemExit(f"REPRO_KERNELS={_KERNELS_ENV!r} is not a kernel "
                         f"mode; one of {_ctx.KERNEL_MODES}")
    _ctx.set_default_context(dataclasses.replace(
        _ctx.get_default_context(), kernels=_KERNELS_ENV))


def run_in_subprocess(code: str, devices: int = 1, timeout: int = 300) -> str:
    """Run python code in a fresh process with N host devices.

    Multi-device tests must not pollute this process's jax device count
    (smoke tests and benches must keep seeing 1 device).
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices > 1:
        # drop any inherited count (e.g. the tp CI leg's REPRO_HOST_DEVICES
        # wiring above) so the subprocess sees exactly `devices`
        flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                       env.get("XLA_FLAGS", ""))
        env["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={devices}"
        ).strip()
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_in_subprocess


@pytest.fixture(autouse=True)
def _fresh_registry():
    """Each test sees an empty global parameter registry."""
    import repro.core as nn
    nn.clear_parameters()
    yield
    nn.clear_parameters()
