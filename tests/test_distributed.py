"""Distributed correctness, in subprocesses with forced host devices
(this process must keep seeing 1 device).

Covers: DP gradients == single-device gradients; communicator collectives;
compressed all-reduce accuracy; pipeline parallelism == sequential; elastic
checkpoint reshard; sharding rule engine behaviour.
"""

import numpy as np
import pytest

from repro.distributed.sharding import ShardingEnv, param_spec, sharding_env, spec_for


def test_sharding_rules_degrade_on_indivisible():
    import jax
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))
    env = ShardingEnv(mesh=mesh, axis_rules={"heads": "model"},
                      param_rules=[(r"w$", ("heads", None))])
    with sharding_env(env):
        # 8 heads % 1 == 0 -> sharded; 7 % 2 would degrade (simulated below)
        assert param_spec("layer/w", (8, 4)) == P("model", None)

    mesh2 = jax.make_mesh((1,), ("model",))
    env2 = ShardingEnv(mesh=mesh2, axis_rules={"heads": "model"})
    with sharding_env(env2):
        assert spec_for(("heads",), (8,)) == P("model")


def test_make_host_mesh_rejects_insufficient_devices():
    """make_host_mesh must raise the same loud "needs N, have M" error as
    make_production_mesh instead of silently slicing jax.devices()[:n]
    into a wrong-sized mesh."""
    import jax
    from repro.launch.mesh import make_host_mesh
    have = len(jax.devices())
    with pytest.raises(RuntimeError,
                       match=f"needs {8 * have} devices, have {have}"):
        make_host_mesh((8 * have,), ("data",))
    # exact fit still works
    mesh = make_host_mesh((have,), ("data",))
    assert mesh.shape["data"] == have


def test_make_serving_mesh_shape():
    import jax
    from repro.launch.mesh import make_serving_mesh
    mesh = make_serving_mesh(1)
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["model"] == 1 and mesh.shape["data"] == 1
    with pytest.raises(ValueError, match="tp must be >= 1"):
        make_serving_mesh(0)
    with pytest.raises(RuntimeError, match="needs"):
        make_serving_mesh(8 * len(jax.devices()))


def test_duplicate_mesh_axis_dropped():
    import jax
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))
    env = ShardingEnv(mesh=mesh, axis_rules={"a": "model", "b": "model"})
    with sharding_env(env):
        # both dims want 'model'; second use must degrade to None
        assert spec_for(("a", "b"), (4, 4)) == P("model", None)


def test_stacked_param_rule_padding():
    import jax
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))
    env = ShardingEnv(mesh=mesh,
                      axis_rules={"mlp": "model", "layers": None},
                      param_rules=[(r"kernel$", ("embed", "mlp"))])
    with sharding_env(env):
        # stacked (L, d, ff) gets a leading "layers" pad
        assert param_spec("layers/mlp/kernel", (4, 8, 16)) == \
            P(None, None, "model")


DP_GRADS_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
import repro.core as nn
import repro.core.parametric as PF
import repro.core.functions as F

assert len(jax.devices()) == 8, jax.devices()
mesh = jax.make_mesh((8,), ("data",))

def model(tokens, labels):
    h = PF.embed(tokens, 64, 16, name="emb")
    h = PF.dense(h, 64, name="out")
    return jnp.mean(F.softmax_cross_entropy(h, labels))

rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 64, (16, 8)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 64, (16, 8)), jnp.int32)}
params = nn.init(model, jax.random.key(0), batch["tokens"], batch["labels"])

def loss(p, b):
    return nn.apply(model, p, b["tokens"], b["labels"])

# single device
g_ref = jax.grad(loss)(params, batch)

# data-parallel over 8 host devices
bs = {k: NamedSharding(mesh, P("data")) for k in batch}
ps = {k: NamedSharding(mesh, P()) for k in params}
g_dp = jax.jit(jax.grad(loss), in_shardings=(ps, bs),
               out_shardings=ps)(params, batch)
for k in g_ref:
    np.testing.assert_allclose(np.asarray(g_ref[k]), np.asarray(g_dp[k]),
                               rtol=2e-5, atol=2e-6)
print("DP-GRADS-OK")
"""


def test_dp_grads_match_single_device(subproc):
    out = subproc(DP_GRADS_CODE, devices=8)
    assert "DP-GRADS-OK" in out


COMM_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.comm import Communicator, compressed_all_reduce

mesh = jax.make_mesh((8,), ("data",))
comm = Communicator(mesh, axis="data")
x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)

# all_reduce inside shard_map == global sum
f = shard_map(lambda v: comm.all_reduce(v), mesh=mesh,
              in_specs=P("data"), out_specs=P("data"), check_rep=False)
y = f(x)
want = np.tile(np.asarray(x).sum(0, keepdims=True), (8, 1))
np.testing.assert_allclose(np.asarray(y), want, rtol=1e-6)

# reduce_scatter + all_gather == all_reduce (scatter over the wide axis)
x2 = jnp.arange(8 * 32, dtype=jnp.float32).reshape(8, 32)
g = shard_map(lambda v: comm.all_gather(comm.reduce_scatter(v, axis=1),
                                        axis=1),
              mesh=mesh, in_specs=P("data"), out_specs=P("data"),
              check_rep=False)
want2 = np.tile(np.asarray(x2).sum(0, keepdims=True), (8, 1))
np.testing.assert_allclose(np.asarray(g(x2)), want2, rtol=1e-6)

# compressed all-reduce: int8 within quantization error, bf16 within eps
rng = np.random.default_rng(0)
v = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
exact = np.asarray(v).mean(0)
for method, tol in (("bf16", 2e-2), ("int8", 3e-2)):
    h = shard_map(lambda z: compressed_all_reduce(z, "data", method=method),
                  mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                  check_rep=False)
    got = np.asarray(h(v))[0]
    scale = np.abs(exact).max() + 1e-9
    assert np.abs(got - exact).max() / scale < tol, (method, np.abs(got-exact).max())
print("COMM-OK")
"""


def test_communicator_collectives(subproc):
    out = subproc(COMM_CODE, devices=8)
    assert "COMM-OK" in out


PIPELINE_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.pipeline import make_pipeline_fn

mesh = jax.make_mesh((4,), ("pod",))
S, M, MB, D = 4, 8, 2, 16
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
bs = jnp.asarray(rng.normal(size=(S, D)) * 0.1, jnp.float32)
x = jnp.asarray(rng.normal(size=(M, MB, D)), jnp.float32)

def stage_fn(params, h, stage_idx):
    W, b = params
    return jnp.tanh(h @ W + b)

pipe = make_pipeline_fn(stage_fn, mesh, n_micro=M, axis="pod")
Wsh = jax.device_put(Ws, NamedSharding(mesh, P("pod")))
bsh = jax.device_put(bs, NamedSharding(mesh, P("pod")))
got = pipe((Wsh, bsh), x)

# sequential reference
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ Ws[s] + bs[s])
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=1e-5, atol=1e-5)

# differentiable: grad through the pipeline runs
gfn = jax.grad(lambda W, b, xx: jnp.sum(pipe((W, b), xx) ** 2),
               argnums=0)
g = gfn(Wsh, bsh, x)
assert np.isfinite(np.asarray(g)).all()
print("PIPE-OK")
"""


def test_pipeline_parallel_matches_sequential(subproc):
    out = subproc(PIPELINE_CODE, devices=4)
    assert "PIPE-OK" in out


ELASTIC_CODE = """
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager

# save while sharded over 8 devices; restore re-sharded over 4 (elastic)
mesh8 = jax.make_mesh((8,), ("data",))
x = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                   NamedSharding(mesh8, P("data")))
state = {"w": x}
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(1, state)
    mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
    sh4 = {"w": NamedSharding(mesh4, P("data"))}
    got = mgr.restore(1, {"w": np.zeros((8, 8), np.float32)}, shardings=sh4)
    assert got["w"].sharding.mesh.shape["data"] == 4
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(x))
print("ELASTIC-OK")
"""


def test_elastic_reshard_restore(subproc):
    out = subproc(ELASTIC_CODE, devices=8)
    assert "ELASTIC-OK" in out


MOE_EP_CODE = """
import jax, jax.numpy as jnp, numpy as np
import dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
import repro.core as nn
from repro.configs import ARCHS
from repro.models.registry import get_model
from repro.distributed.sharding import ShardingEnv, sharding_env

# expert-parallel MoE == single-device MoE (same params, same batch)
cfg = dataclasses.replace(ARCHS["granite-moe-1b-a400m"].smoke(), remat="none")
api = get_model(cfg)
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 32)), jnp.int32)
params = nn.init(lambda t: api.forward(t), jax.random.key(0), toks)
ref, _ = nn.apply(lambda t: api.forward(t), params, toks)

mesh = jax.make_mesh((2, 4), ("data", "model"))
env = ShardingEnv(mesh=mesh,
                  axis_rules={"batch": "data", "expert": "model",
                              "expert_group": "data"},
                  param_rules=[(r"_wi_(gate|up)$", ("expert", None, None)),
                               (r"_wo$", ("expert", None, None))])
from repro.distributed.sharding import param_spec
with sharding_env(env):
    psh = {k: NamedSharding(mesh, param_spec(k, tuple(v.shape)))
           for k, v in params.items()}
    f = jax.jit(lambda p, t: nn.apply(lambda tt: api.forward(tt), p, t)[0],
                in_shardings=(psh, NamedSharding(mesh, P("data"))))
    got = f(params, toks)
np.testing.assert_allclose(np.asarray(ref, np.float32),
                           np.asarray(got, np.float32), atol=3e-2, rtol=3e-2)
print("MOE-EP-OK")
"""


def test_moe_expert_parallel_matches_single(subproc):
    out = subproc(MOE_EP_CODE, devices=8)
    assert "MOE-EP-OK" in out
