
"""Straggler monitor + elastic mesh policy."""

import pytest

from repro.distributed.resilience import ElasticPolicy, StragglerMonitor


def test_steady_state_no_flags():
    m = StragglerMonitor(warmup=4)
    assert not any(m.observe(1.0 + 0.01 * (i % 3)).is_straggler
                   for i in range(50))


def test_sustained_slowdown_flagged():
    m = StragglerMonitor(warmup=4, patience=3, sigma=4.0)
    for _ in range(20):
        m.observe(1.0)
    flags = [m.observe(3.0).is_straggler for _ in range(5)]
    assert any(flags)


def test_single_spike_not_flagged():
    m = StragglerMonitor(warmup=4, patience=3)
    for _ in range(20):
        m.observe(1.0)
    assert not m.observe(5.0).is_straggler  # needs patience in a row
    assert not m.observe(1.0).is_straggler


def test_elastic_policy_contracts():
    pol = ElasticPolicy(model_axis=16)
    full = pol.choose(256)
    assert full.shape == (16, 16)
    after_loss = pol.choose(240)      # lost a host worth of chips
    assert after_loss.chips <= 240
    assert after_loss.shape == (8, 16)
    tiny = pol.choose(8)
    assert tiny.chips == 8


def test_elastic_policy_raises_when_infeasible():
    pol = ElasticPolicy(model_axis=16, min_data=2)
    with pytest.raises(RuntimeError):
        pol.choose(16)
