
"""Straggler monitor + elastic mesh policy.

PR 8 additions: the two resilience primitives against their real
consumers — :class:`ElasticPolicy` choices must be realizable as the
disjoint replica meshes :func:`make_replica_meshes` carves, and
:class:`StragglerMonitor` must drive the router health machine
(HEALTHY <-> SUSPECT) without ever shrinking the routing pool on its
own (only hard step-deadline overruns escalate to DEAD).
"""

import jax
import pytest

from repro.distributed.resilience import ElasticPolicy, StragglerMonitor
from repro.serving.router import DEAD, HEALTHY, SUSPECT, Router


def test_steady_state_no_flags():
    m = StragglerMonitor(warmup=4)
    assert not any(m.observe(1.0 + 0.01 * (i % 3)).is_straggler
                   for i in range(50))


def test_sustained_slowdown_flagged():
    m = StragglerMonitor(warmup=4, patience=3, sigma=4.0)
    for _ in range(20):
        m.observe(1.0)
    flags = [m.observe(3.0).is_straggler for _ in range(5)]
    assert any(flags)


def test_single_spike_not_flagged():
    m = StragglerMonitor(warmup=4, patience=3)
    for _ in range(20):
        m.observe(1.0)
    assert not m.observe(5.0).is_straggler  # needs patience in a row
    assert not m.observe(1.0).is_straggler


def test_elastic_policy_contracts():
    pol = ElasticPolicy(model_axis=16)
    full = pol.choose(256)
    assert full.shape == (16, 16)
    after_loss = pol.choose(240)      # lost a host worth of chips
    assert after_loss.chips <= 240
    assert after_loss.shape == (8, 16)
    tiny = pol.choose(8)
    assert tiny.chips == 8


def test_elastic_policy_raises_when_infeasible():
    pol = ElasticPolicy(model_axis=16, min_data=2)
    with pytest.raises(RuntimeError):
        pol.choose(16)


def test_elastic_policy_never_overcommits_survivors():
    # pure property: whatever the loss, the chosen mesh fits on what is
    # left, keeps power-of-two axes, and preserves the model axis while
    # survivors can still hold it
    pol = ElasticPolicy(model_axis=4)
    for chips in range(1, 65):
        c = pol.choose(chips)
        data, model = c.shape
        assert c.chips == data * model <= chips
        assert data & (data - 1) == 0 and model & (model - 1) == 0
        if chips >= 4:
            assert model == 4


def test_elastic_policy_shapes_realizable_as_replica_meshes():
    # the policy's (data, model) choice is not abstract: data = replica
    # count, model = tp, and make_replica_meshes must be able to carve
    # exactly that many disjoint (1, tp) slices out of the survivors
    if jax.device_count() < 8:
        pytest.skip("needs >= 8 devices (REPRO_HOST_DEVICES)")
    from repro.launch.mesh import make_replica_meshes
    tp = 2
    pol = ElasticPolicy(model_axis=tp)
    for survivors in (4, 3, 2, 1):     # replicas left after deaths
        choice = pol.choose(survivors * tp)
        data, model = choice.shape
        assert model == tp             # model axis survives replica loss
        assert data <= survivors
        meshes = make_replica_meshes(data, tp=model)
        assert len(meshes) == data
        seen: set = set()
        for m in meshes:
            assert m.devices.shape == (1, model)
            assert m.axis_names == ("data", "model")
            devs = set(m.devices.flat)
            assert not (devs & seen), "replica meshes must be disjoint"
            seen |= devs
        assert len(seen) == choice.chips <= survivors * tp


class _Replica:
    """Just enough engine surface for Router health bookkeeping."""
    max_seq = 64
    paged = False
    block_size = 8

    class scheduler:
        prefix = None


def _warmed_router(**kw) -> Router:
    r = Router([_Replica(), _Replica()], policy="round_robin", **kw)
    # jittered fast steps: the EWMA needs real variance before z-scores
    # mean anything (constant inputs leave ewvar at zero)
    for i in range(30):
        r.record_step_time(0, 0.010 + (i % 3) * 0.0005)
        r.record_step_time(1, 0.010 + (i % 3) * 0.0005)
    return r


def test_straggler_verdict_suspects_but_never_sheds():
    # sustained slowness *below* the hard deadline: the monitor flags,
    # the router marks SUSPECT — and keeps routing there (SUSPECT is
    # diagnostic; only DEAD shrinks the pool)
    r = _warmed_router(step_deadline_s=30.0)
    for _ in range(10):
        r.record_step_time(0, 0.2)
    assert r.health[0] == SUSPECT
    assert "straggler" in r.health_reason[0]
    assert r.alive() == [0, 1]
    # back to nominal speed: heals without a probe cycle
    for i in range(5):
        r.record_step_time(0, 0.010 + (i % 3) * 0.0005)
    assert r.health[0] == HEALTHY
    assert r.health_reason[0] == ""


def test_deadline_overrun_escalates_and_readmit_resets_watchdog():
    r = _warmed_router(step_deadline_s=0.1)
    n_before = r.watchdog[0].n
    r.record_step_time(0, 0.5)         # first overrun: strike
    assert r.health[0] == SUSPECT
    r.record_step_time(0, 0.5)         # second consecutive: dead
    assert r.health[0] == DEAD
    assert "sustained" in r.health_reason[0]
    assert r.alive() == [1]
    r.readmit(0)
    assert r.health[0] == HEALTHY
    assert r.alive() == [0, 1]
    # the statistics that condemned it are stale — readmission must not
    # inherit them
    assert r.watchdog[0].n == 0 < n_before
    assert r.watchdog[1].n == n_before   # untouched replica keeps its history
