"""Property tests for the scheduler: random submit / preempt / resume /
speculative-commit / complete interleavings against the real
:class:`~repro.serving.scheduler.Scheduler` (host-side only — no jitted
step involved), checking the invariants the serving engine's correctness
rests on:

* zero leaked references, always: every live block's refcount equals the
  number of slot page tables holding it plus one if the prefix map pins
  it — and after a full drain + prefix flush the pool is fully free;
* a preempted victim's *private* (unregistered) blocks go straight back
  to the free list and are never pinned by the prefix cache;
* pool conservation after every operation.

The driver is a plain seeded function so a couple of fixed seeds run
even without hypothesis (the deterministic smoke below); with hypothesis
installed, the sibling ``@given`` test explores the interleaving space.
Companion to ``test_paged_allocator_props.py``, which drives the raw
allocator.
"""

import random
from collections import Counter

import pytest

from repro.serving.engine import Request
from repro.serving.scheduler import Scheduler


def assert_no_leaks(sched: Scheduler) -> None:
    """Every allocator reference is accounted for by exactly one owner:
    a slot's page-table block list, or the prefix map (one ref each)."""
    want = Counter()
    for blocks in sched._slot_blocks:
        want.update(blocks)
    if sched.prefix is not None:
        want.update(sched.prefix._map.values())
    assert sched.alloc.live_blocks == len(want), \
        f"live {sched.alloc.live_blocks} != owned {len(want)}"
    for bid, n in want.items():
        assert sched.alloc.refcount(bid) == n, \
            f"block {bid}: refcount {sched.alloc.refcount(bid)} != {n} owners"
    assert sched.alloc.check_conservation()


def preempt_checked(sched: Scheduler, slot: int, now: float) -> None:
    """Preempt ``slot`` and assert its private blocks are immediately
    free and unpinned by the prefix map."""
    registered = (set(sched.prefix._map.values())
                  if sched.prefix is not None else set())
    private = [b for b in sched._slot_blocks[slot] if b not in registered]
    sched.preempt(slot, now)
    for b in private:
        assert sched.alloc.refcount(b) == 0, \
            f"preempted victim's private block {b} still referenced"
    if sched.prefix is not None:
        assert not (set(private) & set(sched.prefix._map.values())), \
            "prefix cache pinned a preempted victim's private block"


def drive(seed: int, num_blocks: int, max_batch: int = 3,
          n_ops: int = 120) -> None:
    """Random interleaving of submit / step / preempt / finish against a
    tight pool, with the leak invariants checked after every operation."""
    rng = random.Random(seed)
    sched = Scheduler(max_batch=max_batch, max_seq=64, chunk=8,
                      paged=True, block_size=4, num_blocks=num_blocks,
                      prefix_cache=bool(seed % 2), aging_s=0.25)
    uid = 0
    reqs: list[Request] = []
    usable = num_blocks - 1

    def active_slots():
        return [s for s, r in enumerate(sched.active) if r is not None]

    def host_step(now):
        sched.admit(now)
        for s in active_slots():
            req = sched.active[s]
            pend = sched.pending_prompt[s]
            if pend:
                k = min(8, len(pend))
                for _ in range(k):
                    pend.popleft()
                sched.advance(s, k)
                if pend:
                    continue
                sched.register_prompt_blocks(s)
                req.generated.append(rng.randrange(50))
            else:
                # decode — about half the steps resolve as a speculative
                # verify window (engine cap arithmetic, random accepted
                # prefix) instead of a single token: accept/reject
                # bookkeeping is pure pos arithmetic and must be
                # invisible to every block/refcount invariant
                cap = min(3, req.max_new_tokens - len(req.generated) - 1,
                          sched.max_seq - 2 - int(sched.pos[s]))
                k = rng.randrange(0, cap + 1) \
                    if cap > 0 and rng.random() < 0.5 else 0
                kept = rng.randrange(0, k + 1)
                sched.commit_spec(s, k, kept)
                req.generated.extend(
                    rng.randrange(50) for _ in range(1 + kept))
            if (len(req.generated) >= req.max_new_tokens
                    or sched.pos[s] >= sched.max_seq - 1):
                req.done = True
                sched.finish(s)

    now = 0.0
    for _ in range(n_ops):
        now += rng.random()
        op = rng.random()
        if op < 0.35:
            # shared short prefixes so the prefix map actually gets hits
            plen = rng.choice([4, 6, 8, 8, 12, 16])
            prompt = [1 + (j % 5) for j in range(plen)] if rng.random() < .5 \
                else [rng.randrange(1, 90) for _ in range(plen)]
            req = Request(uid=uid, prompt=prompt,
                          max_new_tokens=rng.randrange(1, 9),
                          priority=rng.randrange(0, 3))
            try:
                sched.submit(req, now)
                reqs.append(req)
                uid += 1
            except ValueError:
                pass                    # oversized for this pool: fine
        elif op < 0.75:
            host_step(now)
        elif op < 0.9 and active_slots():
            preempt_checked(sched, rng.choice(active_slots()), now)
        elif sched.prefix is not None:
            sched.prefix.evict(rng.randrange(0, 4))
        assert_no_leaks(sched)

    # drain everything; the scheduler must terminate and leak nothing
    for _ in range(2000):
        if not sched.has_work():
            break
        now += 1.0
        host_step(now)
        assert_no_leaks(sched)
    assert not sched.has_work(), "scheduler failed to drain"
    assert all(r.done for r in reqs)
    assert sched._prompt_keys == {} and sched._ticket == {}
    # every live block is now prefix-pinned only; flushing the map must
    # return the pool to fully free — the zero-leak end state
    if sched.prefix is not None:
        sched.prefix.evict(len(sched.prefix))
    assert sched.alloc.free_blocks == usable
    assert sched.alloc.check_conservation()


@pytest.mark.parametrize("seed", [0, 1, 2, 7])
@pytest.mark.parametrize("num_blocks", [8, 14, 40])
def test_scheduler_interleavings_smoke(seed, num_blocks):
    """Deterministic seeds — runs everywhere, no hypothesis needed."""
    drive(seed, num_blocks)


try:                                   # the smoke above must still run
    from hypothesis import given, settings, strategies as st
except ImportError:                    # pragma: no cover - CI installs it
    st = None

if st is not None:
    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), num_blocks=st.integers(6, 48))
    def test_scheduler_interleavings(seed, num_blocks):
        drive(seed, num_blocks)
