"""Property tests for the paged-cache block allocator (hypothesis).

Random interleavings of alloc / share / free / fork / evict against a
model of who owns what, checking the invariants the serving engine's
correctness rests on:

* pool conservation: free + live == usable blocks, always;
* no double-free: dropping a dead reference raises instead of corrupting
  the free list;
* exclusivity: a block referenced by two "page tables" is always
  refcounted as shared — and fork() restores exclusivity before a write.
"""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.serving.paged import BlockAllocator, PrefixCache, prefix_keys

OPS = st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(0, 4)),
        st.tuples(st.just("share"), st.integers(0, 200)),
        st.tuples(st.just("free"), st.integers(0, 200)),
        st.tuples(st.just("fork"), st.integers(0, 200)),
    ),
    min_size=1, max_size=60)


@settings(max_examples=200, deadline=None)
@given(num_blocks=st.integers(2, 24), ops=OPS)
def test_allocator_invariants_under_random_ops(num_blocks, ops):
    a = BlockAllocator(num_blocks, 4)
    refs: list[int] = []               # our model: one entry per reference

    for op, arg in ops:
        if op == "alloc":
            if a.can_alloc(arg):
                got = a.alloc(arg)
                assert len(set(got)) == arg and 0 not in got
                assert not (set(got) & set(refs)), \
                    "alloc handed out a block someone still references"
                refs.extend(got)
            else:
                with pytest.raises(MemoryError):
                    a.alloc(arg)
        elif op == "share" and refs:
            b = refs[arg % len(refs)]
            a.incref(b)
            refs.append(b)
        elif op == "free" and refs:
            b = refs.pop(arg % len(refs))
            freed = a.decref(b)
            assert freed == (b not in refs), \
                "block freed while other references remain (or kept dead)"
        elif op == "fork" and refs:
            b = refs[arg % len(refs)]
            if refs.count(b) > 1 and a.can_alloc(1):
                nb = a.fork(b)
                assert nb is not None and nb != b
                refs.remove(b)
                refs.append(nb)
            elif refs.count(b) == 1:
                assert a.fork(b) is None

        # invariants after EVERY operation
        assert a.check_conservation()
        assert a.free_blocks == (num_blocks - 1) - len(set(refs))
        for b in set(refs):
            assert a.refcount(b) == refs.count(b), \
                "refcount out of sync with outstanding references"
        for b in set(refs):
            if refs.count(b) >= 2:
                assert a.refcount(b) >= 2, \
                    "block in two page tables but not marked shared"

    # drain: every reference released returns the pool to fully-free
    while refs:
        a.decref(refs.pop())
    assert a.free_blocks == num_blocks - 1 and a.check_conservation()


@settings(max_examples=100, deadline=None)
@given(tokens=st.lists(st.integers(0, 50), min_size=0, max_size=40),
       block_size=st.integers(1, 8))
def test_prefix_keys_model(tokens, block_size):
    ks = prefix_keys(tokens, block_size)
    assert len(ks) == len(tokens) // block_size
    # equal prefixes key equal; any earlier-block perturbation changes
    # every later key (the digest chain commits to the whole prefix)
    assert ks == prefix_keys(tokens[:len(ks) * block_size], block_size)
    assert len(set(ks)) == len(ks)     # each key commits to its depth too
    if ks:
        other = list(tokens)
        other[0] += 1
        assert all(a != b for a, b in zip(prefix_keys(other, block_size),
                                          ks))


@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 5), evict=st.integers(0, 10))
def test_prefix_cache_pins_exactly_once(n, evict):
    a = BlockAllocator(2 * n + 2, 4)
    pc = PrefixCache(a)
    keys = prefix_keys(list(range(4 * n)), 4)
    blocks = a.alloc(n)
    for k, b in zip(keys, blocks):
        pc.register(k, b)
        pc.register(k, b)              # idempotent: still one map ref
    for b in blocks:
        a.decref(b)                    # owner gone; map keeps them live
    assert a.live_blocks == n
    freed = pc.evict(evict)
    assert freed == min(evict, n)
    assert a.free_blocks == (2 * n + 1) - (n - freed)
    assert a.check_conservation()
