"""Property tests for the paged-cache block allocator (hypothesis).

Random interleavings of alloc / share / free / fork / evict against a
model of who owns what, checking the invariants the serving engine's
correctness rests on:

* pool conservation: free + live == usable blocks, always;
* no double-free: dropping a dead reference raises instead of corrupting
  the free list;
* exclusivity: a block referenced by two "page tables" is always
  refcounted as shared — and fork() restores exclusivity before a write.
"""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.serving.paged import BlockAllocator, PrefixCache, prefix_keys

OPS = st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(0, 4)),
        st.tuples(st.just("share"), st.integers(0, 200)),
        st.tuples(st.just("free"), st.integers(0, 200)),
        st.tuples(st.just("fork"), st.integers(0, 200)),
    ),
    min_size=1, max_size=60)


@settings(max_examples=200, deadline=None)
@given(num_blocks=st.integers(2, 24), ops=OPS)
def test_allocator_invariants_under_random_ops(num_blocks, ops):
    a = BlockAllocator(num_blocks, 4)
    refs: list[int] = []               # our model: one entry per reference

    for op, arg in ops:
        if op == "alloc":
            if a.can_alloc(arg):
                got = a.alloc(arg)
                assert len(set(got)) == arg and 0 not in got
                assert not (set(got) & set(refs)), \
                    "alloc handed out a block someone still references"
                refs.extend(got)
            else:
                with pytest.raises(MemoryError):
                    a.alloc(arg)
        elif op == "share" and refs:
            b = refs[arg % len(refs)]
            a.incref(b)
            refs.append(b)
        elif op == "free" and refs:
            b = refs.pop(arg % len(refs))
            freed = a.decref(b)
            assert freed == (b not in refs), \
                "block freed while other references remain (or kept dead)"
        elif op == "fork" and refs:
            b = refs[arg % len(refs)]
            if refs.count(b) > 1 and a.can_alloc(1):
                nb = a.fork(b)
                assert nb is not None and nb != b
                refs.remove(b)
                refs.append(nb)
            elif refs.count(b) == 1:
                assert a.fork(b) is None

        # invariants after EVERY operation
        assert a.check_conservation()
        assert a.free_blocks == (num_blocks - 1) - len(set(refs))
        for b in set(refs):
            assert a.refcount(b) == refs.count(b), \
                "refcount out of sync with outstanding references"
        for b in set(refs):
            if refs.count(b) >= 2:
                assert a.refcount(b) >= 2, \
                    "block in two page tables but not marked shared"

    # drain: every reference released returns the pool to fully-free
    while refs:
        a.decref(refs.pop())
    assert a.free_blocks == num_blocks - 1 and a.check_conservation()


@settings(max_examples=100, deadline=None)
@given(tokens=st.lists(st.integers(0, 50), min_size=0, max_size=40),
       block_size=st.integers(1, 8))
def test_prefix_keys_model(tokens, block_size):
    ks = prefix_keys(tokens, block_size)
    assert len(ks) == len(tokens) // block_size
    # equal prefixes key equal; any earlier-block perturbation changes
    # every later key (the digest chain commits to the whole prefix)
    assert ks == prefix_keys(tokens[:len(ks) * block_size], block_size)
    assert len(set(ks)) == len(ks)     # each key commits to its depth too
    if ks:
        other = list(tokens)
        other[0] += 1
        assert all(a != b for a, b in zip(prefix_keys(other, block_size),
                                          ks))


@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 5), evict=st.integers(0, 10))
def test_prefix_cache_pins_exactly_once(n, evict):
    a = BlockAllocator(2 * n + 2, 4)
    pc = PrefixCache(a)
    keys = prefix_keys(list(range(4 * n)), 4)
    blocks = a.alloc(n)
    for k, b in zip(keys, blocks):
        pc.register(k, b)
        pc.register(k, b)              # idempotent: still one map ref
    for b in blocks:
        a.decref(b)                    # owner gone; map keeps them live
    assert a.live_blocks == n
    freed = pc.evict(evict)
    assert freed == min(evict, n)
    assert a.free_blocks == (2 * n + 1) - (n - freed)
    assert a.check_conservation()


# ---------------------------------------------------------------------- #
# tiered cache: random spill / fetch / drop interleavings
# ---------------------------------------------------------------------- #

TIER_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("reg"), st.integers(0, 3)),     # register @ prio
        st.tuples(st.just("evict"), st.integers(1, 4)),   # spill-or-drop
        st.tuples(st.just("fetch"), st.integers(1, 12)),  # host -> HBM
        st.tuples(st.just("hold"), st.integers(0, 50)),   # acquire a hit
        st.tuples(st.just("drop"), st.integers(0, 50)),   # release a hold
    ),
    min_size=1, max_size=50)


@settings(max_examples=150, deadline=None)
@given(num_blocks=st.integers(4, 16), host_cap=st.integers(0, 10),
       ops=TIER_OPS, quantized=st.booleans())
def test_tiered_cache_invariants_under_random_ops(num_blocks, host_cap, ops,
                                                  quantized):
    """Spill/fetch/drop interleavings against a model of who owns what:

    * refcount == owners per tier: an HBM map entry holds exactly 1 ref
      plus one per outstanding hold; host entries hold no allocator refs;
    * no key resident in two tiers, ever;
    * block contents round-trip spill -> fetch bit-exact — for quantized
      pools that means the int8 payload AND the float32 scale leaf, whose
      lifecycle must mirror the payload's exactly (spilled together,
      fetched together, never resident in one tier without the other);
    * a full drain (evict everything, flush the host pool, release holds)
      leaves both pools empty with zero leaked blocks.
    """
    import numpy as np

    from repro.serving.tiering import HostPool, TieredPrefixCache

    a = BlockAllocator(num_blocks, 4)
    pc = TieredPrefixCache(a, HostPool(host_cap))
    # quantized pools: an int8 payload leaf plus a scale leaf, spilled
    # and fetched as ordinary sibling KV leaves (exactly how the engine's
    # _extract_blocks/_insert_blocks treat "k"/"k_scale")
    if quantized:
        dev = {"k": np.zeros((1, num_blocks, 4), np.int8),
               "k_scale": np.zeros((1, num_blocks), np.float32)}
    else:
        dev = {"k": np.zeros((1, num_blocks, 4), np.float32)}
    pc.bind_device_io(
        lambda bids: {n: leaf[:, np.asarray(bids)].copy()
                      for n, leaf in dev.items()},
        lambda bids, data: [leaf.__setitem__(
            (slice(None), np.asarray(bids)), data[n])
            for n, leaf in dev.items()])

    keys = prefix_keys(list(range(4 * 64)), 4)
    value: dict[bytes, float] = {}     # key -> expected block payload
    held: list[int] = []               # bids acquired by fake requests
    nreg = 0

    for op, arg in ops:
        if op == "reg" and nreg < len(keys) and a.can_alloc(1):
            bid = a.alloc(1)[0]
            dev["k"][:, bid] = nreg + 1 if not quantized else (nreg % 126) + 1
            if quantized:
                # a distinct non-trivial scale so a payload/scale swap or
                # a zeroed scale leaf cannot round-trip undetected
                dev["k_scale"][:, bid] = (nreg + 1) * 0.125
            value[keys[nreg]] = float(dev["k"][0, bid, 0])
            pc.register(keys[nreg], bid, priority=arg)
            a.decref(bid)              # owner done: map-only entry
            nreg += 1
        elif op == "evict":
            before_idle = pc.evictable()
            freed = pc.evict(arg)
            assert freed == min(arg, before_idle)
        elif op == "fetch":
            chain = keys[:nreg]
            hits = pc.peek(chain)
            got = pc.fetch_into_hbm(chain, list(hits), arg)
            assert len(got) >= len(hits)
            assert len(got) <= max(len(hits), arg)
        elif op == "hold" and len(pc):
            run = pc.peek(keys[:nreg])
            if run:
                bid = run[arg % len(run)]
                a.incref(bid)
                held.append(bid)
        elif op == "drop" and held:
            a.decref(held.pop(arg % len(held)))

        # invariants after EVERY operation ---------------------------- #
        assert a.check_conservation()
        for k, bid in pc._map.items():
            assert k not in pc.host, f"key resident in two tiers"
            assert a.refcount(bid) == 1 + held.count(bid), \
                "map entry refcount != map ref + outstanding holds"
            assert dev["k"][0, bid, 0] == value[k], \
                "HBM block content diverged from its registered value"
            if quantized:
                assert dev["k_scale"][0, bid] == value[k] * 0.125, \
                    "scale leaf diverged from its payload's lifecycle"
        for k in pc.host.keys():
            ent = pc.host.get(k).data
            assert ent["k"][0, 0] == value[k], \
                "host tier content diverged (spill not bit-exact)"
            if quantized:
                assert ent["k"].dtype == np.int8, \
                    "spill widened the quantized payload"
                assert "k_scale" in ent, \
                    "payload spilled without its scale leaf"
                assert ent["k_scale"].dtype == np.float32
                assert ent["k_scale"][0] == value[k] * 0.125, \
                    "scale spill not bit-exact"
        assert len(pc.host) <= host_cap

    # full drain: drop holds, evict the map dry, flush the host pool
    while held:
        a.decref(held.pop())
    pc.evict(len(pc))
    pc.host.flush()
    assert len(pc) == 0 and len(pc.host) == 0
    assert a.free_blocks == num_blocks - 1 and a.check_conservation()
    total = pc.spilled_blocks + pc.dropped_blocks + pc.fetched_blocks
    assert total >= 0   # counters monotone; exercised paths accounted
