
"""Property tests for F ops (hypothesis) against numpy semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import repro.core.functions as F

shapes = st.sampled_from([(2, 3), (4,), (2, 2, 2), (1, 5)])
floats = st.floats(-10, 10, allow_nan=False, width=32)


@given(shapes, st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_softmax_properties(shape, seed):
    x = np.random.default_rng(seed).normal(size=shape).astype(np.float32)
    y = np.asarray(F.softmax(jnp.asarray(x)))
    np.testing.assert_allclose(y.sum(-1), 1.0, rtol=1e-5)
    assert (y >= 0).all()
    # shift invariance
    y2 = np.asarray(F.softmax(jnp.asarray(x + 100.0)))
    np.testing.assert_allclose(y, y2, atol=1e-5)


@given(shapes, st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_layer_norm_stats(shape, seed):
    x = np.random.default_rng(seed).normal(3, 7, size=shape).astype(np.float32)
    g = jnp.ones(shape[-1]); b = jnp.zeros(shape[-1])
    y = np.asarray(F.layer_normalization(jnp.asarray(x), g, b))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(y.std(-1), 1.0, atol=1e-2)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_softmax_cross_entropy_matches_manual(seed):
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(4, 9)).astype(np.float32)
    labels = rng.integers(0, 9, size=(4,))
    got = np.asarray(F.softmax_cross_entropy(jnp.asarray(logits),
                                             jnp.asarray(labels)))
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = -np.log(p[np.arange(4), labels])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_rope_preserves_norm(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(1, 6, 2, 8)).astype(np.float32)
    cos, sin = F.rope_frequencies(8, 6)
    y = np.asarray(F.apply_rope(jnp.asarray(x), cos, sin))
    np.testing.assert_allclose(np.linalg.norm(y, axis=-1),
                               np.linalg.norm(x, axis=-1), rtol=1e-4)


def test_sdpa_matches_explicit_softmax():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 5, 2, 4)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 5, 2, 4)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 5, 2, 4)), jnp.float32)
    out = np.asarray(F.scaled_dot_product_attention(q, k, v, causal=False))
    # manual
    logits = np.einsum("bqhd,bkhd->bhqk", q, k) / 2.0
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bhqk,bkhd->bqhd", p, v)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


def test_conv_matches_numpy_direct():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(1, 1, 5, 5)).astype(np.float32)
    w = rng.normal(size=(1, 1, 3, 3)).astype(np.float32)
    y = np.asarray(F.convolution(jnp.asarray(x), jnp.asarray(w)))
    want = np.zeros((1, 1, 3, 3), np.float32)
    for i in range(3):
        for j in range(3):
            want[0, 0, i, j] = (x[0, 0, i:i+3, j:j+3] * w[0, 0]).sum()
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-5)


def test_pooling():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 1, 4, 4)
    y = np.asarray(F.max_pooling(x, kernel=(2, 2)))
    np.testing.assert_array_equal(y[0, 0], [[5, 7], [13, 15]])
    y2 = np.asarray(F.average_pooling(x, kernel=(2, 2)))
    np.testing.assert_allclose(y2[0, 0], [[2.5, 4.5], [10.5, 12.5]])
