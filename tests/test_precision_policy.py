
"""dtype policies (paper §3.3 type_config) drive storage/compute dtypes."""

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as nn
import repro.core.parametric as PF


def _param_and_out(type_config):
    nn.clear_parameters()
    ctx = nn.get_extension_context("cpu", type_config=type_config)
    with nn.context_scope(ctx):
        def model(x):
            return PF.dense(x, 4, name="fc")
        params = nn.init(model, jax.random.key(0),
                         jnp.ones((2, 3), ctx.policy.compute_dtype))
        out = nn.apply(model, params,
                       jnp.ones((2, 3), ctx.policy.compute_dtype))
    return params["fc/kernel"].dtype, out.dtype


def test_float_policy():
    p, o = _param_and_out("float")
    assert p == jnp.float32 and o == jnp.float32


def test_half_policy_fp16_storage():
    p, o = _param_and_out("half")
    assert p == jnp.float16 and o == jnp.float16


def test_bf16_policy_fp32_storage_bf16_compute():
    p, o = _param_and_out("bf16")
    assert p == jnp.float32   # master-style storage
    assert o == jnp.bfloat16  # compute dtype


def test_needs_loss_scaling():
    assert nn.get_extension_context("cpu", type_config="half") \
        .policy.needs_loss_scaling
    assert not nn.get_extension_context("cpu", type_config="bf16") \
        .policy.needs_loss_scaling


def test_norms_stay_fp32_under_half():
    nn.clear_parameters()
    ctx = nn.get_extension_context("cpu", type_config="half")
    with nn.context_scope(ctx):
        def model(x):
            return PF.layer_normalization(x, name="ln")
        params = nn.init(model, jax.random.key(0),
                         jnp.ones((2, 8), jnp.float16))
        assert params["ln/gamma"].dtype == jnp.float32  # paper: BN in fp32
        out = nn.apply(model, params, jnp.ones((2, 8), jnp.float16))
        assert out.dtype == jnp.float16
        assert bool(jnp.isfinite(out).all())
