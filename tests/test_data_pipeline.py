
"""Data pipeline: determinism, sharding metadata, resume."""

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import SyntheticLMPipeline


CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=8,
                  n_heads=1, n_kv_heads=1, d_ff=16, vocab_size=100)
SHAPE = ShapeConfig("t", 8, 4, "train")


def test_deterministic_by_step():
    p1 = SyntheticLMPipeline(CFG, SHAPE, seed=3)
    p2 = SyntheticLMPipeline(CFG, SHAPE, seed=3)
    np.testing.assert_array_equal(p1.batch_at(7)["tokens"],
                                  p2.batch_at(7)["tokens"])
    assert not np.array_equal(p1.batch_at(7)["tokens"],
                              p1.batch_at(8)["tokens"])


def test_labels_are_shifted_tokens():
    b = SyntheticLMPipeline(CFG, SHAPE).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tokens_in_vocab():
    b = SyntheticLMPipeline(CFG, SHAPE).batch_at(0)
    assert b["tokens"].min() >= 1 and b["tokens"].max() < CFG.vocab_size


def test_iterator_resume():
    p = SyntheticLMPipeline(CFG, SHAPE, seed=4)
    first = [next(p)["tokens"] for _ in range(3)]
    snap_at_0 = {"step": 0, "seed": 4}
    p.restore(snap_at_0)
    again = [next(p)["tokens"] for _ in range(3)]
    for a, b in zip(first, again):
        np.testing.assert_array_equal(a, b)


def test_prefetch_ordering():
    p = SyntheticLMPipeline(CFG, SHAPE, seed=9, prefetch=4)
    seq = [next(p)["tokens"] for _ in range(5)]
    for i, b in enumerate(seq):
        np.testing.assert_array_equal(b, p.batch_at(i)["tokens"])
