"""Speculative decoding: n-gram proposer units, and engine-level
stream identity — the accepted path must be BITWISE the non-speculative
stream, greedy and sampled, across kernel modes and tensor-parallel
widths (the CI pallas-interpret and tp legs re-run this file under
``REPRO_KERNELS=pallas_interpret`` / ``REPRO_HOST_DEVICES=8``).

Speculation may only ever change how many steps a generation takes;
these tests pin the contract that it never changes a single token.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.speculative import NgramProposer, propose_ngram

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, remat="none")
SSM_CFG = ModelConfig(name="ssm", family="ssm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                      head_dim=16, ssm_state=16, ssm_head_dim=32,
                      ssm_chunk=4, remat="none")

_PARAMS_CACHE: dict[str, dict] = {}


def init_params(cfg=CFG):
    if cfg.name not in _PARAMS_CACHE:
        api = get_model(cfg)
        _PARAMS_CACHE[cfg.name] = nn.init(
            lambda t: api.forward(t), jax.random.key(0),
            jnp.zeros((1, 8), jnp.int32))
    return _PARAMS_CACHE[cfg.name]


def make_engine(cfg=CFG, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_seq", 64)
    kw.setdefault("chunk", 8)
    return ServingEngine(get_model(cfg), init_params(cfg), **kw)


def _needs_devices(n: int) -> None:
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} host devices, have {len(jax.devices())} — "
                    "set XLA_FLAGS=--xla_force_host_platform_device_count")


def drain_streams(reqs, **engine_kw):
    eng = make_engine(**engine_kw)
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    return {r.uid: list(r.generated) for r in done}, eng


# ---------------------------------------------------------------------- #
# proposer units (pure host-side)
# ---------------------------------------------------------------------- #

def test_propose_constant_run_fills_window():
    # inside a constant run the proposer must offer the FULL window, not
    # the 1-token continuation of the nearest (suffix-adjacent) match
    assert propose_ngram([7] * 20, 4) == [7, 7, 7, 7]


def test_propose_cycle_continuation():
    hist = [1, 2, 3, 4] * 5
    assert propose_ngram(hist, 4) == [1, 2, 3, 4]
    assert propose_ngram(hist + [1, 2], 4) == [3, 4, 1, 2]


def test_propose_prefers_recent_full_continuation():
    # suffix [9, 9] occurs twice with a full 2-token continuation; the
    # most recent one (followed by 5, 6) must win over the stale (3, 4)
    hist = [9, 9, 3, 4, 0, 9, 9, 5, 6, 0, 9, 9]
    assert propose_ngram(hist, 2) == [5, 6]


def test_propose_falls_back_to_partial_continuation():
    # the only match's continuation runs into the suffix itself — no
    # full-k continuation exists, so best effort beats proposing nothing
    assert propose_ngram([9, 9, 9, 1, 2, 3, 1, 2], 4) == [3, 1, 2]


def test_propose_no_match_returns_empty():
    assert propose_ngram([1, 2, 3, 4, 5, 6], 4) == []
    assert propose_ngram([], 4) == []
    assert propose_ngram([1], 4) == []
    assert propose_ngram([1, 1, 1], 0) == []


def test_proposer_handle_validates():
    with pytest.raises(ValueError, match="spec k"):
        NgramProposer(k=-1)
    with pytest.raises(ValueError, match="min_ngram"):
        NgramProposer(k=4, max_ngram=2, min_ngram=3)
    p = NgramProposer(k=4)
    assert p.propose([3] * 10, 2) == [3, 3]      # per-call cap wins
    assert p.propose([3] * 10) == [3, 3, 3, 3]


# ---------------------------------------------------------------------- #
# engine: stream identity (the tentpole contract)
# ---------------------------------------------------------------------- #

def _greedy_reqs():
    # a mix the proposer loves (repetitive) and one it can't help with
    reqs = [Request(uid=i, prompt=[1 + i, 2, 3, 2, 3, 2, 3],
                    max_new_tokens=12) for i in range(4)]
    reqs.append(Request(uid=9, prompt=[11, 23, 37, 41], max_new_tokens=12))
    return reqs


def _sampled_reqs():
    return [Request(uid=i, prompt=[1 + i, 2, 3, 2, 3], max_new_tokens=10,
                    temperature=0.8, top_k=20, top_p=0.9, seed=42 + i)
            for i in range(4)]


def test_spec_greedy_streams_identical():
    base, _ = drain_streams(_greedy_reqs(), prefix_cache=False)
    spec, eng = drain_streams(_greedy_reqs(), prefix_cache=False, spec_k=4)
    assert spec == base
    assert eng.scheduler.spec_proposed > 0
    assert eng.alloc.free_blocks == eng.num_blocks - 1
    assert eng.alloc.check_conservation()


def test_spec_sampled_streams_identical():
    base, _ = drain_streams(_sampled_reqs())
    spec, _ = drain_streams(_sampled_reqs(), spec_k=4)
    assert spec == base


def test_spec_mixed_greedy_sampled_batch_identical():
    def reqs():
        return [Request(uid=i, prompt=[1 + i, 2, 3, 2, 3], max_new_tokens=8,
                        temperature=0.0 if i % 2 == 0 else 0.9, seed=7 + i)
                for i in range(4)]
    base, _ = drain_streams(reqs())
    spec, _ = drain_streams(reqs(), spec_k=4)
    assert spec == base


@pytest.mark.parametrize("spec_k", [1, 3, 8])
def test_spec_width_never_changes_streams(spec_k):
    base, _ = drain_streams(_greedy_reqs())
    spec, _ = drain_streams(_greedy_reqs(), spec_k=spec_k)
    assert spec == base


def test_spec_eos_inside_draft_window():
    """EOS emitted mid-window must cut the stream exactly where the
    token-at-a-time engine stops — accepted drafts past EOS are dropped."""
    probe, _ = drain_streams([Request(uid=0, prompt=[5, 2, 3, 2, 3, 2, 3],
                                      max_new_tokens=16)])
    stream = probe[0]
    # pick an eos that the stream actually emits mid-way
    eos = stream[len(stream) // 2]

    def reqs():
        return [Request(uid=0, prompt=[5, 2, 3, 2, 3, 2, 3],
                        max_new_tokens=16, eos_id=eos)]
    base, _ = drain_streams(reqs())
    spec, _ = drain_streams(reqs(), spec_k=4)
    assert spec == base
    assert base[0][-1] == eos and len(base[0]) < 16


def test_spec_max_seq_boundary_identical():
    """Acceptance must not overshoot the max_seq finish boundary: the
    speculative run stops at exactly the token count of the plain run."""
    def reqs():
        return [Request(uid=0, prompt=[3, 2, 3, 2, 3, 2], max_new_tokens=64)]
    base, _ = drain_streams(reqs(), max_seq=24, max_batch=1,
                            prefix_cache=False)
    spec, eng = drain_streams(reqs(), max_seq=24, max_batch=1,
                              prefix_cache=False, spec_k=4)
    assert spec == base
    assert eng.alloc.free_blocks == eng.num_blocks - 1
    assert eng.alloc.check_conservation()


def test_spec_with_prefix_cache_and_requeue_pressure():
    """Spec decoding composed with prefix hits and slot churn: two waves
    over a shared prefix, tiny batch, streams still bitwise equal."""
    shared = [4, 2, 3, 2, 3, 2, 3, 2, 3, 5]

    def reqs():
        return [Request(uid=i, prompt=shared + [10 + i], max_new_tokens=8)
                for i in range(6)]
    base, _ = drain_streams(reqs(), max_batch=2)
    spec, _ = drain_streams(reqs(), max_batch=2, spec_k=4)
    assert spec == base


# ---------------------------------------------------------------------- #
# metrics / gating
# ---------------------------------------------------------------------- #

def test_spec_metrics_and_acceptance_reported():
    spec, eng = drain_streams(_greedy_reqs(), spec_k=4)
    m = eng.metrics_summary()
    assert m["spec_proposed"] > 0
    assert 0.0 < m["spec_accept_rate"] <= 1.0
    assert m["spec_accepted"] == pytest.approx(
        m["spec_accept_rate"] * m["spec_proposed"])
    per_req = [r.metrics for r in eng.completed]
    assert sum(x.spec_proposed for x in per_req) == m["spec_proposed"]
    assert sum(x.spec_accepted for x in per_req) == m["spec_accepted"]
    # a repetitive greedy workload must actually save steps
    base, beng = drain_streams(_greedy_reqs())
    spec_steps = sum(r.metrics.decode_steps for r in eng.completed)
    base_steps = sum(r.metrics.decode_steps for r in beng.completed)
    assert spec_steps < base_steps


def test_non_spec_engine_reports_no_spec_metrics():
    _, eng = drain_streams(_greedy_reqs())
    m = eng.metrics_summary()
    assert "spec_accept_rate" not in m and "spec_proposed" not in m


def test_spec_rejected_for_recurrent_state():
    with pytest.raises(ValueError, match="spec_k"):
        make_engine(cfg=SSM_CFG, spec_k=4)


def test_spec_rejected_on_dense_layout():
    with pytest.raises(ValueError, match="spec_k"):
        make_engine(spec_k=4, paged=False)


def test_spec_default_off():
    eng = make_engine()
    assert eng.spec is None


# ---------------------------------------------------------------------- #
# tensor parallel: spec streams identical across mesh widths
# ---------------------------------------------------------------------- #

TP_CFG = dataclasses.replace(CFG, name="tp-spec")


def test_spec_tp2_streams_match_tp1():
    _needs_devices(2)
    base, _ = drain_streams(_greedy_reqs(), cfg=TP_CFG)
    for tp in (1, 2):
        spec, _ = drain_streams(_greedy_reqs(), cfg=TP_CFG, spec_k=4, tp=tp)
        assert spec == base, f"tp={tp} speculative stream diverged"


def test_spec_tp2_sampled_streams_match():
    _needs_devices(2)
    base, _ = drain_streams(_sampled_reqs(), cfg=TP_CFG)
    spec, _ = drain_streams(_sampled_reqs(), cfg=TP_CFG, spec_k=4, tp=2)
    assert spec == base
