
"""NNP compatibility layer (paper §3/§3.1): round-trips + queries."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as nn
import repro.core.functions as F
import repro.core.parametric as PF
from repro.fileformat import (ModelFile, NnpExecutor, export_model, load_nnp,
                              query_unsupported, save_nnp, trace_network)
from repro.fileformat.defs import NetworkDef, FunctionDef, VariableDef
from repro.fileformat.onnx_mini import (export_onnx, import_onnx,
                                        unsupported_for_export,
                                        unsupported_for_import)
from repro.models.cnn import lenet


def test_lenet_roundtrip_identical_outputs(tmp_path):
    x = np.random.default_rng(0).standard_normal((2, 1, 28, 28)).astype(np.float32)
    xv = nn.Variable(data=x)
    y = lenet(xv)
    y.forward()
    ref_out = np.asarray(y.data)

    path = str(tmp_path / "lenet.nnp")
    export_model("lenet", lambda x: lenet(x), {"x": x}, path)
    mf, params = load_nnp(path)
    out = NnpExecutor(mf.network("lenet"), params)(x=x)[0]
    np.testing.assert_array_equal(np.asarray(out), ref_out)  # bitwise


def test_parameters_roundtrip_bitwise(tmp_path):
    x = np.ones((1, 1, 28, 28), np.float32)
    path = str(tmp_path / "m.nnp")
    export_model("m", lambda x: lenet(x), {"x": x}, path)
    before = {k: np.asarray(v.data) for k, v in nn.get_parameters().items()}
    _, params = load_nnp(path)
    for k, v in before.items():
        np.testing.assert_array_equal(params[k], v)


def test_query_unsupported():
    net = NetworkDef(name="n", functions=[
        FunctionDef(name="f0", type="matmul", inputs=[], outputs=[]),
        FunctionDef(name="f1", type="alien_op", inputs=[], outputs=[]),
    ])
    assert query_unsupported(net) == ["alien_op"]
    with pytest.raises(ValueError, match="alien_op"):
        NnpExecutor(net, {})


def test_executor_runs_fresh_process_semantics(tmp_path):
    """Load + execute WITHOUT the defining python code (registry cleared)."""
    x = np.random.default_rng(1).standard_normal((1, 6)).astype(np.float32)

    def model(x):
        return F.tanh(PF.affine(x, 3, name="fc"))

    xv = nn.Variable(data=x)
    y = model(xv); y.forward()
    want = np.asarray(y.data)
    path = str(tmp_path / "t.nnp")
    export_model("t", model, {"x": x}, path)

    nn.clear_parameters()                     # "fresh process"
    mf, params = load_nnp(path)
    got = NnpExecutor(mf.network("t"), params)(x=x)[0]
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-6)


def test_onnx_export_import_roundtrip(tmp_path):
    x = np.random.default_rng(2).standard_normal((2, 4)).astype(np.float32)

    def model(x):
        return F.relu(PF.affine(x, 3, name="fc"))

    net, params = trace_network("mini", model, {"x": x})
    assert unsupported_for_export(net) == []
    onnx = export_onnx(net, params)
    assert {n["op_type"] for n in onnx["graph"]["node"]} >= {"MatMul", "Relu"}
    back = import_onnx(onnx)
    assert [f.type for f in back.functions] == [f.type for f in net.functions]
    assert unsupported_for_import(onnx["graph"]) == []


def test_onnx_unsupported_strictness():
    net = NetworkDef(name="x", functions=[
        FunctionDef(name="f", type="apply_rope", inputs=[], outputs=[])])
    assert unsupported_for_export(net) == ["apply_rope"]
    with pytest.raises(ValueError):
        export_onnx(net, {}, strict=True)


def test_model_file_messages_roundtrip(tmp_path):
    """The full §3.1 message set survives save/load."""
    from repro.fileformat.defs import (DatasetDef, ExecutorDef, GlobalConfig,
                                       MonitorDef, OptimizerDef,
                                       TrainingConfig, to_dict)
    mf = ModelFile(
        global_config=GlobalConfig(default_context="tpu|bf16"),
        training_config=TrainingConfig(max_epoch=90, iter_per_epoch=100),
        datasets=[DatasetDef(name="synth", batch_size=32)],
        optimizers=[OptimizerDef(name="opt", solver="adam",
                                 hyper={"alpha": 1e-3})],
        monitors=[MonitorDef(name="loss", variable="loss")],
        executors=[ExecutorDef(name="run", network="net")])
    path = str(tmp_path / "cfg.nnp")
    save_nnp(path, mf, {})
    mf2, _ = load_nnp(path)
    assert mf2.global_config.default_context == "tpu|bf16"
    assert mf2.training_config.max_epoch == 90
    assert mf2.optimizers[0].hyper["alpha"] == 1e-3
    assert mf2.executors[0].name == "run"
