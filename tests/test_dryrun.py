"""Launch-path regression: one real dry-run cell compiles on the production
mesh (subprocess — 512 forced host devices must not leak into this process)."""

import json


CODE = """
import json
from repro.launch.dryrun import run_cell
import pathlib, tempfile
with tempfile.TemporaryDirectory() as d:
    rec = run_cell("llama3.2-1b", "decode_32k", False, pathlib.Path(d),
                   kernels="xla_chunked", probes=False)
    assert rec["status"] == "ok", rec.get("error")
    assert rec["n_chips"] == 256
    r = rec["roofline"]
    assert r["t_memory_s"] > 0 and r["bottleneck"] in (
        "compute", "memory", "collective")
    print("DRYRUN-OK", json.dumps(rec["collectives"]["by_kind_count"]))
"""


def test_dryrun_cell_compiles(subproc):
    # dryrun.py sets its own XLA_FLAGS at import; devices=1 here is fine
    out = subproc(CODE, devices=1, timeout=560)
    assert "DRYRUN-OK" in out


def test_mesh_shapes():
    from repro.launch import mesh as M
    import inspect
    src = inspect.getsource(M.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src
