
"""Dynamic loss scaling (paper §3.3 Listing 6 semantics)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.precision.loss_scale import (all_finite, dynamic_scaler,
                                        static_scaler)


def test_halves_on_nonfinite_and_skips():
    sc = dynamic_scaler(init_scale=1024.0, interval=4)
    st = sc.init_state()
    st2 = sc.next_state(st, jnp.asarray(False))
    assert float(st2.scale) == 512.0
    assert int(st2.counter) == 0
    assert int(st2.total_skipped) == 1


def test_doubles_after_interval_good_steps():
    sc = dynamic_scaler(init_scale=1024.0, interval=3)
    st = sc.init_state()
    for _ in range(3):
        st = sc.next_state(st, jnp.asarray(True))
    assert float(st.scale) == 2048.0
    assert int(st.counter) == 0


def test_scale_and_unscale_roundtrip():
    sc = dynamic_scaler(init_scale=8.0)
    st = sc.init_state()
    loss = jnp.asarray(2.0)
    assert float(sc.scale_loss(loss, st)) == 16.0
    grads = {"w": jnp.asarray([8.0, 16.0])}
    un = sc.unscale_grads(grads, st)
    np.testing.assert_allclose(np.asarray(un["w"]), [1.0, 2.0])


def test_all_finite():
    assert bool(all_finite({"a": jnp.ones(3)}))
    assert not bool(all_finite({"a": jnp.asarray([1.0, np.inf])}))
    assert not bool(all_finite({"a": jnp.asarray([np.nan])}))
    assert bool(all_finite({"i": jnp.arange(3)}))  # ints ignored


def test_static_scaler_noop_transitions():
    sc = static_scaler(1.0)
    st = sc.init_state()
    st2 = sc.next_state(st, jnp.asarray(False))
    assert float(st2.scale) == 1.0


def test_bounds():
    sc = dynamic_scaler(init_scale=2.0)
    st = sc.init_state()
    for _ in range(5):
        st = sc.next_state(st, jnp.asarray(False))
    assert float(st.scale) >= 1.0  # min_scale floor
