
"""Solvers: reference math, master weights, dual-plane equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as nn
from repro.solvers import Adam, AdamW, Adafactor, Momentum, Sgd, make_solver
from repro.solvers.base import clip_by_global_norm


def test_adam_matches_reference_math():
    solver = Adam(alpha=0.1, beta1=0.9, beta2=0.999, eps=1e-8)
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    state = solver.init_state(p)
    p1, state = solver.step(p, g, state)
    # manual first step: m=0.1g v=0.001g^2, bias-corrected
    m = 0.1 * np.asarray(g["w"]); v = 0.001 * np.asarray(g["w"]) ** 2
    mhat = m / 0.1; vhat = v / 0.001
    want = np.asarray(p["w"]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8) * np.sqrt(0.001) / 0.1 * (0.1 / np.sqrt(0.001))
    # equivalent closed form for step1: p - alpha * sign-ish
    got = np.asarray(p1["w"])
    ref = np.asarray(p["w"]) - 0.1 * m / (1 - 0.9) / (np.sqrt(v / (1 - 0.999)) + 1e-8)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_sgd_and_momentum():
    p = {"w": jnp.ones(3)}
    g = {"w": jnp.full(3, 2.0)}
    s = Sgd(lr=0.5)
    st = s.init_state(p)
    p1, _ = s.step(p, g, st)
    np.testing.assert_allclose(np.asarray(p1["w"]), 0.0)

    m = Momentum(lr=0.1, momentum=0.9)
    st = m.init_state(p)
    p1, st = m.step(p, g, st)
    p2, st = m.step(p1, g, st)
    # v1=2, v2=0.9*2+2=3.8
    np.testing.assert_allclose(np.asarray(p2["w"]), 1 - 0.2 - 0.38, rtol=1e-6)


def test_master_weights_fp16_storage():
    p = {"w": jnp.ones(4, jnp.float16)}
    g = {"w": jnp.full(4, 1e-4, jnp.float16)}  # update below fp16 resolution
    s = Sgd(lr=1.0)
    st = s.init_state(p)
    assert st["master"]["w"].dtype == jnp.float32
    cur_p, cur_st = p, st
    for _ in range(10):
        cur_p, cur_st = s.step(cur_p, g, cur_st)
    # fp32 master accumulated 10 * 1e-4 even though each step < fp16 eps
    assert abs(float(cur_st["master"]["w"][0]) - (1 - 10e-4)) < 1e-5


def test_eager_plane_matches_functional():
    rng = np.random.default_rng(0)
    w0 = rng.random((3, 2)).astype(np.float32)
    grad = rng.random((3, 2)).astype(np.float32)

    solver_f = Adam(alpha=0.01)
    pf = {"w": jnp.asarray(w0)}
    st = solver_f.init_state(pf)
    pf1, _ = solver_f.step(pf, {"w": jnp.asarray(grad)}, st)

    solver_e = Adam(alpha=0.01)
    p = nn.set_parameter("w", jnp.asarray(w0))
    solver_e.set_parameters({"w": p})
    p.grad = jnp.asarray(grad)
    solver_e.update()
    np.testing.assert_allclose(np.asarray(p.data), np.asarray(pf1["w"]),
                               rtol=1e-6)


def test_weight_decay_and_clip_eager():
    p = nn.set_parameter("w", jnp.full(4, 2.0))
    s = Sgd(lr=1.0)
    s.set_parameters({"w": p})
    p.grad = jnp.zeros(4)
    s.weight_decay(0.1)
    np.testing.assert_allclose(np.asarray(p.grad), 0.2)
    s.clip_grad_by_norm(0.1)
    assert float(jnp.linalg.norm(p.grad)) <= 0.1 + 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 3.0), "b": jnp.full(9, 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = np.sqrt(sum(float(jnp.sum(v ** 2)) for v in clipped.values()))
    assert abs(total - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_adafactor_factored_slots():
    p = {"w": jnp.ones((8, 16)), "b": jnp.ones(16)}
    s = Adafactor(lr=0.01)
    st = s.init_state(p)
    assert st["slots"]["w"]["vr"].shape == (8,)
    assert st["slots"]["w"]["vc"].shape == (16,)
    assert st["slots"]["b"]["v"].shape == (16,)
    p1, _ = s.step(p, {"w": jnp.ones((8, 16)), "b": jnp.ones(16)}, st)
    assert np.isfinite(np.asarray(p1["w"])).all()


def test_make_solver_registry():
    assert isinstance(make_solver("adamw", alpha=1e-3), AdamW)
    with pytest.raises(ValueError):
        make_solver("nope")
