"""Pallas paged-attention kernels vs the gather-then-dense references.

Everything runs the kernels in interpret mode (CPU container): same kernel
logic as the compiled TPU build, minus Mosaic. Sweeps cover block sizes
{4, 8, 16}, GQA ratios (incl. MQA), ragged lengths exactly on / one off
block boundaries, all-idle rows, the fused scatter (incl. the overrun ->
garbage-block regression), the dense-prefill-as-paged-walk route, and an
engine-level smoke with ``kernels="pallas_interpret"``.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import context as ctx
from repro.kernels import ops
from repro.kernels.flash_attention import paged_attention as pa
from repro.kernels.flash_attention import ref as fa_ref


def rand(shape, seed, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), dtype)


def make_pools(B, MB, bs, Hkv, D, seed=0):
    """Pools + a shuffled (non-contiguous) page table, garbage block 0."""
    NB = B * MB + 1
    kp = rand((NB, bs, Hkv, D), seed)
    vp = rand((NB, bs, Hkv, D), seed + 1)
    perm = np.random.default_rng(seed + 2).permutation(np.arange(1, NB))
    pages = jnp.asarray(perm[:B * MB].reshape(B, MB), jnp.int32)
    return kp, vp, pages


def interpret_ctx():
    return ctx.context_scope(dataclasses.replace(
        ctx.get_default_context(), kernels="pallas_interpret"))


# ---------------------------------------------------------------------- #
# decode parity
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("bs", [4, 8, 16])
@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2), (8, 1)])  # GQA + MQA
def test_paged_decode_parity(bs, Hq, Hkv):
    B, D, MB = 4, 32, 48 // bs
    kp, vp, pages = make_pools(B, MB, bs, Hkv, D, seed=bs)
    q = rand((B, 1, Hq, D), 7)
    # boundary sweep: exactly on a block edge, one before, one after, full
    lengths = jnp.asarray([bs, bs - 1, bs + 1, MB * bs], jnp.int32)
    got = pa.paged_decode(q, kp, vp, pages, lengths, interpret=True)
    want = fa_ref.paged_decode_reference(q, kp, vp, pages, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_paged_decode_all_idle_row():
    """An idle slot has an all-zero page table and length 1 (the engine
    decodes at pos + 1): the kernel must read only the garbage block and
    still agree with the reference."""
    B, bs, MB, Hq, Hkv, D = 3, 8, 4, 4, 2, 32
    kp, vp, pages = make_pools(B, MB, bs, Hkv, D, seed=3)
    pages = pages.at[1, :].set(0)                  # row 1 idle
    lengths = jnp.asarray([2 * bs + 3, 1, bs], jnp.int32)
    q = rand((B, 1, Hq, D), 11)
    got = pa.paged_decode(q, kp, vp, pages, lengths, interpret=True)
    want = fa_ref.paged_decode_reference(q, kp, vp, pages, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_paged_decode_bf16():
    B, bs, MB, Hq, Hkv, D = 2, 8, 4, 4, 2, 64
    NB = B * MB + 1
    kp = rand((NB, bs, Hkv, D), 0, jnp.bfloat16)
    vp = rand((NB, bs, Hkv, D), 1, jnp.bfloat16)
    pages = jnp.asarray(1 + np.arange(B * MB).reshape(B, MB), jnp.int32)
    q = rand((B, 1, Hq, D), 2, jnp.bfloat16)
    lengths = jnp.asarray([5, 29], jnp.int32)
    got = pa.paged_decode(q, kp, vp, pages, lengths, interpret=True)
    want = fa_ref.paged_decode_reference(q, kp, vp, pages, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------- #
# chunk-causal prefill parity
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("bs", [4, 8, 16])
@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2)])
def test_paged_prefill_parity(bs, Hq, Hkv):
    """Chunks spanning block boundaries mid-chunk: C = 5 with pos at, one
    before and one past a block edge, plus a fresh row at pos 0."""
    B, C, D, MB = 4, 5, 32, 48 // bs
    kp, vp, pages = make_pools(B, MB, bs, Hkv, D, seed=10 + bs)
    q = rand((B, C, Hq, D), 13)
    pos = jnp.asarray([0, bs - 1, bs, bs + 1], jnp.int32)
    got = pa.paged_prefill(q, kp, vp, pages, pos, interpret=True)
    want = fa_ref.paged_prefill_reference(q, kp, vp, pages, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_paged_prefill_chunk_causality():
    """The kernel's mask is per-query: later queries in the chunk must see
    strictly more of the cache (checked against a manual per-row oracle)."""
    B, C, bs, MB, Hq, Hkv, D = 1, 4, 4, 4, 2, 2, 16
    kp, vp, pages = make_pools(B, MB, bs, Hkv, D, seed=21)
    q = rand((B, C, Hq, D), 22)
    pos = jnp.asarray([3], jnp.int32)
    got = np.asarray(pa.paged_prefill(q, kp, vp, pages, pos, interpret=True))
    dense_k = fa_ref.gather_pages(kp, pages)
    dense_v = fa_ref.gather_pages(vp, pages)
    for i in range(C):
        # query i as a standalone decode over pos+i+1 visible tokens
        one = fa_ref.decode_reference(
            q[:, i:i + 1], dense_k, dense_v,
            jnp.asarray([int(pos[0]) + i + 1], jnp.int32))
        np.testing.assert_allclose(got[:, i:i + 1], np.asarray(one),
                                   atol=1e-5, rtol=1e-5,
                                   err_msg=f"query {i} sees wrong window")


def test_dense_prefill_routes_through_paged_walk():
    """ops.attention_prefill in pallas modes runs the paged kernel over an
    identity page table (free reshape of the contiguous cache)."""
    B, C, Smax, Hq, Hkv, D = 2, 6, 48, 4, 2, 32
    q = rand((B, C, Hq, D), 31)
    kc = rand((B, Smax, Hkv, D), 32)
    vc = rand((B, Smax, Hkv, D), 33)
    pos = jnp.asarray([0, 37], jnp.int32)
    want = fa_ref.prefill_reference(q, kc, vc, pos)
    with interpret_ctx():
        got = ops.attention_prefill(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------- #
# ops dispatch
# ---------------------------------------------------------------------- #

def test_ops_paged_dispatch_modes_agree():
    B, bs, MB, Hq, Hkv, D = 2, 8, 4, 4, 2, 32
    kp, vp, pages = make_pools(B, MB, bs, Hkv, D, seed=41)
    q = rand((B, 1, Hq, D), 42)
    qc = rand((B, 3, Hq, D), 43)
    lengths = jnp.asarray([7, 2 * bs], jnp.int32)
    pos = jnp.asarray([2, bs - 2], jnp.int32)
    base_dec = ops.attention_decode_paged(q, kp, vp, pages, lengths)
    base_pre = ops.attention_prefill_paged(qc, kp, vp, pages, pos)
    with interpret_ctx():
        k_dec = ops.attention_decode_paged(q, kp, vp, pages, lengths)
        k_pre = ops.attention_prefill_paged(qc, kp, vp, pages, pos)
    np.testing.assert_allclose(np.asarray(k_dec), np.asarray(base_dec),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(k_pre), np.asarray(base_pre),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------- #
# fused cache write
# ---------------------------------------------------------------------- #

def test_paged_write_fused_matches_scatter():
    B, C, bs, MB, Hkv, D = 2, 5, 4, 4, 2, 16
    kp, _, pages = make_pools(B, MB, bs, Hkv, D, seed=51)
    new = rand((B, C, Hkv, D), 52)
    pos = jnp.asarray([3, 9], jnp.int32)
    want = ops.paged_cache_write(kp, new, pages, pos)       # jnp scatter
    with interpret_ctx():
        got = ops.paged_cache_write(kp, new, pages, pos)    # fused kernel
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mode", ["xla", "pallas_interpret"])
def test_paged_write_overrun_hits_garbage_block(mode):
    """Regression: a chunk whose ``pos + C`` runs past the page table's
    last column must spill into the garbage block 0 — the old clip
    redirected those tokens into whatever LIVE block sat in the last
    column, corrupting another request's cache."""
    B, C, bs, MB, Hkv, D = 1, 4, 4, 3, 2, 8
    NB = B * MB + 1
    kp = rand((NB, bs, Hkv, D), 61)
    new = rand((B, C, Hkv, D), 62)
    pages = jnp.asarray([[3, 1, 2]], jnp.int32)
    pos = jnp.asarray([bs * MB - 2], jnp.int32)   # tokens 2,3 overrun
    with ctx.context_scope(dataclasses.replace(
            ctx.get_default_context(), kernels=mode)):
        out = np.asarray(ops.paged_cache_write(kp, new, pages, pos))
    old = np.asarray(kp)
    npnew = np.asarray(new)
    # in-bounds tokens land in the last column's block (id 2)
    np.testing.assert_array_equal(out[2, bs - 2], npnew[0, 0])
    np.testing.assert_array_equal(out[2, bs - 1], npnew[0, 1])
    # overrun tokens land in garbage block 0 — NOT in block 2
    np.testing.assert_array_equal(out[0, 0], npnew[0, 2])
    np.testing.assert_array_equal(out[0, 1], npnew[0, 3])
    # every non-garbage block slot outside the two written ones untouched
    mask = np.ones((NB, bs), bool)
    mask[0] = False
    mask[2, bs - 2:] = False
    np.testing.assert_array_equal(out[mask], old[mask])


# ---------------------------------------------------------------------- #
# engine smoke under the interpret kernels
# ---------------------------------------------------------------------- #

def test_engine_pallas_interpret_matches_xla():
    from repro.configs.base import ModelConfig
    from repro.models.registry import get_model
    from repro.serving.engine import Request, ServingEngine
    import repro.core as nn
    import jax

    cfg = ModelConfig(name="pk", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                      head_dim=16, remat="none")
    api = get_model(cfg)
    params = nn.init(lambda t: api.forward(t), jax.random.key(0),
                     jnp.zeros((1, 8), jnp.int32))
    outs = []
    for kernels in ("xla", "pallas_interpret"):
        eng = ServingEngine(api, params, max_batch=2, max_seq=32, chunk=4,
                            block_size=4, kernels=kernels)
        assert eng.paged
        for i in range(3):
            eng.submit(Request(uid=i, prompt=[1 + i, 2, 3, 4, 5],
                               max_new_tokens=4))
        outs.append({r.uid: r.generated for r in eng.run_until_drained()})
    assert outs[0] == outs[1]
