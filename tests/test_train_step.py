
"""Compiled train step: microbatching, skip-on-nonfinite, fp16 loop."""

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as nn
import repro.core.parametric as PF
import repro.core.functions as F
from repro.distributed.train_step import init_train_state, make_train_step
from repro.precision.loss_scale import dynamic_scaler, static_scaler
from repro.solvers import Adam, Sgd


def tiny_model(tokens, labels):
    h = PF.embed(tokens, 64, 16, name="emb")
    h = PF.dense(h, 64, name="out")
    return jnp.mean(F.softmax_cross_entropy(h, labels))


def make_batch(b=8, s=8, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, 64, (b, s)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, 64, (b, s)), jnp.int32)}


def loss_fn(p, batch):
    return nn.apply(tiny_model, p, batch["tokens"], batch["labels"])


def test_microbatch_equivalence():
    batch = make_batch()
    params = nn.init(tiny_model, jax.random.key(0), batch["tokens"],
                     batch["labels"])
    solver = Sgd(lr=0.1)
    scaler = static_scaler(1.0)
    s1 = init_train_state(params, solver, scaler)
    s4 = init_train_state(params, solver, scaler)
    step1 = make_train_step(loss_fn, solver, scaler, microbatches=1)
    step4 = make_train_step(loss_fn, solver, scaler, microbatches=4)
    out1, m1 = step1(s1, batch)
    out4, m4 = step4(s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for k in out1.params:
        np.testing.assert_allclose(np.asarray(out1.params[k]),
                                   np.asarray(out4.params[k]), rtol=1e-4,
                                   atol=1e-6)


def test_nonfinite_grads_skip_update_and_halve_scale():
    batch = make_batch()
    params = nn.init(tiny_model, jax.random.key(0), batch["tokens"],
                     batch["labels"])
    solver = Adam(alpha=0.1)
    scaler = dynamic_scaler(init_scale=1024.0)

    def bad_loss(p, b):
        # multiply by inf so the *gradients* (not just the loss) blow up
        return loss_fn(p, b) * jnp.float32(jnp.inf)

    step = make_train_step(bad_loss, solver, scaler)
    state = init_train_state(params, solver, scaler)
    new_state, metrics = step(state, batch)
    assert int(metrics["skipped"]) == 1
    assert float(new_state.scaler_state.scale) == 512.0
    for k in params:  # params unchanged
        np.testing.assert_array_equal(np.asarray(new_state.params[k]),
                                      np.asarray(params[k]))


def test_loss_decreases_over_steps():
    batch = make_batch()
    params = nn.init(tiny_model, jax.random.key(0), batch["tokens"],
                     batch["labels"])
    solver = Adam(alpha=0.01)
    scaler = static_scaler(1.0)
    step = jax.jit(make_train_step(loss_fn, solver, scaler))
    state = init_train_state(params, solver, scaler)
    losses = []
    for i in range(20):
        state, metrics = step(state, make_batch(seed=0))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8


def test_fp16_training_with_dynamic_scaling_converges():
    """Paper §3.3: fp16 storage + dynamic scaling trains stably."""
    ctx = nn.get_extension_context("cpu", type_config="half")
    with nn.context_scope(ctx):
        batch = make_batch()
        params = nn.init(tiny_model, jax.random.key(0), batch["tokens"],
                         batch["labels"])
        assert params["out/kernel"].dtype == jnp.float16
        solver = Adam(alpha=0.01)
        scaler = dynamic_scaler(init_scale=2.0 ** 10, interval=5)
        step = jax.jit(make_train_step(loss_fn, solver, scaler))
        state = init_train_state(params, solver, scaler)
        losses = []
        for _ in range(20):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0]
