"""Fault tolerance: deterministic injection, replica health, migration.

Three layers, mirroring the PR-8 stack:

* :class:`~repro.serving.faults.FaultInjector` semantics on a fake
  engine — step-indexed firing, windows, install/uninstall hygiene.
* Router health machine + stream-preserving migration on real engines
  driven by the sync driver — the bitwise-exactness contract.
* The async frontend's edge resilience over real sockets — crash-safe
  workers, disconnect cancellation, deadlines, retry, shedding.

Every chaos scenario is scripted by step index (never wall clock), so
each test is a reproducible unit test of a specific failure.
"""

import asyncio
import time

import jax
import jax.numpy as jnp
import pytest

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import (Fault, FaultInjector, FaultPlan,
                                  InjectedError, ReplicaDead)
from repro.serving.frontend import (AsyncFrontend, client_generate,
                                    client_get, retry_delays)
from repro.serving.router import (DEAD, HEALTHY, SUSPECT, Router,
                                  make_replica_engines)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, remat="none")

_PARAMS_CACHE: dict[str, dict] = {}


def init_params(cfg=CFG):
    if cfg.name not in _PARAMS_CACHE:
        api = get_model(cfg)
        _PARAMS_CACHE[cfg.name] = nn.init(
            lambda t: api.forward(t), jax.random.key(0),
            jnp.zeros((1, 8), jnp.int32))
    return _PARAMS_CACHE[cfg.name]


def make_engine(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("chunk", 8)
    return ServingEngine(get_model(CFG), init_params(), **kw)


def make_replicas(n=2, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("chunk", 8)
    return make_replica_engines(get_model(CFG), init_params(), replicas=n,
                                use_meshes=False, **kw)


def mixed_requests(n=6, plen=12, new=10):
    """Mixed greedy/sampled request kwargs; sampled ones carry explicit
    seeds so streams are placement-independent."""
    out = []
    for i in range(n):
        kw = dict(uid=i, prompt=[1 + (5 * i + j) % 96 for j in range(plen)],
                  max_new_tokens=new)
        if i % 2:
            kw.update(temperature=0.8, top_k=20, seed=100 + i)
        out.append(kw)
    return out


def reference_streams(kw_list):
    eng = make_engine()
    for kw in kw_list:
        eng.submit(Request(**kw))
    return {r.uid: list(r.generated) for r in eng.run_until_drained()}


def assert_no_leaks(eng):
    """After a drain, every live non-garbage block must be prefix-pinned;
    a full flush must free the whole pool."""
    assert eng.alloc.check_conservation()
    live = {b for b in range(1, eng.num_blocks)
            if eng.alloc.refcount(b) > 0}
    assert live <= eng.prefix.registered_blocks(), \
        f"leaked blocks: {sorted(live - eng.prefix.registered_blocks())}"
    eng.prefix.evict(eng.num_blocks)
    assert eng.alloc.free_blocks == eng.num_blocks - 1


# ---------------------------------------------------------------------- #
# injector semantics (fake engine: pure step-counting)
# ---------------------------------------------------------------------- #

class FakeEngine:
    def __init__(self):
        self.steps_run = 0

    def step(self):
        self.steps_run += 1
        return 0


def drive(inj, n):
    """n step attempts; returns the per-attempt outcome ('ok' or the
    exception class name)."""
    out = []
    for _ in range(n):
        try:
            inj.engine.step()
            out.append("ok")
        except (ReplicaDead, InjectedError) as e:
            out.append(type(e).__name__)
    return out


def test_error_fires_exactly_once():
    eng = FakeEngine()
    inj = FaultInjector(eng, [Fault(step=2, kind="error")]).install()
    assert drive(inj, 5) == ["ok", "ok", "InjectedError", "ok", "ok"]
    assert eng.steps_run == 4            # the faulted attempt never ran
    assert inj.fired == [(2, "error")]


def test_die_permanent_raises_forever():
    eng = FakeEngine()
    inj = FaultInjector(
        eng, [Fault(step=1, kind="die", steps=0)]).install()
    assert drive(inj, 5) == ["ok"] + ["ReplicaDead"] * 4
    assert eng.steps_run == 1


def test_die_window_recovers_after_n_attempts():
    eng = FakeEngine()
    inj = FaultInjector(
        eng, [Fault(step=2, kind="die", steps=3)]).install()
    # window [2, 5): attempts 2,3,4 raise — including failed probes,
    # which also advance the counter — then the replica recovers
    assert drive(inj, 7) == ["ok", "ok", "ReplicaDead", "ReplicaDead",
                             "ReplicaDead", "ok", "ok"]
    assert [a for a, _ in inj.fired] == [2, 3, 4]


def test_stall_sleeps_but_step_completes():
    eng = FakeEngine()
    slept = []
    inj = FaultInjector(eng, [Fault(step=1, kind="stall", stall_s=2.5,
                                    steps=2)],
                        sleep=slept.append).install()
    assert drive(inj, 4) == ["ok"] * 4   # nothing raises
    assert eng.steps_run == 4            # every step ran
    assert slept == [2.5, 2.5]           # window [1, 3) slept first
    assert inj.fired == [(1, "stall"), (2, "stall")]


def test_install_uninstall_restores_stock_engine():
    eng = FakeEngine()
    stock = eng.step
    inj = FaultInjector(eng, [Fault(step=0, kind="die", steps=0)])
    assert not inj.installed
    inj.install()
    assert "step" in eng.__dict__        # instance shadow, class untouched
    with pytest.raises(RuntimeError, match="already"):
        inj.install()
    with pytest.raises(RuntimeError, match="already wrapped"):
        FaultInjector(eng, []).install()
    inj.uninstall()
    assert "step" not in eng.__dict__
    assert eng.step == stock             # byte-for-byte the stock engine
    eng.step()
    assert eng.steps_run == 1


def test_fault_validation():
    with pytest.raises(ValueError, match="kind"):
        Fault(step=0, kind="explode")
    with pytest.raises(ValueError, match=">= 0"):
        Fault(step=-1, kind="die")
    with pytest.raises(ValueError, match="stall_s"):
        Fault(step=0, kind="stall")
    with pytest.raises(ValueError, match="die-only"):
        Fault(step=0, kind="error", steps=0)


def test_fault_plan_per_replica_install():
    plan = FaultPlan({1: [Fault(step=0, kind="die", steps=0)]})
    assert plan.for_replica(0) == []
    assert len(plan.for_replica(1)) == 1
    engines = [FakeEngine(), FakeEngine()]
    (inj,) = plan.install(engines)
    assert inj.engine is engines[1]
    engines[0].step()                    # unplanned replica is untouched
    with pytest.raises(ReplicaDead):
        engines[1].step()
    with pytest.raises(ValueError, match="only 1 engines"):
        FaultPlan({1: []}).install([FakeEngine()])
    # list shorthand targets replica 0
    assert FaultPlan([Fault(step=0, kind="error")]).for_replica(0)


# ---------------------------------------------------------------------- #
# router health machine
# ---------------------------------------------------------------------- #

def test_deadline_strikes_suspect_then_dead():
    router = Router(make_replicas(2), step_deadline_s=1.0)
    router.record_step_time(0, 0.01)
    assert router.health[0] == HEALTHY
    router.record_step_time(0, 1.5)      # first overrun: one strike
    assert router.health[0] == SUSPECT
    assert "deadline" in router.health_reason[0]
    router.record_step_time(0, 2.0)      # second consecutive: dead
    assert router.health[0] == DEAD
    assert router.replica_deaths == 1
    assert router.alive() == [1]
    # DEAD is sticky against further observations
    router.record_step_time(0, 0.01)
    assert router.health[0] == DEAD


def test_deadline_miss_heals_on_fast_step():
    router = Router(make_replicas(2), step_deadline_s=1.0)
    router.record_step_time(0, 1.5)
    assert router.health[0] == SUSPECT
    router.record_step_time(0, 0.01)     # recovered before strike two
    assert router.health[0] == HEALTHY
    assert router.health_reason[0] == ""
    assert router.replica_deaths == 0


def test_sustained_straggler_marks_suspect_not_dead():
    # below the hard deadline but way outside the step-time distribution:
    # the EWMA z-score needs `patience` consecutive outliers to flag
    router = Router(make_replicas(2), step_deadline_s=30.0)
    # small jitter builds a nonzero EWMA variance for the z-score
    for i in range(12):
        router.record_step_time(0, 0.010 + (i % 3) * 0.0005)
    for _ in range(2):
        router.record_step_time(0, 0.500)
    assert router.health[0] == HEALTHY   # not sustained yet
    router.record_step_time(0, 0.500)
    assert router.health[0] == SUSPECT
    assert "straggler" in router.health_reason[0]
    assert router.alive() == [0, 1]      # SUSPECT never changes routing
    router.record_step_time(0, 0.010)
    assert router.health[0] == HEALTHY


def test_dead_replica_excluded_from_every_policy():
    long_prompt = [5] * 40               # >= 1 block: affinity keys exist
    for policy in ("affinity", "random", "round_robin"):
        router = Router(make_replicas(2, block_size=16), policy=policy,
                        seed=3)
        router.mark_dead(0, "test")
        for i in range(6):
            prompt = long_prompt if i % 2 else [1 + i, 2, 3]
            rid = router.route(Request(uid=i, prompt=prompt,
                                       max_new_tokens=4))
            assert rid == 1, f"policy {policy} routed to a dead replica"
        router.mark_dead(1, "test")
        with pytest.raises(RuntimeError, match="no live replicas"):
            router.route(Request(uid=99, prompt=[1, 2],
                                 max_new_tokens=4))


def test_stats_surface_health_counters():
    router = Router(make_replicas(2))
    s = router.stats()
    assert s["replicas_alive"] == 2.0
    assert "replica_deaths" not in s     # healthy path: counters absent
    router.mark_dead(0, "test")
    s = router.stats()
    assert s["replicas_alive"] == 1.0
    assert s["replica_deaths"] == 1.0


# ---------------------------------------------------------------------- #
# migration: bitwise streams, zero leaks (sync driver)
# ---------------------------------------------------------------------- #

def test_replica_death_migrates_streams_bitwise():
    kw_list = mixed_requests(6)
    ref = reference_streams(kw_list)
    engines = make_replicas(2)
    router = Router(engines, seed=7)
    for kw in kw_list:
        router.submit(Request(**kw))
    assert all(c > 0 for c in router.routed), \
        "workload must exercise both replicas before the kill"
    inj = FaultInjector(engines[0],
                        [Fault(step=3, kind="die", steps=0)]).install()
    done = router.run_until_drained()
    assert inj.fired and inj.fired[0][1] == "die"
    assert router.replica_deaths == 1
    assert router.migration_failures == 0
    assert router.migrated_requests > 0
    streams = {r.uid: list(r.generated) for r in done}
    assert streams == ref, \
        "migrated streams must be bitwise the fault-free streams"
    migrated = [r for r in done if r.migrated]
    assert migrated and all(r.error is None for r in migrated)
    assert_no_leaks(engines[1])          # survivor
    assert_no_leaks(engines[0])          # victim: harvest freed its slots


def test_mid_step_error_also_kills_and_migrates():
    # a single raised exception is indistinguishable from death to the
    # step loop: the replica is killed, work migrates, probes readmit it
    kw_list = mixed_requests(4)
    ref = reference_streams(kw_list)
    engines = make_replicas(2)
    router = Router(engines, seed=7, probe_successes=2)
    for kw in kw_list:
        router.submit(Request(**kw))
    FaultInjector(engines[0], [Fault(step=2, kind="error")]).install()
    done = router.run_until_drained()
    assert router.replica_deaths == 1
    assert "step raised" in router.health_reason[0] \
        or router.health[0] == HEALTHY   # reason cleared on readmission
    assert {r.uid: list(r.generated) for r in done} == ref
    # probes succeed after the one-shot error: the replica is readmitted
    assert router.readmissions == 1
    assert router.health[0] == HEALTHY


def test_die_window_probe_readmission_and_reuse():
    kw_list = mixed_requests(6)
    ref = reference_streams(kw_list)
    engines = make_replicas(2)
    router = Router(engines, seed=7, probe_successes=2)
    for kw in kw_list:
        router.submit(Request(**kw))
    # dies at attempts [2, 5): the kill, then 2 failed probes, then clean
    # probes readmit — all deterministic in step attempts
    inj = FaultInjector(engines[0],
                        [Fault(step=2, kind="die", steps=3)]).install()
    done = router.run_until_drained()
    assert {r.uid: list(r.generated) for r in done} == ref
    assert router.replica_deaths == 1
    assert router.readmissions == 1
    assert router.health[0] == HEALTHY
    assert router.watchdog[0].n == 0     # fresh statistics after readmit
    assert inj.fired[-1][1] == "die"
    # the readmitted replica serves new traffic again
    n0 = len(engines[0].completed)
    for i in range(4):
        router.submit(Request(uid=100 + i, prompt=[2 + i, 3, 5],
                              max_new_tokens=4))
    router.run_until_drained()
    assert len(engines[0].completed) > n0, \
        "readmitted replica never served again"


def test_stall_trips_deadline_watchdog_and_migrates():
    # the stall fault raises nothing — only the wall-time deadline can
    # catch it. Two stalled steps = two strikes = dead + migration; once
    # the window passes, probes readmit.
    kw_list = mixed_requests(4)
    ref = reference_streams(kw_list)
    engines = make_replicas(2)
    router = Router(engines, seed=7, step_deadline_s=0.04,
                    probe_successes=2)
    for kw in kw_list:
        router.submit(Request(**kw))
    FaultInjector(engines[0], [Fault(step=0, kind="stall", stall_s=0.06,
                                     steps=4)]).install()
    done = router.run_until_drained()
    assert router.replica_deaths == 1
    assert "deadline" in dict(enumerate(router.health_reason)).get(0, "") \
        or router.health[0] == HEALTHY
    assert {r.uid: list(r.generated) for r in done} == ref
    assert_no_leaks(engines[1])


def test_non_resumable_request_fails_loudly():
    # a request within one position of max_seq cannot fold its generated
    # tokens back into a resume prompt — migration must refuse, not
    # silently truncate
    engines = make_replicas(2)
    router = Router(engines)
    req = Request(uid=0, prompt=list(range(1, 41)), max_new_tokens=40)
    req.generated = [3] * 30             # 40 + 30 > max_seq - 1 = 63
    fired = []
    req.on_tokens = lambda r, toks, done: fired.append((list(toks), done))
    assert router.place_migrated(req) is None
    assert router.migration_failures == 1
    assert "cannot migrate" in req.error
    assert fired == [([], True)], "the stream must fail loudly"


def test_scheduler_resubmit_rejects_duplicates_and_counts_cancels():
    eng = make_engine()
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4))
    with pytest.raises(ValueError, match="uid 0"):
        eng.resubmit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4))
    assert eng.cancel(0) is True
    assert eng.cancel(0) is False        # already gone: benign
    assert eng.scheduler.cancelled == 1
    assert eng.metrics_summary().get("cancelled", 1.0) == 1.0 \
        or not eng.completed             # summary empty with 0 completions


# ---------------------------------------------------------------------- #
# frontend: crash-safe workers, disconnects, deadlines, retry, shedding
# ---------------------------------------------------------------------- #

def serve(target, scenario, **fe_kw):
    fe_kw.setdefault("idle_wait", 0.002)

    async def _main():
        fe = AsyncFrontend(target, port=0, **fe_kw)
        await fe.start()
        try:
            return fe, await scenario(fe)
        finally:
            await fe.shutdown()

    return asyncio.run(_main())


def test_worker_crash_migrates_streams_to_survivor():
    kw_list = mixed_requests(6, new=8)
    ref = reference_streams(kw_list)
    engines = make_replicas(2, max_batch=3)
    router = Router(engines, seed=7)
    FaultInjector(engines[0],
                  [Fault(step=2, kind="die", steps=0)]).install()

    async def scenario(fe):
        return await asyncio.gather(*[
            client_generate("127.0.0.1", fe.port, prompt=kw["prompt"],
                            max_new_tokens=kw["max_new_tokens"],
                            temperature=kw.get("temperature", 0.0),
                            top_k=kw.get("top_k", 0),
                            seed=kw.get("seed", uid))
            for uid, kw in enumerate(kw_list)])

    fe, outs = serve(router, scenario)
    # every stream completed despite the replica death, tokens bitwise
    # (seeds pinned to the reference uids, so server-side uid order is
    # irrelevant to sampled streams; greedy is uid-free anyway)
    by_prompt = {tuple(kw["prompt"]): ref[kw["uid"]] for kw in kw_list}
    for uid, out in enumerate(outs):
        assert out["http_status"] == 200, out
        assert "error" not in out, out
        assert out["tokens"] == by_prompt[tuple(kw_list[uid]["prompt"])], \
            "a migrated stream diverged from the fault-free run"
    assert fe.stats.workers_crashed == 1
    assert fe.workers[0].crashed
    assert fe.stats.requests_migrated > 0
    assert router.health[0] == DEAD
    assert engines[0].worker_crashed == 1
    assert_no_leaks(engines[1])


def test_worker_crash_without_survivor_fails_streams_loudly():
    eng = make_engine()
    FaultInjector(eng, [Fault(step=1, kind="die", steps=0)]).install()

    async def scenario(fe):
        outs = await asyncio.gather(*[
            client_generate("127.0.0.1", fe.port, prompt=[1 + i, 2, 3],
                            max_new_tokens=32) for i in range(3)])
        metrics = await client_get("127.0.0.1", fe.port, "/metrics")
        return outs, metrics

    fe, (outs, metrics) = serve(eng, scenario)
    for out in outs:
        assert "worker crashed" in out["error"], \
            "streams must fail loudly, not hang"
    assert fe.stats.workers_crashed == 1
    assert fe.stats.requests_failed == 3
    assert metrics["worker_crashed"] == 1.0
    assert metrics["frontend_workers_crashed"] == 1.0


def test_client_disconnect_cancels_and_frees_blocks():
    eng = make_engine(max_batch=1, max_seq=256, chunk=8)

    async def scenario(fe):
        # hand-rolled dropper: read the SSE stream's first event, vanish
        reader, writer = await asyncio.open_connection("127.0.0.1",
                                                       fe.port)
        body = b'{"prompt": [1, 2, 3], "max_new_tokens": 200}'
        writer.write(
            (f"POST /generate HTTP/1.1\r\nHost: x\r\n"
             f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        await writer.drain()
        while True:                       # first data event = mid-stream
            line = await asyncio.wait_for(reader.readline(), 10.0)
            if line.startswith(b"data:"):
                break
        writer.close()
        # the next SSE write hits the dead socket -> cancel path; wait
        # for the engine to actually drop the request
        for _ in range(400):
            if not eng.has_work() and fe.stats.requests_cancelled:
                break
            await asyncio.sleep(0.01)
        return None

    fe, _ = serve(eng, scenario)
    assert fe.stats.requests_cancelled == 1
    assert eng.scheduler.cancelled == 1
    done = eng.completed
    assert not done or all(len(r.generated) < 200 for r in done)
    assert_no_leaks(eng)                 # zero leaked blocks after cancel


def test_request_deadline_times_out_with_504():
    eng = make_engine(max_batch=1, max_seq=512, chunk=8)

    async def scenario(fe):
        out = await client_generate("127.0.0.1", fe.port, stream=False,
                                    prompt=[1, 2, 3],
                                    max_new_tokens=400, deadline_s=0.25)
        for _ in range(400):
            if not eng.has_work():
                break
            await asyncio.sleep(0.01)
        return out

    fe, out = serve(eng, scenario)
    assert out["http_status"] == 504
    assert "deadline exceeded" in out["error"]
    assert fe.stats.requests_timed_out == 1
    assert eng.scheduler.cancelled == 1, \
        "an expired request must stop generating"
    assert_no_leaks(eng)


def test_retry_delays_deterministic_backoff():
    class FixedRng:
        def random(self):
            return 0.5

    ds = list(retry_delays(5, base_s=0.1, cap_s=0.5, jitter=0.2,
                           rng=FixedRng()))
    # min(cap, base * 2^i) * (1 + 0.2 * 0.5) = [.1, .2, .4, .5, .5] * 1.1
    assert ds == pytest.approx([0.11, 0.22, 0.44, 0.55, 0.55])
    assert list(retry_delays(0)) == []


def test_client_retries_transient_503():
    async def scenario(fe):
        rejected = await client_generate(
            "127.0.0.1", fe.port, prompt=[1, 2], max_new_tokens=4,
            retries=2, retry_base_s=0.005, retry_jitter=0.0)
        return rejected

    # max_queue=0 rejects every attempt: the client retries then reports
    fe, out = serve(make_engine(), scenario, max_queue=0)
    assert out["http_status"] == 503
    assert out["attempts"] == 3
    assert fe.stats.requests_rejected == 3

    # healthy server: exactly one attempt
    _, ok = serve(make_engine(),
                  lambda fe: client_generate(
                      "127.0.0.1", fe.port, prompt=[1, 2],
                      max_new_tokens=4, retries=2))
    assert ok["http_status"] == 200
    assert ok["attempts"] == 1


def test_degraded_pool_sheds_low_priority_only():
    engines = make_replicas(2)
    router = Router(engines, seed=7)
    router.mark_dead(0, "test")          # 1/2 alive <= shed_below=0.5

    async def scenario(fe):
        low = await client_generate("127.0.0.1", fe.port, prompt=[1, 2],
                                    max_new_tokens=4, priority=0)
        hi = await client_generate("127.0.0.1", fe.port, prompt=[1, 2],
                                   max_new_tokens=4, priority=1)
        health = await client_get("127.0.0.1", fe.port, "/health")
        return low, hi, health

    fe, (low, hi, health) = serve(router, scenario)
    assert low["http_status"] == 503
    assert "degraded" in low["error"]
    assert hi["http_status"] == 200      # high priority rides through
    assert hi["replica"] == 1
    assert fe.stats.requests_shed == 1
    assert health["replica_health"] == ["dead", "healthy"]


def test_healthy_pool_never_sheds():
    router = Router(make_replicas(2), seed=7)

    async def scenario(fe):
        return await client_generate("127.0.0.1", fe.port, prompt=[1, 2],
                                     max_new_tokens=4, priority=0)

    fe, out = serve(router, scenario, shed_below=1.0)
    assert out["http_status"] == 200     # all alive: shedding is inert
    assert fe.stats.requests_shed == 0


def test_stuck_step_watchdog_quarantines_and_migrates():
    # a real in-step stall (the injector's sleep), caught by the async
    # watchdog task polling step_started_t: the worker is marked DEAD for
    # routing, then quarantined -> crash path -> migration to replica 1
    kw_list = mixed_requests(4, plen=8, new=8)
    ref = reference_streams(kw_list)
    engines = make_replicas(2, max_batch=2, chunk=4)
    # warm EVERY compiled shape the workload can hit BEFORE arming the
    # watchdog: greedy + sampled decode compile distinct graphs, and a
    # migrated resume prompt (len 9..16) ends on any chunk width 1..4.
    # A first-step jit compile stalls inside one step for real, and the
    # deadline cannot tell compilation from a hang (deliberately so —
    # production sets step_deadline_s far above compile time).
    for eng in engines:
        for i, (plen, sampled) in enumerate(
                (p, s) for p in range(8, 12) for s in (False, True)):
            kw = dict(uid=-100 - i, max_new_tokens=4,
                      prompt=[1 + j % 96 for j in range(plen)])
            if sampled:
                kw.update(temperature=0.8, top_k=20, seed=7)
            eng.submit(Request(**kw))
            eng.run_until_drained()
        eng.completed.clear()
        eng.prefix.evict(eng.num_blocks)
    router = Router(engines, seed=7)
    FaultInjector(engines[0], [Fault(step=2, kind="stall", stall_s=0.8,
                                     steps=1)]).install()

    async def scenario(fe):
        return await asyncio.gather(*[
            client_generate("127.0.0.1", fe.port, prompt=kw["prompt"],
                            max_new_tokens=kw["max_new_tokens"],
                            temperature=kw.get("temperature", 0.0),
                            top_k=kw.get("top_k", 0),
                            seed=kw.get("seed", uid), timeout=60.0)
            for uid, kw in enumerate(kw_list)])

    fe, outs = serve(router, scenario, step_deadline_s=0.15)
    by_prompt = {tuple(kw["prompt"]): ref[kw["uid"]] for kw in kw_list}
    for uid, out in enumerate(outs):
        assert out["http_status"] == 200
        assert "error" not in out, out
        assert out["tokens"] == by_prompt[tuple(kw_list[uid]["prompt"])]
    assert router.health[0] == DEAD
    assert "stuck" in router.health_reason[0]
    assert fe.workers[0].crashed         # WorkerQuarantined -> crash path
    assert fe.stats.workers_crashed == 1
