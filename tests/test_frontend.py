"""Async HTTP/SSE frontend: concurrent streaming, backpressure, drain.

Plain ``asyncio.run`` inside ordinary test functions (the CI environment
has no pytest-asyncio). Each scenario starts a real server on an
ephemeral port, drives it with the stdlib client helpers from
:mod:`repro.serving.frontend`, and shuts it down — the worker threads,
SSE framing, admission probe and drain paths all run for real.
"""

import asyncio
import math

import jax
import jax.numpy as jnp
import pytest

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.frontend import (AsyncFrontend, client_generate,
                                    client_get)
from repro.serving.router import Router, make_replica_engines

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, remat="none")

_PARAMS_CACHE: dict[str, dict] = {}


def init_params(cfg=CFG):
    if cfg.name not in _PARAMS_CACHE:
        api = get_model(cfg)
        _PARAMS_CACHE[cfg.name] = nn.init(
            lambda t: api.forward(t), jax.random.key(0),
            jnp.zeros((1, 8), jnp.int32))
    return _PARAMS_CACHE[cfg.name]


def make_engine(**kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_seq", 64)
    kw.setdefault("chunk", 8)
    return ServingEngine(get_model(CFG), init_params(), **kw)


def serve(target, scenario, **fe_kw):
    """Start a frontend on an ephemeral port, run ``await scenario(fe)``,
    drain-shutdown, return (frontend, scenario result)."""
    fe_kw.setdefault("idle_wait", 0.002)

    async def _main():
        fe = AsyncFrontend(target, port=0, **fe_kw)
        await fe.start()
        try:
            return fe, await scenario(fe)
        finally:
            await fe.shutdown()

    return asyncio.run(_main())


def prompts(n=8):
    """n distinct prompts; greedy decode makes the streams deterministic
    regardless of arrival order or server-assigned uids."""
    return [[1 + i, 2 + i, 3, 4 + i % 3] for i in range(n)]


def reference_streams(ps, new=6):
    eng = make_engine()
    for i, p in enumerate(ps):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=new))
    done = {r.uid: r.generated for r in eng.run_until_drained()}
    return {tuple(p): done[i] for i, p in enumerate(ps)}


# ---------------------------------------------------------------------- #
# concurrent SSE streaming
# ---------------------------------------------------------------------- #

def test_eight_concurrent_sse_streams_match_direct_run():
    ps = prompts(8)
    ref = reference_streams(ps)

    async def scenario(fe):
        outs = await asyncio.gather(*[
            client_generate("127.0.0.1", fe.port, prompt=p,
                            max_new_tokens=6) for p in ps])
        metrics = await client_get("127.0.0.1", fe.port, "/metrics")
        return outs, metrics

    fe, (outs, metrics) = serve(make_engine(), scenario)
    for p, out in zip(ps, outs):
        assert out["http_status"] == 200
        assert out["done"] and out["n"] == 6
        assert not out["truncated"]
        assert out["tokens"] == ref[tuple(p)], \
            "streamed tokens must match a direct engine run"
        # SSE events carry exactly the summary's tokens, in order
        assert [t for e in out["events"] for t in e["tokens"]] \
            == out["tokens"]
        assert [e["index"] for e in out["events"]] \
            == list(range(len(out["events"])))
        assert out["ttft_s"] > 0.0
    assert fe.stats.requests_accepted == 8
    assert fe.stats.requests_completed == 8
    assert fe.stats.tokens_streamed == 48
    # per-token stream latency: 8 streams x 6 emissions = 40 gaps
    assert fe.stats.inter_token_n > 0
    assert fe.stats.mean_inter_token_s > 0.0
    assert metrics["http_status"] == 200
    assert metrics["frontend_tokens_streamed"] == 48.0
    assert metrics["frontend_mean_inter_token_s"] > 0.0
    assert metrics["mean_ttft_s"] > 0.0      # engine summary merged in


def test_non_streaming_json_response():
    ps = prompts(2)
    ref = reference_streams(ps)

    async def scenario(fe):
        return await asyncio.gather(*[
            client_generate("127.0.0.1", fe.port, stream=False, prompt=p,
                            max_new_tokens=6) for p in ps])

    _, outs = serve(make_engine(), scenario)
    for p, out in zip(ps, outs):
        assert out["http_status"] == 200
        assert out["events"] == []
        assert out["tokens"] == ref[tuple(p)]


def test_health_and_errors():
    async def scenario(fe):
        health = await client_get("127.0.0.1", fe.port, "/health")
        missing = await client_generate("127.0.0.1", fe.port,
                                        max_new_tokens=4)
        bad = await client_generate("127.0.0.1", fe.port, prompt="nope")
        lost = await client_get("127.0.0.1", fe.port, "/nope")
        return health, missing, bad, lost

    _, (health, missing, bad, lost) = serve(make_engine(), scenario)
    assert health["http_status"] == 200
    assert health["status"] == "ok"
    assert health["replicas"] == 1
    assert missing["http_status"] == 400
    assert "prompt" in missing["error"]
    assert bad["http_status"] == 400
    assert lost["http_status"] == 404


# ---------------------------------------------------------------------- #
# backpressure
# ---------------------------------------------------------------------- #

def test_queue_full_rejects_with_503():
    # max_queue=0: the depth check trips before any request is queued —
    # the deterministic form of "the queue is full"
    async def scenario(fe):
        return await client_generate("127.0.0.1", fe.port, prompt=[1, 2],
                                     max_new_tokens=4)

    fe, out = serve(make_engine(), scenario, max_queue=0)
    assert out["http_status"] == 503
    assert "queue is full" in out["error"]
    assert fe.stats.requests_rejected == 1
    assert fe.stats.requests_accepted == 0


def test_unplaceable_request_rejects_immediately():
    # pool of 2 usable 4-token blocks: a request needing 6 blocks can
    # never be placed — the would_admit probe rejects it at the door
    # instead of parking it at the head of the queue forever
    eng = make_engine(max_batch=1, block_size=4, num_blocks=3,
                      prefix_cache=False)

    async def scenario(fe):
        return await client_generate("127.0.0.1", fe.port,
                                     prompt=[1] * 8, max_new_tokens=16)

    fe, out = serve(eng, scenario)
    assert out["http_status"] == 503
    assert "pool" in out["error"]
    assert fe.stats.requests_rejected == 1


# ---------------------------------------------------------------------- #
# shutdown paths
# ---------------------------------------------------------------------- #

def test_graceful_drain_completes_inflight_streams():
    async def scenario():
        fe = AsyncFrontend(make_engine(), port=0, idle_wait=0.002)
        await fe.start()
        tasks = [asyncio.create_task(
            client_generate("127.0.0.1", fe.port, prompt=p,
                            max_new_tokens=8)) for p in prompts(4)]
        await asyncio.sleep(0.05)        # streams in flight
        await fe.shutdown(drain=True)
        return fe, await asyncio.gather(*tasks)

    fe, outs = asyncio.run(scenario())
    for out in outs:
        assert out["http_status"] == 200
        assert out["n"] == 8
        assert "error" not in out
    assert fe.stats.requests_completed == 4
    assert fe.stats.requests_failed == 0


def test_shutdown_without_drain_fails_streams_loudly():
    # long generations ensure the abort lands mid-flight: the streams
    # must end with an error event, not hang or pretend completion
    eng = make_engine(max_batch=2, max_seq=256, chunk=8)

    async def scenario():
        fe = AsyncFrontend(eng, port=0, idle_wait=0.002)
        await fe.start()
        tasks = [asyncio.create_task(
            client_generate("127.0.0.1", fe.port, prompt=[1 + i, 2],
                            max_new_tokens=500)) for i in range(2)]
        await asyncio.sleep(0.05)
        await fe.shutdown(drain=False)
        return fe, await asyncio.gather(*tasks)

    fe, outs = asyncio.run(scenario())
    for out in outs:
        assert out["http_status"] == 200      # stream started, then failed
        assert "aborted" in out["error"]
    assert fe.stats.requests_failed == 2
    # abandoned actives were finished: their blocks are back in the pool
    live = {b for b in range(1, eng.num_blocks)
            if eng.alloc.refcount(b) > 0}
    assert live <= eng.prefix.registered_blocks()


# ---------------------------------------------------------------------- #
# multi-replica: frontend over the router
# ---------------------------------------------------------------------- #

def test_frontend_over_router_streams_and_feeds_ttft():
    ps = prompts(8)
    ref = reference_streams(ps)
    engines = make_replica_engines(get_model(CFG), init_params(),
                                   replicas=2, use_meshes=False,
                                   max_batch=3, max_seq=64, chunk=8)
    router = Router(engines)

    async def scenario(fe):
        outs = await asyncio.gather(*[
            client_generate("127.0.0.1", fe.port, prompt=p,
                            max_new_tokens=6) for p in ps])
        health = await client_get("127.0.0.1", fe.port, "/health")
        metrics = await client_get("127.0.0.1", fe.port, "/metrics")
        return outs, health, metrics

    fe, (outs, health, metrics) = serve(router, scenario)
    for p, out in zip(ps, outs):
        assert out["http_status"] == 200
        assert out["replica"] in (0, 1)
        assert out["tokens"] == ref[tuple(p)], \
            "routing must never change a token stream"
    assert health["replicas"] == 2
    assert metrics["replicas"] == 2.0
    assert metrics["routed_total"] == 8.0
    assert fe.stats.requests_completed == 8
    # first-token events fed the router's EWMA load signal
    used = [r for r, n in enumerate(router.routed) if n]
    assert all(not math.isnan(router.ewma_ttft[r]) for r in used)
