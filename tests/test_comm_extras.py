
"""Communicator extras: bucketing, error feedback (subprocess collectives)."""

import jax.numpy as jnp
import numpy as np

from repro.comm import flatten_buckets


def test_flatten_buckets_respects_size():
    tree = {f"p{i}": jnp.zeros((1024, 1024), jnp.float32)  # 4 MiB each
            for i in range(10)}
    buckets = flatten_buckets(tree, bucket_bytes=8 * 2**20)
    assert sum(len(b) for b in buckets) == 10
    assert all(len(b) <= 2 for b in buckets)      # 2 x 4 MiB fits, 3 doesn't
    flat = [k for b in buckets for k in b]
    assert flat == sorted(tree)                    # deterministic order


def test_single_giant_tensor_gets_own_bucket():
    tree = {"big": jnp.zeros((64, 1024, 1024), jnp.float32),
            "small": jnp.zeros(4, jnp.float32)}
    buckets = flatten_buckets(tree, bucket_bytes=2**20)
    assert ["big"] in buckets


EF_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.comm import error_feedback_reduce

mesh = jax.make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)
err0 = jnp.zeros((4, 256), jnp.float32)

f = shard_map(lambda v, e: error_feedback_reduce(v, e, "data"),
              mesh=mesh, in_specs=(P("data"), P("data")),
              out_specs=(P("data"), P("data")), check_rep=False)
exact = np.asarray(x).mean(0)

# the EF guarantee: the RUNNING MEAN of estimates converges to the exact
# value (the carried residual cancels quantization bias over steps), and
# the residual stays bounded
err = err0
ests = []
for _ in range(16):
    est, err = f(x, err)
    ests.append(np.asarray(est)[0])
e_mean = np.abs(np.mean(ests, axis=0) - exact).max()
one, _ = f(x, err0)
e_singleshot = np.abs(np.asarray(one)[0] - exact).max()
assert e_mean <= e_singleshot * 0.75, (e_mean, e_singleshot)
assert np.abs(np.asarray(err)).max() < 1.0
print("EF-OK", e_mean, e_singleshot)
"""


def test_error_feedback_runs(subproc):
    out = subproc(EF_CODE, devices=4)
    assert "EF-OK" in out
