
"""Beyond-paper optimizations == paper-faithful math (the §Perf safety net)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import ref as fa_ref
from repro.models import transformer as T


def rand(shape, seed=0, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), dtype)


class TestFoldedChunkedAttention:
    @pytest.mark.parametrize("S,bq", [(256, 32), (512, 64), (256, 128)])
    def test_folded_causal_matches_reference(self, S, bq):
        q, k, v = rand((2, S, 4, 64), 1), rand((2, S, 2, 64), 2), \
            rand((2, S, 2, 64), 3)
        got = fa_ref.mha_chunked(q, k, v, causal=True, block_q=bq, block_k=bq)
        want = fa_ref.mha_reference(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-6, rtol=2e-5)

    def test_noncausal_and_window(self):
        q, k, v = rand((1, 256, 4, 32), 4), rand((1, 256, 4, 32), 5), \
            rand((1, 256, 4, 32), 6)
        for kw in ({"causal": False}, {"causal": True, "window": 64}):
            got = fa_ref.mha_chunked(q, k, v, block_q=64, block_k=64, **kw)
            want = fa_ref.mha_reference(q, k, v, **kw)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=2e-6, rtol=2e-5)

    def test_grads_match(self):
        q, k, v = rand((1, 128, 2, 32), 7), rand((1, 128, 2, 32), 8), \
            rand((1, 128, 2, 32), 9)
        g1 = jax.grad(lambda q: fa_ref.mha_chunked(
            q, k, v, causal=True, block_q=32, block_k=32).sum())(q)
        g2 = jax.grad(lambda q: fa_ref.mha_reference(
            q, k, v, causal=True).sum())(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=2e-5, rtol=2e-4)

    def test_unrolled_matches_scanned(self):
        q, k, v = rand((1, 256, 2, 32), 10), rand((1, 256, 2, 32), 11), \
            rand((1, 256, 2, 32), 12)
        a = fa_ref.mha_chunked(q, k, v, causal=True, block_q=64, block_k=64)
        b = fa_ref.mha_chunked(q, k, v, causal=True, block_q=64, block_k=64,
                               unroll=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, remat="none")


class TestChunkedLoss:
    def test_loss_and_grads_match_plain(self):
        cfgc = dataclasses.replace(CFG, loss_chunk=8)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(1, 97, (2, 32)), jnp.int32)
        labs = jnp.asarray(rng.integers(1, 97, (2, 32)), jnp.int32)
        params = nn.init(lambda t: T.forward(CFG, t), jax.random.key(0), toks)
        l1 = nn.apply(lambda t, l: T.loss_fn(CFG, t, l), params, toks, labs)
        l2 = nn.apply(lambda t, l: T.loss_fn(cfgc, t, l), params, toks, labs)
        assert abs(float(l1) - float(l2)) < 1e-5
        g1 = jax.grad(lambda p: nn.apply(
            lambda t, l: T.loss_fn(CFG, t, l), p, toks, labs))(params)
        g2 = jax.grad(lambda p: nn.apply(
            lambda t, l: T.loss_fn(cfgc, t, l), p, toks, labs))(params)
        for k in g1:
            np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                       atol=2e-5, rtol=2e-4)


MERGED_CODE = """
import jax, jax.numpy as jnp, numpy as np
import repro.core as nn
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.distributed.sharding import ShardingEnv, sharding_env

# 6 heads on a 4-wide model axis -> merged batch x kv-head path triggers
cfg = ModelConfig(name="m", family="dense", n_layers=1, d_model=48,
                  n_heads=6, n_kv_heads=2, d_ff=64, vocab_size=64,
                  head_dim=8, remat="none")
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(1, 64, (4, 16)), jnp.int32)
params = nn.init(lambda t: T.forward(cfg, t), jax.random.key(0), toks)
ref, _ = nn.apply(lambda t: T.forward(cfg, t), params, toks)  # no mesh

mesh = jax.make_mesh((2, 4), ("data", "model"))
env = ShardingEnv(mesh=mesh,
                  axis_rules={"batch": "data", "heads": "model",
                              "batch_kv": ("data", "model"),
                              "seq": None, "embed": None})
with sharding_env(env):
    f = jax.jit(lambda p, t: nn.apply(lambda tt: T.forward(cfg, tt), p, t)[0])
    got = f(params, toks)
np.testing.assert_allclose(np.asarray(ref, np.float32),
                           np.asarray(got, np.float32), atol=2e-2, rtol=2e-2)
print("MERGED-OK")
"""


def test_merged_batch_kv_sharding_matches(subproc):
    out = subproc(MERGED_CODE, devices=8)
    assert "MERGED-OK" in out


class TestSplitProj:
    def test_split_decode_matches_forward(self):
        cfg = ModelConfig(name="s", family="ssm", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=1, d_ff=0, vocab_size=97,
                          ssm_state=16, ssm_head_dim=32, ssm_chunk=8,
                          remat="none", ssm_split_proj=True)
        from repro.models import mamba as M
        rng = np.random.default_rng(1)
        S = 8
        seq = jnp.asarray(rng.integers(1, 97, (1, S)), jnp.int32)
        ps = nn.init(lambda t: M.forward(cfg, t), jax.random.key(0), seq)
        full, _ = nn.apply(lambda t: M.forward(cfg, t), ps, seq)
        st = M.init_state(cfg, 1, dtype=jnp.float32)
        outs = []
        for i in range(S):
            lg, st = nn.apply(lambda t, s, p: M.decode_step(cfg, t, s, p),
                              ps, seq[:, i:i + 1], st,
                              jnp.asarray(i, jnp.int32))
            outs.append(lg[:, 0])
        np.testing.assert_allclose(np.asarray(full),
                                   np.asarray(jnp.stack(outs, 1)),
                                   atol=5e-3, rtol=1e-2)
