
"""Roofline HLO parsing + term arithmetic (pure unit tests)."""

from repro.launch.roofline import (CollectiveStats, parse_collectives,
                                   roofline_terms, PEAK_FLOPS, HBM_BW,
                                   LINK_BW)

HLO = """
ENTRY %main {
  %ag = bf16[16,512]{1,0} all-gather(bf16[1,512]{1,0} %p0), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %p1), replica_groups=[1,256]<=[256], to_apply=%sum
  %rs = f32[64]{0} reduce-scatter(f32[1024]{0} %p2), replica_groups=[16,16]<=[256], dimensions={0}
  %a2a = bf16[8,128]{1,0} all-to-all(bf16[8,128]{1,0} %p3), replica_groups={{0,1,2,3}}
  %cp = f32[256]{0} collective-permute(f32[256]{0} %p4), source_target_pairs={{0,1}}
  %done = f32[8] all-reduce-done(f32[8] %x)
}
"""


def test_parse_kinds_and_bytes():
    st = parse_collectives(HLO)
    assert st.by_kind_count["all-gather"] == 1
    assert st.by_kind_count["all-reduce"] == 1   # -done line skipped
    assert st.by_kind_count["reduce-scatter"] == 1
    assert st.by_kind_count["all-to-all"] == 1
    assert st.by_kind_count["collective-permute"] == 1
    assert st.by_kind_bytes["all-gather"] == 1 * 512 * 2      # operand
    assert st.by_kind_bytes["all-reduce"] == 1024 * 4
    assert st.by_kind_bytes["all-to-all"] == 8 * 128 * 2
    assert st.operand_bytes == sum(st.by_kind_bytes.values())


def test_wire_model_factors():
    st = parse_collectives(HLO)
    ops = {o["kind"]: o for o in st.ops}
    # AG: result*(g-1)/g with g=16
    assert abs(ops["all-gather"]["wire_bytes"]
               - 16 * 512 * 2 * 15 / 16) < 1
    # AR: 2*operand*(g-1)/g with g=256
    assert abs(ops["all-reduce"]["wire_bytes"]
               - 2 * 1024 * 4 * 255 / 256) < 1
    # explicit replica_groups {{0,1,2,3}} -> g=4
    assert ops["all-to-all"]["group"] == 4


def test_terms_and_bottleneck():
    st = CollectiveStats({}, {}, operand_bytes=int(LINK_BW), wire_bytes=0.0,
                         ops=[])
    terms = roofline_terms({"flops": PEAK_FLOPS * 0.5,
                            "bytes accessed": HBM_BW * 0.25}, st, 256)
    assert abs(terms["t_compute_s"] - 0.5) < 1e-9
    assert abs(terms["t_memory_s"] - 0.25) < 1e-9
    assert abs(terms["t_collective_s"] - 1.0) < 1e-9
    assert terms["bottleneck"] == "collective"
    assert abs(terms["roofline_fraction"] - 0.5) < 1e-9


def test_memory_adjustment_applies():
    st = CollectiveStats({}, {}, 0, 0.0, [])
    adj = {"attn_intermediate_bytes": HBM_BW * 1.0,
           "attn_kernel_bytes": HBM_BW * 0.1,
           "ssd_intermediate_bytes": 0.0, "ssd_kernel_bytes": 0.0}
    terms = roofline_terms({"flops": 0.0, "bytes accessed": HBM_BW * 2.0},
                           st, 256, mem_adjust=adj)
    assert abs(terms["t_memory_raw_s"] - 2.0) < 1e-9
    assert abs(terms["t_memory_s"] - 1.1) < 1e-9
