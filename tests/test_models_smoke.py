
"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
assert output shapes + no NaNs. Full configs are exercised by the dry-run."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as nn
from repro.configs import ARCHS
from repro.models.registry import get_model
from repro.distributed.train_step import init_train_state, make_train_step
from repro.precision.loss_scale import static_scaler
from repro.solvers import Adam

ARCH_IDS = sorted(ARCHS)


def _inputs(cfg, b=2, s=32):
    if cfg.ssm_state:
        s = max(s, cfg.ssm_chunk * 2)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    if cfg.mrope:
        pos = np.broadcast_to(np.arange(s, dtype=np.int32)[None, :, None],
                              (b, s, 3))
        batch["positions"] = jnp.asarray(np.ascontiguousarray(pos))
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_finite(arch):
    cfg = ARCHS[arch].smoke()
    api = get_model(cfg)
    batch = _inputs(cfg)
    fwd_kwargs = {k: v for k, v in batch.items() if k != "labels"}
    params = nn.init(lambda **kw: api.forward(**kw), jax.random.key(0),
                     **fwd_kwargs)
    logits, aux = nn.apply(lambda **kw: api.forward(**kw), params,
                           **fwd_kwargs)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/Inf in logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    cfg = dataclasses.replace(ARCHS[arch].smoke(), remat="none")
    api = get_model(cfg)
    batch = _inputs(cfg)

    def loss_fn(p, b):
        return nn.apply(lambda **kw: api.loss_fn(**kw), p, **b)

    fwd_kwargs = {k: v for k, v in batch.items() if k != "labels"}
    params = nn.init(lambda **kw: api.forward(**kw), jax.random.key(0),
                     **fwd_kwargs)
    solver = Adam(alpha=1e-3)
    scaler = static_scaler(1.0)
    state = init_train_state(params, solver, scaler)
    step = make_train_step(loss_fn, solver, scaler)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(metrics["skipped"]) == 0
    changed = any(
        not np.array_equal(np.asarray(new_state.params[k]),
                           np.asarray(params[k])) for k in params)
    assert changed, f"{arch}: train step changed no parameters"


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-370m",
                                  "zamba2-1.2b", "whisper-medium"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode == full forward (cache correctness), per family."""
    cfg = dataclasses.replace(ARCHS[arch].smoke(), remat="none")
    api = get_model(cfg)
    S = 8 if not cfg.ssm_state else cfg.ssm_chunk
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, S)), jnp.int32)
    kwargs = {}
    if cfg.family == "audio":
        kwargs["frames"] = jnp.asarray(
            rng.standard_normal((1, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)
    params = nn.init(lambda t, **kw: api.forward(t, **kw), jax.random.key(0),
                     toks, **kwargs)
    full, _ = nn.apply(lambda t, **kw: api.forward(t, **kw), params, toks,
                       **kwargs)

    if cfg.family == "audio":
        from repro.models import whisper
        state = nn.apply(
            lambda f: whisper.init_decode_state(cfg, f, S + 4, jnp.float32),
            params, kwargs["frames"])
    else:
        state = api.decode_state_init(1, S + 4, jnp.float32)
    outs = []
    for i in range(S):
        lg, state = nn.apply(
            lambda t, s, p: api.decode_step(t, s, p), params,
            toks[:, i:i + 1], state, jnp.asarray(i, jnp.int32))
        outs.append(lg[:, 0])
    stepped = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stepped),
                               atol=5e-3, rtol=1e-2)


def test_moe_capacity_drops_tokens_but_stays_finite():
    cfg = dataclasses.replace(ARCHS["granite-moe-1b-a400m"].smoke(),
                              capacity_factor=0.5, remat="none")
    api = get_model(cfg)
    batch = _inputs(cfg)
    fwd_kwargs = {k: v for k, v in batch.items() if k != "labels"}
    params = nn.init(lambda **kw: api.forward(**kw), jax.random.key(0),
                     **fwd_kwargs)
    logits, aux = nn.apply(lambda **kw: api.forward(**kw), params,
                           **fwd_kwargs)
    assert bool(jnp.isfinite(logits).all())
    assert float(aux) > 0.0


def test_param_counts_match_nameplate():
    expected = {
        "phi3.5-moe-42b-a6.6b": (41.9e9, 0.03),
        "deepseek-coder-33b": (33.3e9, 0.03),
        "llama3.2-1b": (1.24e9, 0.05),
        "mistral-nemo-12b": (12.2e9, 0.05),
        "qwen2-vl-72b": (72.7e9, 0.03),
        "mamba2-370m": (0.37e9, 0.10),
        "whisper-medium": (0.81e9, 0.10),
    }
    for arch, (want, tol) in expected.items():
        got = ARCHS[arch].param_count()
        assert abs(got - want) / want < tol, f"{arch}: {got:.3e} vs {want:.3e}"
