"""Memory-mapped token-file dataset."""

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.token_file import TokenFilePipeline, write_token_file

CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=8,
                  n_heads=1, n_kv_heads=1, d_ff=16, vocab_size=1000)


def test_roundtrip_and_determinism(tmp_path):
    path = str(tmp_path / "c.bin")
    write_token_file(path, np.arange(10_000) % 1000)
    shape = ShapeConfig("t", 16, 4, "train")
    p1 = TokenFilePipeline(path, CFG, shape, seed=3)
    p2 = TokenFilePipeline(path, CFG, shape, seed=3)
    b1, b2 = p1.batch_at(5), p2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert b1["tokens"].max() < CFG.vocab_size


def test_shards_differ(tmp_path):
    path = str(tmp_path / "c.bin")
    write_token_file(path, np.arange(10_000) % 1000)
    shape = ShapeConfig("t", 16, 4, "train")
    a = TokenFilePipeline(path, CFG, shape, shard=(0, 2)).batch_at(0)
    b = TokenFilePipeline(path, CFG, shape, shard=(1, 2)).batch_at(0)
    assert not np.array_equal(a["tokens"], b["tokens"])
