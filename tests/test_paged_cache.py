"""Block-paged KV cache: allocator invariants (deterministic), paged-vs-
dense logits equivalence, engine behavior under paging + prefix reuse.

Property-based allocator tests live in ``test_paged_allocator_props.py``
(hypothesis, optional); this module runs everywhere.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.paged import (BlockAllocator, PrefixCache,
                                 blocks_for_tokens, prefix_keys)

DENSE = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                    head_dim=16, remat="none")
SSM = ModelConfig(name="ssm", family="ssm", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, ssm_state=16, ssm_head_dim=32, ssm_chunk=4,
                  remat="none")
HYBRID = ModelConfig(name="hyb", family="hybrid", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                     head_dim=16, ssm_state=16, ssm_head_dim=32, ssm_chunk=4,
                     attn_every=2, remat="none")

_PARAMS_CACHE: dict[str, dict] = {}


def init_params(cfg):
    if cfg.name not in _PARAMS_CACHE:
        api = get_model(cfg)
        _PARAMS_CACHE[cfg.name] = nn.init(
            lambda t: api.forward(t), jax.random.key(0),
            jnp.zeros((1, 8), jnp.int32))
    return _PARAMS_CACHE[cfg.name]


# ---------------------------------------------------------------------- #
# allocator + prefix map invariants (deterministic)
# ---------------------------------------------------------------------- #

def test_allocator_alloc_free_roundtrip():
    a = BlockAllocator(8, 4)
    assert a.free_blocks == 7          # block 0 reserved
    got = a.alloc(3)
    assert len(set(got)) == 3 and 0 not in got
    assert a.free_blocks == 4 and all(a.refcount(b) == 1 for b in got)
    for b in got:
        assert a.decref(b)             # freed
    assert a.free_blocks == 7 and a.check_conservation()


def test_allocator_double_free_raises():
    a = BlockAllocator(4, 4)
    (b,) = a.alloc(1)
    a.decref(b)
    with pytest.raises(ValueError):
        a.decref(b)
    with pytest.raises(ValueError):
        a.incref(b)                    # free blocks can't be shared either


def test_allocator_overcommit_raises():
    a = BlockAllocator(4, 4)
    with pytest.raises(MemoryError):
        a.alloc(4)                     # only 3 usable
    assert a.check_conservation()


def test_allocator_shared_block_survives_one_owner():
    a = BlockAllocator(4, 4)
    (b,) = a.alloc(1)
    a.incref(b)                        # second page table references it
    assert not a.decref(b)             # first owner leaves: still live
    assert a.refcount(b) == 1
    assert a.decref(b)                 # last owner frees it
    assert a.check_conservation()


def test_allocator_fork_copy_on_write():
    a = BlockAllocator(8, 4)
    (b,) = a.alloc(1)
    assert a.fork(b) is None           # exclusive: write in place
    a.incref(b)
    nb = a.fork(b)                     # shared: get a private copy
    assert nb is not None and nb != b
    assert a.refcount(b) == 1 and a.refcount(nb) == 1
    assert a.check_conservation()


def test_prefix_cache_register_lookup_evict():
    a = BlockAllocator(8, 4)
    pc = PrefixCache(a)
    toks = list(range(12))
    keys = prefix_keys(toks, 4)
    assert len(keys) == 3
    blocks = a.alloc(3)
    for k, b in zip(keys, blocks):
        pc.register(k, b)
    for b in blocks:                   # owner completes
        a.decref(b)
    assert a.live_blocks == 3          # map pins them
    hits = pc.lookup(keys)
    assert hits == blocks              # same prefix -> same blocks, shared
    assert all(a.refcount(b) == 2 for b in blocks)
    miss = pc.lookup(prefix_keys(list(range(99, 111)), 4))
    assert miss == []
    pc.release(hits)
    assert pc.evict(10) == 3           # idle now: all evictable, LRU
    assert a.free_blocks == 7 and a.check_conservation()


def test_prefix_cache_never_evicts_in_use_blocks():
    a = BlockAllocator(8, 4)
    pc = PrefixCache(a)
    keys = prefix_keys(list(range(8)), 4)
    blocks = a.alloc(2)
    for k, b in zip(keys, blocks):
        pc.register(k, b)
    hits = pc.lookup(keys)             # a second request shares them
    assert pc.evictable() == 0
    assert pc.evict(10) == 0           # nothing evictable while shared
    pc.release(hits)
    for b in blocks:
        a.decref(b)
    assert pc.evictable() == 2
    assert pc.evict(10) == 2


def test_prefix_cache_peek_mutates_nothing():
    """Failed-admission retries peek every step: no refcounts, stats or
    LRU order may move until the admission commits."""
    a = BlockAllocator(8, 4)
    pc = PrefixCache(a)
    keys = prefix_keys(list(range(8)), 4)
    blocks = a.alloc(2)
    for k, b in zip(keys, blocks):
        pc.register(k, b)
    for _ in range(5):
        assert pc.peek(keys) == blocks
    assert pc.hits == 0 and pc.misses == 0
    assert all(a.refcount(b) == 2 for b in blocks)  # owner + map only
    pc.commit(keys, 2)
    assert pc.hits == 2 and pc.misses == 0


def test_prefix_cache_commit_survives_evicted_peeked_key():
    """The deepest peeked hit is popped by the never-skip-the-whole-
    prompt rule and therefore NOT acquired — the admission's own eviction
    pass can free it between peek and commit. commit must refresh the
    surviving keys instead of KeyError-ing on the evicted one (found by
    the scheduler interleaving property tests)."""
    a = BlockAllocator(8, 4)
    pc = PrefixCache(a)
    keys = prefix_keys(list(range(8)), 4)
    blocks = a.alloc(2)
    for k, b in zip(keys, blocks):
        pc.register(k, b)
    for b in blocks:
        a.decref(b)                    # only the map holds them now
    hits = pc.peek(keys)
    peeked = len(hits)
    hits.pop()                         # whole-prompt hit: drop the deepest
    pc.acquire(hits)
    assert pc.evict(1) == 1            # frees the unacquired deepest entry
    pc.commit(keys, peeked)            # must not raise
    assert pc.hits == peeked
    pc.release(hits)
    assert a.check_conservation()


def test_prefix_key_sensitivity():
    # same block content after a different prefix must key differently
    # (the digest chain commits to the whole prefix, not just the block)
    k1 = prefix_keys([1, 2, 3, 4, 9, 9, 9, 9], 4)
    k2 = prefix_keys([5, 6, 7, 8, 9, 9, 9, 9], 4)
    assert k1[0] != k2[0] and k1[1] != k2[1]
    # deterministic across calls (the map must survive re-keying)
    assert k1 == prefix_keys([1, 2, 3, 4, 9, 9, 9, 9], 4)
    # token boundaries are unambiguous: [1, 23] vs [12, 3] differ
    assert prefix_keys([1, 23], 2) != prefix_keys([12, 3], 2)
    assert blocks_for_tokens(0, 4) == 0
    assert blocks_for_tokens(9, 4) == 3


# ---------------------------------------------------------------------- #
# paged vs dense: exact logits equivalence at the model level
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("block_size", [4, 8, 16])
@pytest.mark.parametrize("cfg", [DENSE, HYBRID],
                         ids=[c.family for c in [DENSE, HYBRID]])
def test_paged_matches_dense_logits(cfg, block_size):
    """Identical (bitwise) logits from the dense cache and the block pool,
    for every prefill chunk and decode step. chunk=5 with plen=13 makes
    chunks span block boundaries mid-chunk at block_size 4 and 8, and the
    ragged tail exercises pad-column writes into partial blocks."""
    api = get_model(cfg)
    params = init_params(cfg)
    B, plen, chunk, ndec = 2, 13, 5, 3
    S_dense = 32                       # == max_blocks * block_size below
    MB = S_dense // block_size
    rng = np.random.default_rng(7)
    toks = rng.integers(1, cfg.vocab_size, (B, plen)).astype(np.int32)

    def run_dense():
        st = api.decode_state_init(B, S_dense, jnp.float32)
        out = []
        off, cur = 0, None
        while off < plen:
            k = min(chunk, plen - off)
            buf = np.zeros((B, chunk), np.int32)
            buf[:, :k] = toks[:, off:off + k]
            lg, st = nn.apply(lambda t, s, p, l: api.prefill(t, s, p, l),
                              params, jnp.asarray(buf), st,
                              jnp.full((B,), off, jnp.int32),
                              jnp.full((B,), k, jnp.int32))
            off += k
        out.append(np.asarray(lg, np.float32))
        cur = np.argmax(out[-1][:, -1], -1).astype(np.int32)
        for i in range(ndec):
            lg, st = nn.apply(lambda t, s, p, l: api.prefill(t, s, p, l),
                              params, jnp.asarray(cur[:, None]), st,
                              jnp.full((B,), plen + i, jnp.int32),
                              jnp.ones((B,), jnp.int32))
            out.append(np.asarray(lg, np.float32))
            cur = np.argmax(out[-1][:, -1], -1).astype(np.int32)
        return out

    def run_paged():
        NB = B * MB + 1                # + garbage block 0
        st = api.paged_state_init(B, NB, block_size, jnp.float32)
        pages = jnp.asarray(
            1 + np.arange(B * MB).reshape(B, MB).astype(np.int32))
        out = []
        off, cur = 0, None
        while off < plen:
            k = min(chunk, plen - off)
            buf = np.zeros((B, chunk), np.int32)
            buf[:, :k] = toks[:, off:off + k]
            lg, st = nn.apply(
                lambda t, s, g, p, l: api.prefill_paged(t, s, g, p, l),
                params, jnp.asarray(buf), st, pages,
                jnp.full((B,), off, jnp.int32),
                jnp.full((B,), k, jnp.int32))
            off += k
        out.append(np.asarray(lg, np.float32))
        cur = np.argmax(out[-1][:, -1], -1).astype(np.int32)
        for i in range(ndec):
            lg, st = nn.apply(
                lambda t, s, g, p, l: api.prefill_paged(t, s, g, p, l),
                params, jnp.asarray(cur[:, None]), st, pages,
                jnp.full((B,), plen + i, jnp.int32),
                jnp.ones((B,), jnp.int32))
            out.append(np.asarray(lg, np.float32))
            cur = np.argmax(out[-1][:, -1], -1).astype(np.int32)
        return out

    dense, paged = run_dense(), run_paged()
    assert len(dense) == len(paged) == 1 + ndec
    # XLA reference modes gather the pool into the exact dense cache, so
    # logits agree BITWISE. Pallas modes partition the online softmax by
    # block_size — dense and paged walk different partitions, so equality
    # is tight-allclose, not bitwise (CI's REPRO_KERNELS=pallas_interpret
    # leg takes this branch).
    from repro.core import context as _ctx
    bitwise = _ctx.get_default_context().kernels in ("xla", "xla_chunked")
    for i, (a, b) in enumerate(zip(dense, paged)):
        if bitwise:
            np.testing.assert_array_equal(
                a, b, err_msg=f"step {i}: paged logits diverge from dense")
        else:
            np.testing.assert_allclose(
                a, b, atol=1e-4, rtol=1e-4,
                err_msg=f"step {i}: paged logits diverge from dense")


@pytest.mark.parametrize("cfg", [DENSE, SSM, HYBRID],
                         ids=[c.family for c in [DENSE, SSM, HYBRID]])
def test_engine_paged_equals_dense(cfg):
    """The engine emits identical greedy tokens with the paged cache and
    with the PR-1 dense layout, across all three LM families (the pure-SSM
    family has no KV cache — its paged engine IS the dense engine — which
    this pins down as well). Pools pin kv_dtype="native": this is a
    LAYOUT-equivalence invariant, exact only at matching pool dtypes, so
    the int8 CI leg's REPRO_KV_DTYPE must not quantize the paged side."""
    api = get_model(cfg)
    params = init_params(cfg)
    outs = []
    for paged in (True, False):
        eng = ServingEngine(api, params, max_batch=2, max_seq=48, chunk=6,
                            block_size=4, paged=paged, kv_dtype="native")
        assert eng.paged == (paged and api.cache_spec.paged)
        for i in range(4):
            eng.submit(Request(uid=i, prompt=[1 + i, 2, 3, 4, 5, 6, 7],
                               max_new_tokens=6))
        outs.append({r.uid: r.generated for r in eng.run_until_drained()})
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------- #
# engine: block accounting, admission control, prefix reuse
# ---------------------------------------------------------------------- #

def make_engine(**kw):
    api = get_model(DENSE)
    return ServingEngine(api, init_params(DENSE), **kw)


def test_engine_frees_blocks_on_completion():
    eng = make_engine(max_batch=2, max_seq=64, chunk=4, block_size=4,
                      prefix_cache=False)
    total = eng.alloc.free_blocks
    for i in range(5):
        eng.submit(Request(uid=i, prompt=[1 + i, 2, 3, 4, 5],
                           max_new_tokens=3))
    eng.run_until_drained()
    assert len(eng.completed) == 5
    assert eng.alloc.free_blocks == total      # every block returned
    assert eng.alloc.check_conservation()


def test_engine_admission_blocks_on_pool_exhaustion():
    """With a pool sized for ~one request, requests serialize through the
    allocator but all complete, FIFO — admission is by free blocks, not
    free slots."""
    eng = make_engine(max_batch=3, max_seq=64, chunk=4, block_size=4,
                      num_blocks=8, prefix_cache=False)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=[1 + i] * 10, max_new_tokens=4))
    done = eng.run_until_drained()
    assert {r.uid for r in done} == set(range(5))
    admits = [r.metrics.admit_t for r in sorted(done, key=lambda r: r.uid)]
    assert all(a <= b for a, b in zip(admits, admits[1:]))
    assert eng.alloc.check_conservation()


def test_engine_prefix_reuse_skips_prefill_and_matches():
    eng = make_engine(max_batch=1, max_seq=64, chunk=8, block_size=8)
    prompt = list(range(1, 41))                # 5 full blocks of 8
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    eng.run_until_drained()
    first = eng.completed[0]
    assert first.metrics.prefix_hit_tokens == 0

    eng.submit(Request(uid=1, prompt=prompt, max_new_tokens=4))
    eng.submit(Request(uid=2, prompt=[90] * 20, max_new_tokens=4))
    done = {r.uid: r for r in eng.run_until_drained()}
    # full-block hits only, and never the whole prompt (the last token must
    # re-run through prefill to produce first-token logits): 40 tokens ->
    # 4 of 5 blocks reused
    assert done[1].metrics.prefix_hit_tokens == 32
    assert done[1].generated == first.generated
    assert done[2].metrics.prefix_hit_tokens == 0
    # fewer prefill steps: 8 remaining tokens @ chunk 8 = 1 step vs 5
    assert done[1].metrics.prefill_steps == 1
    assert first.metrics.prefill_steps == 5
    # stats credit ALL peeked hits (5), including the deepest block that
    # the never-skip-whole-prompt rule re-prefills — it stayed LRU-hot
    assert eng.prefix.hits == 5
    summary = eng.metrics_summary()
    assert summary["mean_prefix_hit_tokens"] > 0


def test_engine_prefix_partial_block_not_shared():
    """A prompt whose tail shares a *partial* block with a cached prefix
    must recompute that tail (copy-on-write degenerates to recompute):
    hits stop at the last full shared block."""
    eng = make_engine(max_batch=1, max_seq=64, chunk=4, block_size=8)
    base = list(range(1, 25))                  # 3 full blocks
    eng.submit(Request(uid=0, prompt=base, max_new_tokens=2))
    eng.run_until_drained()
    # same 24-token prefix + a divergent tail inside block 3
    eng.submit(Request(uid=1, prompt=base + [77, 78, 79],
                       max_new_tokens=2))
    done = {r.uid: r for r in eng.run_until_drained()}
    assert done[1].metrics.prefix_hit_tokens == 24
    # and a prompt diverging INSIDE a shared block hits nothing after it
    eng.submit(Request(uid=2, prompt=base[:4] + [88] * 20,
                       max_new_tokens=2))
    done = {r.uid: r for r in eng.run_until_drained()}
    assert done[2].metrics.prefix_hit_tokens == 0


def test_engine_shared_blocks_freed_only_after_all_users():
    """Two concurrent same-prompt requests share prompt blocks by
    refcount; the blocks only return to the pool when the prefix map
    entry is evicted after both complete."""
    eng = make_engine(max_batch=2, max_seq=64, chunk=8, block_size=8)
    prompt = list(range(1, 25))                # 3 full blocks
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=12))
    for _ in range(3):                         # absorb all 3 chunks so the
        eng.step()                             # prompt blocks get registered
    eng.submit(Request(uid=1, prompt=prompt, max_new_tokens=2))
    done = {r.uid: r for r in eng.run_until_drained()}
    assert done[1].metrics.prefix_hit_tokens == 16
    assert done[1].generated == done[0].generated[:2]
    # all requests done: live blocks are exactly the prefix-pinned ones
    assert eng.alloc.live_blocks == len(eng.prefix)
    eng.prefix.evict(len(eng.prefix))
    assert eng.alloc.live_blocks == 0 and eng.alloc.check_conservation()


def test_engine_prefix_eviction_under_pressure():
    """Prefix-pinned blocks are reclaimed (LRU) when admission runs dry,
    so a full map can never wedge the engine."""
    eng = make_engine(max_batch=1, max_seq=64, chunk=4, block_size=4,
                      num_blocks=9)            # 8 usable
    for i in range(4):
        eng.submit(Request(uid=i, prompt=[10 * i + j for j in range(9)],
                           max_new_tokens=3))  # 3 blocks each, 2 registered
    done = eng.run_until_drained()
    assert len(done) == 4                      # eviction kept admission alive
    assert eng.alloc.check_conservation()


def test_engine_oversized_request_rejected_at_submit():
    """A request whose TOTAL footprint (prefix hits included — they stay
    pinned for the whole request) exceeds the usable pool is rejected at
    submit, not left to wedge the FIFO queue retrying an impossible
    admission mid-scheduling."""
    eng = make_engine(max_batch=1, max_seq=64, chunk=4, block_size=4,
                      num_blocks=7)            # 6 usable
    base = list(range(1, 9))                   # 2 full blocks
    eng.submit(Request(uid=0, prompt=base, max_new_tokens=2))
    eng.run_until_drained()                    # registers the 2 blocks
    # same prefix + long tail: need 8 blocks total, even with 2 hits
    with pytest.raises(ValueError, match="needs 8 blocks"):
        eng.submit(Request(uid=1, prompt=base + list(range(20, 38)),
                           max_new_tokens=4))
    assert not eng.queue                       # never enqueued
    assert eng.alloc.check_conservation()


def test_engine_paged_memory_is_length_proportional():
    """The paged engine's pool can be sized to actual traffic: requests of
    ~16 tokens total run fine in a pool 4x smaller than max_batch*max_seq
    would demand densely."""
    dense_slots_tokens = 4 * 128
    eng = make_engine(max_batch=4, max_seq=128, chunk=4, block_size=4,
                      num_blocks=dense_slots_tokens // (4 * 4) + 1,
                      prefix_cache=False)
    for i in range(8):
        eng.submit(Request(uid=i, prompt=[1 + i] * 8, max_new_tokens=8))
    done = eng.run_until_drained()
    assert len(done) == 8
    assert all(len(r.generated) == 8 for r in done)
