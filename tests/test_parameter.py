
"""Scoped registry semantics (paper §2.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as nn
import repro.core.parametric as PF


def test_scope_paths_and_reuse():
    x = nn.Variable(data=np.ones((2, 4), np.float32))
    with nn.parameter_scope("block1"):
        PF.affine(x, 3)
        with nn.parameter_scope("inner"):
            PF.affine(x, 3)
    keys = set(nn.get_parameters())
    assert "block1/affine/W" in keys
    assert "block1/inner/affine/W" in keys
    # same scope+name -> same parameter object (reuse, not duplicate)
    with nn.parameter_scope("block1"):
        before = nn.get_parameter("affine/W")
        PF.affine(x, 3)
        assert nn.get_parameter("affine/W") is before


def test_scoped_get_parameters_filters():
    x = nn.Variable(data=np.ones((1, 2), np.float32))
    with nn.parameter_scope("a"):
        PF.affine(x, 1)
    with nn.parameter_scope("b"):
        PF.affine(x, 1)
    with nn.parameter_scope("a"):
        assert all(k.startswith("a/") for k in nn.get_parameters())


def test_shape_conflict_raises():
    x = nn.Variable(data=np.ones((2, 4), np.float32))
    PF.affine(x, 3, name="c")
    x2 = nn.Variable(data=np.ones((2, 5), np.float32))
    with pytest.raises(ValueError):
        PF.affine(x2, 3, name="c")


def test_functional_read_missing_param_raises():
    def model(t):
        return PF.dense(t, 4, name="fc")
    params = nn.init(model, jax.random.key(0), jnp.ones((1, 3)))
    bad = {k + "_typo": v for k, v in params.items()}
    with pytest.raises(KeyError):
        nn.apply(model, bad, jnp.ones((1, 3)))


def test_deterministic_init_per_path():
    def model(t):
        return PF.dense(t, 4, name="fc")
    p1 = nn.init(model, jax.random.key(0), jnp.ones((1, 3)))
    p2 = nn.init(model, jax.random.key(0), jnp.ones((1, 3)))
    np.testing.assert_array_equal(np.asarray(p1["fc/kernel"]),
                                  np.asarray(p2["fc/kernel"]))


def test_need_grad_false_excluded():
    nn.set_parameter("stats/mean", jnp.zeros(3), need_grad=False)
    nn.set_parameter("w", jnp.zeros(3), need_grad=True)
    assert "stats/mean" not in nn.get_parameters(grad_only=True)
    assert "stats/mean" in nn.get_parameters(grad_only=False)


def test_parameter_count():
    nn.set_parameter("w", jnp.zeros((3, 4)))
    nn.set_parameter("b", jnp.zeros((4,)))
    assert nn.parameter_count() == 16
