
"""Flash-attention Pallas kernel vs jnp oracle: shape/dtype sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention as fa
from repro.kernels.flash_attention import ref


def rand(shape, dtype, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), dtype)


SWEEP = [
    # (B, Sq, Sk, Hq, Hkv, D, causal, window, dtype)
    (1, 128, 128, 4, 4, 64, True, None, jnp.float32),
    (2, 256, 256, 4, 2, 64, True, None, jnp.float32),
    (1, 128, 128, 8, 1, 128, True, None, jnp.float32),   # MQA
    (1, 100, 160, 4, 4, 64, False, None, jnp.float32),   # ragged + pad
    (1, 256, 256, 4, 2, 64, True, 64, jnp.float32),      # windowed
    (2, 128, 128, 4, 2, 128, True, None, jnp.bfloat16),
    (1, 64, 64, 2, 2, 32, True, None, jnp.float16),
]


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D,causal,window,dtype", SWEEP)
def test_flash_vs_oracle(B, Sq, Sk, Hq, Hkv, D, causal, window, dtype):
    q = rand((B, Sq, Hq, D), dtype, 1)
    k = rand((B, Sk, Hkv, D), dtype, 2)
    v = rand((B, Sk, Hkv, D), dtype, 3)
    got = fa.flash_attention(q, k, v, causal=causal, window=window,
                             block_q=64, block_k=64, interpret=True)
    want = ref.mha_reference(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


DECODE_SWEEP = [
    (1, 128, 4, 4, 64, jnp.float32),
    (3, 512, 8, 2, 64, jnp.float32),
    (2, 256, 8, 1, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("B,Smax,Hq,Hkv,D,dtype", DECODE_SWEEP)
def test_flash_decode_vs_oracle(B, Smax, Hq, Hkv, D, dtype):
    q = rand((B, 1, Hq, D), dtype, 4)
    kc = rand((B, Smax, Hkv, D), dtype, 5)
    vc = rand((B, Smax, Hkv, D), dtype, 6)
    lengths = jnp.asarray(
        np.random.default_rng(7).integers(1, Smax + 1, B), jnp.int32)
    got = fa.flash_decode(q, kc, vc, lengths, block_k=128, interpret=True)
    want = ref.decode_reference(q, kc, vc, lengths)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_block_size_invariance():
    q = rand((1, 256, 4, 64), jnp.float32, 8)
    k = rand((1, 256, 2, 64), jnp.float32, 9)
    v = rand((1, 256, 2, 64), jnp.float32, 10)
    outs = [np.asarray(fa.flash_attention(q, k, v, causal=True, block_q=bq,
                                          block_k=bk, interpret=True))
            for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-5, rtol=1e-5)
